"""Continuous-batching reservoir serving: queue -> slots -> chunked rollout.

Simulates a Poisson stream of variable-length prediction requests against
a trained reservoir and serves it two ways:

* **one-shot** — the classic ``ReservoirEngine.serve()``: wait until the
  whole request list exists, pad it into buckets, roll, answer.
* **continuous** — ``AsyncReservoirServer``: a fixed pool of batch slots,
  the engine rolled in ``chunk_steps`` segments, each live slot's
  reservoir state carried between chunks, finished sequences retired and
  queued ones admitted mid-flight.

Both produce identical predictions; the point is the clock.  The report
prints goodput (useful reservoir steps per second of makespan, measured
from the first arrival), queue waits, time-to-first-prediction and slot
occupancy.

Run:  PYTHONPATH=src python examples/serve_async.py
      PYTHONPATH=src python examples/serve_async.py --dim 512 --slots 16
      PYTHONPATH=src python examples/serve_async.py --backend pallas
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.esn import ESNConfig, fit_readout, init_esn, run_reservoir
from repro.serve import (AsyncReservoirServer, PaddingBucketer,
                         ReservoirEngine, ServeStats, SubmitSpec)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "xla", "pallas"])
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--chunk-steps", type=int, default=16)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--utilization", type=float, default=0.8,
                    help="arrival rate as a fraction of service rate")
    args = ap.parse_args()

    cfg = ESNConfig(reservoir_dim=args.dim, element_sparsity=0.85,
                    output_dim=2, seed=0)
    params = init_esn(cfg)
    rng = np.random.default_rng(0)
    train_u = jnp.asarray(rng.standard_normal((400, 1)), jnp.float32)
    states = run_reservoir(params, train_u, engine="scan")
    targets = jnp.concatenate([train_u, jnp.roll(train_u, 1)], axis=-1)
    params = fit_readout(params, states, targets, lam=1e-2)
    engine = ReservoirEngine(params, backend=args.backend, stats=ServeStats())

    lengths = rng.integers(8, 97, args.requests)
    reqs = [SubmitSpec(rng.standard_normal((int(t), 1)).astype(np.float32),
                       uid=i)
            for i, t in enumerate(lengths)]
    total_steps = int(lengths.sum())

    # Poisson arrivals calibrated against one measured pool chunk.  The
    # warmup compiles the exact chunk program the scheduler runs
    # (predictions + carried final state at the pool shape).
    warm = jnp.asarray(
        rng.standard_normal((args.slots, args.chunk_steps, 1)), jnp.float32)
    warm_x0 = jnp.zeros((args.slots, args.dim), jnp.float32)
    preds, _ = engine.run_segment(warm, warm_x0)
    jax.block_until_ready(preds)                             # compile
    t0 = time.perf_counter()
    preds, _ = engine.run_segment(warm, warm_x0)
    jax.block_until_ready(preds)
    t_chunk = time.perf_counter() - t0
    service_rate = args.slots * args.chunk_steps / t_chunk
    mean_gap = float(np.mean(lengths)) / (args.utilization * service_rate)
    arrivals = np.cumsum(rng.exponential(mean_gap, args.requests))
    arrivals -= arrivals[0]
    print(f"{args.requests} requests, {total_steps} steps total, arrivals "
          f"spread over {arrivals[-1] * 1e3:.1f} ms "
          f"(~{args.utilization:.0%} of service rate)")

    # -- one-shot: the batch exists only after the last arrival ------------
    bucketer = PaddingBucketer(len_buckets=(16, 32, 64, 96),
                               batch_buckets=(1, 2, 4, 8))
    engine.submit_many(reqs, bucketer=bucketer)              # warmup
    t0 = time.perf_counter()
    res_one = engine.submit_many(reqs, bucketer=bucketer)
    makespan_one = float(arrivals[-1]) + time.perf_counter() - t0

    # -- continuous: admit on arrival, chunk, retire, repeat ---------------
    srv = AsyncReservoirServer(engine, n_slots=args.slots,
                               chunk_steps=args.chunk_steps,
                               stats=ServeStats())
    handles = [srv.submit(r, arrival_time=float(at))
               for r, at in zip(reqs, arrivals)]
    res_cont = srv.run()
    makespan_cont = srv.now

    for uid, out in res_cont.items():
        np.testing.assert_allclose(np.asarray(out.output),
                                   np.asarray(res_one[uid].output),
                                   rtol=1e-4, atol=1e-6)
    print(f"\nboth paths served {len(res_cont)} requests with matching "
          f"predictions (backend={engine.backend})")
    print(f"  one-shot   : {total_steps / makespan_one:9.0f} steps/s goodput "
          f"({makespan_one * 1e3:.1f} ms makespan)")
    print(f"  continuous : {total_steps / makespan_cont:9.0f} steps/s goodput "
          f"({makespan_cont * 1e3:.1f} ms makespan, "
          f"{makespan_one / makespan_cont:.2f}x)")
    print("\nqueue stats:", srv.stats.render())
    worst = max(handles, key=lambda q: q.first_output_time - q.arrival_time)
    print(f"worst time-to-first-prediction: request {worst.uid} "
          f"({(worst.first_output_time - worst.arrival_time) * 1e3:.2f} ms "
          f"after arrival)")
    print("OK")


if __name__ == "__main__":
    main()
