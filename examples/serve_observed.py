"""Observed serving: metrics, request traces and the compile-event log.

Runs the continuous-batching server from ``serve_async.py`` with the
observability layer switched on (`repro.obs` is a no-op until
``obs.configure()`` is called) and shows what each sink buys you:

* **metrics** — counters and fixed-bucket latency histograms; the
  summary prints exact p50/p99/p999 queue-wait, time-to-first-prediction
  and end-to-end latency, and the same registry renders a
  Prometheus-format scrape payload;
* **tracing** — every request threads a ``trace_id`` through its
  lifecycle spans (enqueue -> queued -> first_output -> serve), so one
  slow request can be reconstructed stage by stage from the flight
  recorder, which is also dumped as JSONL for offline digging;
* **events** — compile/retrace facts: the first trace of each rollout
  variant is expected, a ``retrace`` under steady traffic is a bug, and
  here it prints as a count you can alert on.

Run:  PYTHONPATH=src python examples/serve_observed.py
      PYTHONPATH=src python examples/serve_observed.py --dim 512
      PYTHONPATH=src python examples/serve_observed.py --trace-out t.jsonl
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.esn import ESNConfig, fit_readout, init_esn, run_reservoir
from repro.serve import (AsyncReservoirServer, ReservoirEngine, ServeStats,
                         SubmitSpec)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "xla", "pallas"])
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--chunk-steps", type=int, default=16)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--utilization", type=float, default=0.8,
                    help="arrival rate as a fraction of service rate")
    ap.add_argument("--trace-out", default="serve_trace.jsonl",
                    help="path for the JSONL span dump")
    ap.add_argument("--metrics-out", default="serve_metrics.prom",
                    help="path for the Prometheus text payload")
    args = ap.parse_args()

    # instrumentation on *before* the engine exists, so the build and
    # every compile land in the event log
    obs.configure()

    cfg = ESNConfig(reservoir_dim=args.dim, element_sparsity=0.85,
                    output_dim=2, seed=0)
    params = init_esn(cfg)
    rng = np.random.default_rng(0)
    train_u = jnp.asarray(rng.standard_normal((400, 1)), jnp.float32)
    states = run_reservoir(params, train_u, engine="scan")
    targets = jnp.concatenate([train_u, jnp.roll(train_u, 1)], axis=-1)
    params = fit_readout(params, states, targets, lam=1e-2)
    engine = ReservoirEngine(params, backend=args.backend, stats=ServeStats())

    lengths = rng.integers(8, 97, args.requests)
    reqs = [SubmitSpec(rng.standard_normal((int(t), 1)).astype(np.float32),
                       uid=i)
            for i, t in enumerate(lengths)]
    total_steps = int(lengths.sum())

    # calibrated Poisson arrivals, same recipe as serve_async.py
    warm = jnp.asarray(
        rng.standard_normal((args.slots, args.chunk_steps, 1)), jnp.float32)
    warm_x0 = jnp.zeros((args.slots, args.dim), jnp.float32)
    preds, _ = engine.run_segment(warm, warm_x0)
    jax.block_until_ready(preds)                             # compile
    t0 = time.perf_counter()
    preds, _ = engine.run_segment(warm, warm_x0)
    jax.block_until_ready(preds)
    t_chunk = time.perf_counter() - t0
    service_rate = args.slots * args.chunk_steps / t_chunk
    mean_gap = float(np.mean(lengths)) / (args.utilization * service_rate)
    arrivals = np.cumsum(rng.exponential(mean_gap, args.requests))
    arrivals -= arrivals[0]

    compiles = obs.events().count("xla_trace") \
        + obs.events().count("pallas_trace")
    print(f"warmup done: {compiles} rollout variants compiled "
          f"(backend={engine.backend})")

    srv = AsyncReservoirServer(engine, n_slots=args.slots,
                               chunk_steps=args.chunk_steps,
                               stats=ServeStats())
    for r, at in zip(reqs, arrivals):
        srv.submit(r, arrival_time=float(at))
    results = srv.run()
    print(f"served {len(results)} requests, {total_steps} steps "
          f"in {srv.now * 1e3:.1f} ms of server time")

    # -- live metrics snapshot ---------------------------------------------
    print("\n== metrics snapshot (merged across label sets) ==")
    for name, val in sorted(obs.metrics().summary().items()):
        if isinstance(val, dict):
            print(f"  {name:28s} n={val['count']:<4d} "
                  f"p50={val['p50'] * 1e3:8.3f} ms  "
                  f"p99={val['p99'] * 1e3:8.3f} ms  "
                  f"p999={val['p999'] * 1e3:8.3f} ms")
        else:
            print(f"  {name:28s} {val:g}")

    # -- one request, reassembled from its trace ---------------------------
    slowest = max(results.values(),
                  key=lambda r: r.timings["latency_s"])
    tid = slowest.timings["trace_id"]
    print(f"\n== lifecycle of the slowest request (trace_id={tid}, "
          f"{slowest.timings['latency_s'] * 1e3:.2f} ms end to end) ==")
    for s in obs.tracer().spans(trace_id=tid):
        print(f"  {s.name:22s} {s.duration_s * 1e3:8.3f} ms "
              f"[{s.clock} clock] {s.attrs}")

    # -- compile-event ledger ----------------------------------------------
    retraces = obs.events().count("retrace")
    print(f"\ncompile events: {compiles} first traces at warmup, "
          f"{retraces} retraces under traffic"
          + (" (steady state held)" if retraces == 0 else "  <-- BUG"))

    # -- exports ------------------------------------------------------------
    n = obs.tracer().export_jsonl(args.trace_out)
    with open(args.metrics_out, "w") as fh:
        fh.write(obs.metrics().prometheus_text())
    print(f"dumped {n} spans to {args.trace_out} and the scrape payload "
          f"to {args.metrics_out}")
    obs.disable()
    print("OK")


if __name__ == "__main__":
    main()
