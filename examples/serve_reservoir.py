"""Reservoir serving end-to-end: compile -> plan -> execute.

Builds a frozen reservoir (the paper's workload), trains its ridge
readout, and serves a stream of variable-length rollout requests through
the fused batched engine — which now answers with *predictions* (``W_out``
fused into the rollout epilogue), not state trajectories.  Prints the
shared ExecutionPlan's compile/cost summary (what was culled, how the
rollout bands under the VMEM budget, the paper's FPGA numbers) and the
throughput/padding statistics.

Run:  PYTHONPATH=src python examples/serve_reservoir.py --dim 512
      PYTHONPATH=src python examples/serve_reservoir.py --mode int8-csd
      PYTHONPATH=src python examples/serve_reservoir.py --backend pallas
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.esn import (ESNConfig, fit_readout, init_esn, predict,
                            run_reservoir)
from repro.launch.report import plan_table
from repro.serve import (PaddingBucketer, ReservoirEngine, RolloutRequest,
                         ServeStats, SubmitSpec)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--mode", default="fp32",
                    choices=["fp32", "int8-pn", "int8-csd"])
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "xla", "pallas"])
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=96)
    args = ap.parse_args()

    cfg = ESNConfig(reservoir_dim=args.dim, element_sparsity=0.85,
                    mode=args.mode, seed=0)
    params = init_esn(cfg)

    # one shared compile: the plan below feeds every backend and the report
    plan = params.w.plan()
    print("=== ExecutionPlan (compile once, execute everywhere) ===")
    print(plan.describe())
    print(plan_table([plan]))

    # train the readout on a short teacher signal, then serve predictions
    rng = np.random.default_rng(0)
    train_u = jnp.asarray(rng.standard_normal((400, 1)), jnp.float32)
    states = run_reservoir(params, train_u, engine="scan")
    targets = jnp.concatenate([train_u, jnp.roll(train_u, 1)], axis=-1)
    params = fit_readout(params, states, targets, lam=1e-2)

    engine = ReservoirEngine(params, backend=args.backend,
                             stats=ServeStats())
    reqs = [RolloutRequest(
                uid=i,
                inputs=rng.standard_normal(
                    (int(rng.integers(8, args.max_len + 1)), 1)
                ).astype(np.float32))
            for i in range(args.requests)]
    bucketer = PaddingBucketer(len_buckets=(16, 32, 64, 128),
                               batch_buckets=(1, 2, 4, 8, 16))

    results = {uid: r.output for uid, r in
               engine.submit_many(
                   [SubmitSpec(q.inputs, uid=q.uid) for q in reqs],
                   bucketer=bucketer).items()}           # predictions!
    print(f"\nserved {len(results)} rollout requests -> predictions "
          f"(dim={args.dim}, mode={args.mode}, backend={engine.backend})")
    print("serve stats:", engine.stats.render())

    # spot-check one request against predict() over the per-step scan
    probe = reqs[0]
    want = np.asarray(predict(params, run_reservoir(
        params, jnp.asarray(probe.inputs), engine="scan")))
    got = np.asarray(results[probe.uid])
    assert got.shape == (probe.length, 2), got.shape
    err = np.abs(got - want).max()
    assert err < 1e-3, err
    print(f"parity vs scan+predict baseline: max |diff| = {err:.2e}")

    # same requests, states contract: one SubmitSpec field away
    specs = [SubmitSpec(r.inputs, uid=r.uid, want_states=True)
             for r in reqs[:2]]
    states_res = engine.submit_many(specs, bucketer=bucketer)
    assert states_res[0].states.shape == (reqs[0].length, args.dim)

    # single-shot latency: fused-readout serve vs states-then-matmul
    u = jnp.asarray(rng.standard_normal((8, 64, 1)), jnp.float32)
    for name, fn in (
            ("two-pass", lambda: jax.block_until_ready(
                predict(params, engine.rollout(u)))),
            ("fused", lambda: jax.block_until_ready(
                engine.predictions(u)))):
        fn()  # warmup
        t0 = time.perf_counter()
        fn()
        dt = time.perf_counter() - t0
        print(f"  {name:8s}: {8 * 64 / dt:9.0f} steps/s "
              f"({dt * 1e3:.1f} ms for 8x64)")
    print("OK")


if __name__ == "__main__":
    main()
