"""Reservoir serving end-to-end: engine + padding buckets + telemetry.

Builds a frozen reservoir (the paper's workload), submits a stream of
variable-length rollout requests, and serves them through the fused
batched engine.  Compares against the legacy per-step scan baseline and
prints the throughput/padding statistics.

Run:  PYTHONPATH=src python examples/serve_reservoir.py --dim 512
      PYTHONPATH=src python examples/serve_reservoir.py --mode int8-csd
      PYTHONPATH=src python examples/serve_reservoir.py --backend pallas
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.esn import ESNConfig, init_esn, run_reservoir
from repro.serve import (PaddingBucketer, ReservoirEngine, RolloutRequest,
                        ServeStats)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--mode", default="fp32",
                    choices=["fp32", "int8-pn", "int8-csd"])
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "xla", "pallas"])
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=96)
    args = ap.parse_args()

    cfg = ESNConfig(reservoir_dim=args.dim, element_sparsity=0.85,
                    mode=args.mode, seed=0)
    params = init_esn(cfg)
    engine = ReservoirEngine(params, backend=args.backend,
                             stats=ServeStats())

    rng = np.random.default_rng(0)
    reqs = [RolloutRequest(
                uid=i,
                inputs=rng.standard_normal(
                    (int(rng.integers(8, args.max_len + 1)), 1)
                ).astype(np.float32))
            for i in range(args.requests)]
    bucketer = PaddingBucketer(len_buckets=(16, 32, 64, 128),
                               batch_buckets=(1, 2, 4, 8, 16))

    results = engine.serve(reqs, bucketer=bucketer)
    print(f"served {len(results)} rollout requests "
          f"(dim={args.dim}, mode={args.mode}, backend={engine.backend})")
    print("serve stats:", engine.stats.render())

    # spot-check one request against the per-step scan baseline
    probe = reqs[0]
    want = np.asarray(run_reservoir(params, jnp.asarray(probe.inputs),
                                    engine="scan"))
    got = np.asarray(results[probe.uid])
    err = np.abs(got - want).max()
    assert err < 1e-4, err
    print(f"parity vs scan baseline: max |diff| = {err:.2e}")

    # single-shot latency comparison on one padded bucket shape
    u = jnp.asarray(rng.standard_normal((8, 64, 1)), jnp.float32)
    for name, fn in (
            ("scan", lambda: jax.block_until_ready(
                run_reservoir(params, u, engine="scan"))),
            ("fused", lambda: jax.block_until_ready(engine.rollout(u)))):
        fn()  # warmup
        t0 = time.perf_counter()
        fn()
        dt = time.perf_counter() - t0
        print(f"  {name:5s}: {8 * 64 / dt:9.0f} steps/s "
              f"({dt * 1e3:.1f} ms for 8x64)")
    print("OK")


if __name__ == "__main__":
    main()
