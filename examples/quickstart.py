"""Quickstart: the paper end-to-end in two minutes on CPU.

Builds the paper's workload — an Echo State Network whose fixed sparse
reservoir is "compiled" offline (int8 quantization -> CSD digit planes ->
block-culled structure) — trains the ridge readout on Mackey-Glass
prediction, and prints the FPGA cost-model report for the exact matrix the
reservoir uses, i.e. the numbers Figs 10-12 of the paper are made of.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import baselines
from repro.core.esn import (ESNConfig, fit_readout, init_esn, nrmse, predict,
                            run_readout, run_reservoir)
from repro.data.pipeline import mackey_glass


def main():
    print("=== reservoir: fixed sparse matrix, compiled offline ===")
    cfg = ESNConfig(reservoir_dim=800, element_sparsity=0.75,  # [5] baseline
                    mode="int8-csd", seed=0)
    params = init_esn(cfg)
    # The one shared compile step every consumer (kernels, serving, cost
    # reports) builds from — the TPU analogue of the paper's synthesis run.
    plan = params.w.plan()
    print(plan.describe())
    cost = plan.fpga_cost()
    gpu = baselines.gpu_latency_s(1024, 0.75, "cusparse")
    print(f"vs modeled V100 cuSPARSE gemv: {gpu * 1e6:.2f} us "
          f"({gpu / cost.latency_s:.0f}x)")

    print("\n=== task: Mackey-Glass one-step prediction ===")
    sig = mackey_glass(3000, seed=0)
    u = jnp.asarray(sig[:-1, None])
    y = jnp.asarray(sig[1:, None])
    states = run_reservoir(params, u)
    params = fit_readout(params, states[500:2000], y[500:2000], lam=1e-6)
    train_err = float(nrmse(predict(params, states[500:2000]),
                            y[500:2000]))
    # serving path: predictions straight from the fused rollout + readout
    preds = run_readout(params, u)
    test_err = float(nrmse(preds[2000:], y[2000:]))
    print(f"NRMSE train={train_err:.4f}  test={test_err:.4f} "
          f"(int8+CSD arithmetic, same digit planes the FPGA would burn in; "
          f"test predictions served by the fused readout path)")
    assert np.isfinite(test_err)


if __name__ == "__main__":
    main()
