"""Nonlinear channel equalization with an ESN — the task of paper ref [3].

A 4-PAM symbol stream is distorted by a multipath channel with a memoryless
nonlinearity and additive noise; the reservoir recovers the transmitted
symbol (delay 2).  Reports symbol error rate (SER) for fp32 and for the
paper's int8+CSD fixed-point reservoir, plus the FPGA cost of the deployed
matrix — the latency-per-symbol story is exactly the paper's pitch for
spatial reservoirs.

Run:  PYTHONPATH=src python examples/channel_equalization.py
"""

import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core.esn import (ESNConfig, fit_readout, init_esn, predict,
                            run_reservoir)
from repro.data.pipeline import channel_equalization

SYMBOLS = np.array([-3.0, -1.0, 1.0, 3.0])


def ser(pred, target):
    pred = np.asarray(pred).ravel()
    snap = SYMBOLS[np.argmin(np.abs(pred[:, None] - SYMBOLS[None, :]), axis=1)]
    return float((snap != np.asarray(target).ravel()).mean())


def main():
    n = 6000
    u, d = channel_equalization(n, seed=0, snr_db=28.0)
    u = (u / np.abs(u).max()).astype(np.float32)
    split = 4000

    # per-mode hyperparameters from a small validation sweep
    hp = {"fp32": dict(input_scale=0.3, leak=0.3, spectral_radius=0.8),
          "int8-csd": dict(input_scale=1.0, leak=0.6, spectral_radius=0.85)}
    for mode in ("fp32", "int8-csd"):
        cfg = ESNConfig(reservoir_dim=600, element_sparsity=0.85, mode=mode,
                        seed=3, **hp[mode])
        p = init_esn(cfg)
        states = run_reservoir(p, jnp.asarray(u[:, None]))
        p = fit_readout(p, states[200:split], jnp.asarray(d[200:split, None]),
                        lam=1e-5)
        test = ser(predict(p, states[split:])[:, 0], d[split:])
        cost = p.w.fpga_cost()
        print(f"{mode:9s} SER={test:.4f}  | deployed matrix: "
              f"{p.w.ones} ones, {cost.latency_ns:.0f} ns/symbol, "
              f"{cost.power_w:.1f} W")
        assert test < 0.2  # chance = 0.75


if __name__ == "__main__":
    main()
