"""Production hardening end to end: backpressure, chaos, and grow-back.

Two acts on the deterministic virtual clock:

1. **Overload + backpressure** (single device): a 3x-oversubscribed
   Poisson trace hits an :class:`~repro.serve.admission.AdmissionPolicy`
   stack — bounded queue depth, deadline shedding off the calibrated
   cost model's queue-delay estimate — and every refusal is an explicit
   ``RolloutResult(status="rejected")`` with a retry-after hint, while
   the admitted requests keep a bounded p99.  The same trace without
   admission control shows the unbounded queue's latency blow-up.

2. **Chaos + elastic grow-back** (8 virtual devices): a sharded server
   runs a seeded :class:`~repro.runtime.faults.FaultPlan` — transient
   engine-call failures (retried with backoff, bit-identical replay),
   straggler windows, and a shard death mid-trace.  The death drains
   into the elastic ``shrink()`` path, the
   :class:`~repro.runtime.elastic.AutoscalePolicy` grows the pool back
   under the backlog, and every completed request is checked
   bit-identical against an undisturbed run.

Run:  PYTHONPATH=src python examples/serve_resilient.py
      PYTHONPATH=src python examples/serve_resilient.py --requests 96
"""

import argparse
import os
import sys

# 8 virtual devices on one CPU; must be set before jax initializes
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.esn import ESNConfig, fit_readout, init_esn, run_reservoir
from repro.dist import DistributedReservoirServer, ShardedReservoirEngine
from repro.runtime.elastic import AutoscalePolicy
from repro.runtime.faults import FaultEvent, FaultPlan
from repro.serve import (AsyncReservoirServer, ReservoirEngine, ServeStats,
                         SubmitSpec, default_policy)


def _trained_params(dim, seed=0):
    cfg = ESNConfig(reservoir_dim=dim, element_sparsity=0.85, output_dim=2,
                    seed=seed)
    params = init_esn(cfg)
    rng = np.random.default_rng(seed)
    train_u = jnp.asarray(rng.standard_normal((400, 1)), jnp.float32)
    states = run_reservoir(params, train_u, engine="scan")
    targets = jnp.concatenate([train_u, jnp.roll(train_u, 1)], axis=-1)
    return fit_readout(params, states, targets, lam=1e-2)


def _trace(n, seed, mean_gap):
    rng = np.random.default_rng(seed)
    lengths = rng.integers(16, 65, n)
    specs = [SubmitSpec(rng.standard_normal((int(t), 1)).astype(np.float32),
                        uid=i)
             for i, t in enumerate(lengths)]
    at = np.cumsum(rng.exponential(mean_gap, n))
    return specs, at - at[0]


def _play(srv, specs, arrivals):
    """Submit each request when the virtual clock reaches its arrival."""
    i, n = 0, len(specs)
    while i < n or not srv.drained:
        while i < n and (arrivals[i] <= srv.now or srv.drained):
            srv.submit(specs[i], arrival_time=float(arrivals[i]))
            i += 1
        srv.step()
    return srv.results


def act_one_backpressure(params, n_req):
    print("=" * 66)
    print("Act 1: overload at ~3x service rate, backpressure on vs off")
    print("=" * 66)
    n_slots, chunk_steps = 4, 16
    # ~40-step requests through a 4x16 pool at 1 tick/chunk: service
    # rate ~1.6 req/tick; a 3x-oversubscribed trace arrives ~4.8/tick
    specs, at = _trace(n_req, seed=1, mean_gap=0.21)
    for label, admission in (("backpressure ON ",
                              default_policy(max_depth=8)),
                             ("backpressure OFF", None)):
        eng = ReservoirEngine(params, stats=ServeStats())
        srv = AsyncReservoirServer(eng, n_slots=n_slots,
                                   chunk_steps=chunk_steps, chunk_time=1.0,
                                   stats=ServeStats(), admission=admission)
        res = _play(srv, specs, at)
        done = [r for r in res.values()
                if getattr(r, "status", "ok") == "ok"]
        lat = sorted(r.timings["latency_s"] for r in done)
        p99 = lat[int(0.99 * (len(lat) - 1))]
        st = srv.stats
        print(f"  {label}: {st.completed} served, {st.rejected} rejected, "
              f"{st.shed} shed | p99 latency {p99:5.1f} ticks "
              f"(makespan {srv.now:.0f})")
        if admission is not None:
            sample = next(r for r in res.values()
                          if getattr(r, "status", "ok") == "rejected")
            print(f"    a rejection is explicit: status={sample.status!r}, "
                  f"reason={sample.timings['reason']!r}, "
                  f"retry_after_s={sample.timings['retry_after_s']:.1f}")
    print()


def act_two_chaos(params, n_req):
    print("=" * 66)
    print("Act 2: chaos trace — transients, stragglers, shard death, "
          "grow-back")
    print("=" * 66)
    n_shards, sps, chunk_steps = 4, 2, 16
    specs, at = _trace(n_req, seed=2, mean_gap=0.35)
    loss_at = float(at[-1]) * 0.4

    def serve(plan, autoscale):
        eng = ShardedReservoirEngine(params, n_shards=n_shards,
                                     stats=ServeStats())
        srv = DistributedReservoirServer(
            eng, slots_per_shard=sps, chunk_steps=chunk_steps,
            chunk_time=1.0, stats=ServeStats(), fault_plan=plan,
            autoscale=autoscale)
        res = _play(srv, specs, at)
        return res, srv

    plan = FaultPlan([
        FaultEvent("transient", at=1.0, count=2),
        FaultEvent("slow_shard", at=3.0, factor=3.0, duration=2.0),
        FaultEvent("shard_loss", at=loss_at, shard=1),
    ], backoff_base_s=1 / 64)
    chaos, srv = serve(plan, AutoscalePolicy(min_shards=1,
                                             max_shards=n_shards,
                                             cooldown_steps=2))
    print(f"  injected: {plan.injected} "
          f"(shard death at tick {loss_at:.0f})")
    print(f"  recovered: {srv.reshards} reshard(s) + {srv.grows} grow(s), "
          f"{srv.readmitted} sequences re-admitted with carried state, "
          f"{srv.stats.retries} retried engine calls")
    print(f"  served {srv.stats.completed}/{n_req}, "
          f"lost {srv.stats.enqueued - srv.stats.completed - srv.stats.timed_out}")

    ref, ref_srv = serve(None, None)
    for uid, r in chaos.items():
        np.testing.assert_array_equal(np.asarray(r.output),
                                      np.asarray(ref[uid].output))
    print(f"  every completed request is BIT-IDENTICAL to the undisturbed "
          f"run (makespan {srv.now:.0f} vs {ref_srv.now:.0f} ticks)")
    print()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--requests", type=int, default=64)
    args = ap.parse_args()
    assert len(jax.devices()) >= 4, "needs >= 4 (virtual) devices"
    params = _trained_params(args.dim)
    act_one_backpressure(params, args.requests)
    act_two_chaos(params, args.requests)
    print("OK")


if __name__ == "__main__":
    main()
