"""Sharded reservoir serving: one FIFO, 8 shards, a mid-flight shard loss.

The reservoir matrix is fixed and replicated (the paper's premise), so
serving scale-out is pure batch-axis data parallelism:

    global FIFO ──► least-loaded admission ──► per-shard slot sub-pools
                                                  │ one shard_map call
                                                  ▼ per chunk
                                        8 x (slots, chunk_steps) rollouts
                                        (zero collectives in the hot loop)

This example streams a Poisson trace of prediction requests into a
:class:`~repro.dist.DistributedReservoirServer` over 8 virtual CPU
devices, kills 3 shards mid-flight, and shows the elastic path: the mesh
shrinks to the survivors, the engine rebuilds from the cached
ExecutionPlan, every in-flight sequence is re-admitted with its carried
reservoir state — no request lost, every prediction still matching the
single-device engine.

Run:  PYTHONPATH=src python examples/serve_sharded.py
      PYTHONPATH=src python examples/serve_sharded.py --shards 4 --fail 1
"""

import argparse
import os
import sys

# 8 virtual devices on one CPU; must be set before jax initializes
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.esn import ESNConfig, fit_readout, init_esn, run_reservoir
from repro.dist import DistributedReservoirServer, ShardedReservoirEngine
from repro.serve import ReservoirEngine, ServeStats, SubmitSpec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--slots-per-shard", type=int, default=4)
    ap.add_argument("--chunk-steps", type=int, default=16)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--fail", type=int, default=3,
                    help="shards to kill mid-flight (0 disables)")
    args = ap.parse_args()
    assert args.shards <= len(jax.devices()), \
        f"{args.shards} shards > {len(jax.devices())} devices"

    cfg = ESNConfig(reservoir_dim=args.dim, element_sparsity=0.85,
                    output_dim=2, seed=0)
    params = init_esn(cfg)
    rng = np.random.default_rng(0)
    train_u = jnp.asarray(rng.standard_normal((400, 1)), jnp.float32)
    states = run_reservoir(params, train_u, engine="scan")
    targets = jnp.concatenate([train_u, jnp.roll(train_u, 1)], axis=-1)
    params = fit_readout(params, states, targets, lam=1e-2)

    engine = ShardedReservoirEngine(params, n_shards=args.shards,
                                    stats=ServeStats())
    srv = DistributedReservoirServer(engine,
                                     slots_per_shard=args.slots_per_shard,
                                     chunk_steps=args.chunk_steps,
                                     chunk_time=1.0, stats=ServeStats())
    print(f"mesh: {args.shards} data shards x {args.slots_per_shard} slots, "
          f"chunk_steps={args.chunk_steps} (virtual clock, 1 tick/chunk)")

    lengths = rng.integers(16, 97, args.requests)
    reqs = [SubmitSpec(rng.standard_normal((int(t), 1)).astype(np.float32),
                       uid=i)
            for i, t in enumerate(lengths)]
    arrivals = np.cumsum(rng.exponential(0.15, args.requests))
    arrivals -= arrivals[0]
    for r, at in zip(reqs, arrivals):
        srv.submit(r, arrival_time=float(at))
    print(f"{args.requests} requests ({int(lengths.sum())} steps) arriving "
          f"over {arrivals[-1]:.1f} ticks\n")

    # serve a few chunks, then lose shards mid-flight
    fail_after = 4
    while srv.step():
        if args.fail and srv.reshards == 0 and srv.stats.chunks >= fail_after:
            live = srv.batcher.live
            plan = srv.shrink(failed=args.fail)
            print(f"tick {srv.now:.1f}: lost {args.fail} shards with {live} "
                  f"sequences in flight")
            print(f"  replan: {plan['n_shards_before']} -> "
                  f"{plan['n_shards_after']} shards, "
                  f"{plan['readmitted']} sequences re-admitted with carried "
                  f"state")
            for act in plan["actions"]:
                print(f"    - {act}")
    res = srv.results

    # every prediction must match the single-device engine
    single = ReservoirEngine(params, stats=ServeStats())
    for r in reqs:
        want = np.asarray(single.predictions(jnp.asarray(r.inputs)))
        np.testing.assert_allclose(np.asarray(res[r.uid].output), want,
                                   rtol=1e-4, atol=1e-6)
    print(f"\nall {len(res)}/{args.requests} requests served "
          f"(reshards={srv.reshards}, re-admitted={srv.readmitted}); "
          f"predictions match the single-device engine")
    print("\nserver stats:", srv.stats.render())
    print("\nper-shard (all topology epochs):", srv.shard_summary().render())
    print("OK")


if __name__ == "__main__":
    main()
