"""Multivariate time-series classification with reservoir states ([5]).

Paper ref [5] compares reservoir systems against fully-trained RNNs on
multivariate time-series classification and finds comparable quality at a
fraction of the training cost — only the linear readout is fit.  This
example reproduces that protocol on a synthetic 3-class task: each class is
a differently-parameterized 4-channel oscillator; the classifier is a ridge
readout over the reservoir's final states.

Run:  PYTHONPATH=src python examples/timeseries_classification.py
"""

import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core.esn import ESNConfig, init_esn, run_reservoir
from repro.core.ridge import ridge_fit


def make_dataset(n_per_class=60, t=120, channels=4, seed=0):
    rng = np.random.default_rng(seed)
    xs, ys = [], []
    # class-specific frequency/coupling signatures
    freqs = [(0.9, 0.23), (0.5, 0.61), (1.4, 0.11)]
    for label, (f1, f2) in enumerate(freqs):
        for _ in range(n_per_class):
            phase = rng.uniform(0, 2 * np.pi, channels)
            tt = np.arange(t)[:, None]
            sig = (np.sin(f1 * tt / 4 + phase) +
                   0.5 * np.sin(f2 * tt / 3 + phase[::-1]) +
                   0.15 * rng.standard_normal((t, channels)))
            xs.append(sig.astype(np.float32))
            ys.append(label)
    xs = np.stack(xs)
    ys = np.asarray(ys)
    order = rng.permutation(len(ys))
    return xs[order], ys[order]


def main():
    x, y = make_dataset()
    split = 120
    cfg = ESNConfig(reservoir_dim=400, input_dim=4, element_sparsity=0.8,
                    spectral_radius=0.9, leak=0.5, mode="int8-csd", seed=1)
    p = init_esn(cfg)

    states = run_reservoir(p, jnp.asarray(x))        # (N, T, dim)
    # representation: per-unit mean + std over the settled half of the
    # sequence (phase-invariant — the classes differ by frequency content,
    # and samples carry random phases)
    settled = np.asarray(states[:, 60:, :])
    feats = np.concatenate([settled.mean(axis=1), settled.std(axis=1)],
                           axis=1)
    onehot = np.eye(3, dtype=np.float32)[y]

    w = ridge_fit(jnp.asarray(feats[:split]), jnp.asarray(onehot[:split]),
                  lam=1e-3)
    pred = np.asarray(jnp.asarray(feats[split:]) @ w).argmax(1)
    acc = float((pred == y[split:]).mean())
    cost = p.w.fpga_cost()
    print(f"3-class multivariate series: test accuracy = {acc:.3f} "
          f"(chance 0.333)")
    print(f"reservoir: {cfg.reservoir_dim} units, int8+CSD, "
          f"{p.w.ones} ones -> {cost.latency_ns:.0f} ns/step on XCVU13P")
    assert acc > 0.8
    print("OK")


if __name__ == "__main__":
    main()
