"""End-to-end LM training driver: config -> mesh -> steps -> checkpoints.

Exercises the full production path on whatever devices exist (CPU here):
deterministic data stream, jitted train step with the same sharding rules
the 512-chip dry-run uses, async checkpointing with auto-resume, straggler
watchdog, and an optional simulated host failure that goes through the
elastic re-plan + checkpoint-restore path.

Presets:
  tiny  (~11M params, default)  - a few hundred steps in minutes on CPU
  100m  (~124M params)          - the assignment's ~100M driver; same code,
                                  run with --steps 300 on real hardware

Run:  PYTHONPATH=src python examples/train_lm.py --steps 60
      PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 4
      PYTHONPATH=src python examples/train_lm.py --simulate-failure
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs.base import ModelConfig
from repro.data.pipeline import LMStreamConfig, lm_batch
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models.transformer import LM
from repro.optim import adamw
from repro.runtime.elastic import StragglerWatchdog, replan_after_failure

PRESETS = {
    "tiny": ModelConfig(
        name="tiny-lm", family="dense", n_layers=4, d_model=256, n_heads=4,
        n_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=2048,
        tie_embeddings=True, remat="none"),
    "100m": ModelConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, head_dim=64, d_ff=3072, vocab_size=32768,
        tie_embeddings=True, remat="full"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--simulate-failure", action="store_true",
                    help="kill-and-recover mid-run through the elastic path")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    lm = LM(cfg)
    mesh = make_host_mesh()
    print(f"preset={args.preset} params={lm.param_count():,} "
          f"devices={len(jax.devices())}")

    stream = LMStreamConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                            global_batch=args.batch, seed=0)
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=20,
                                total_steps=max(args.steps, 100))

    params = lm.init(jax.random.PRNGKey(0)).params
    opt = adamw.init_state(params)
    state = {"params": params, "opt": opt}

    start = 0
    resumed = store.latest_step(args.ckpt_dir)
    if resumed is not None:
        state = store.restore(state, args.ckpt_dir, resumed)
        start = resumed + 1
        print(f"resumed from checkpoint step {resumed}")

    step_fn = jax.jit(make_train_step(lm, mesh, opt_cfg), donate_argnums=0)
    ck = store.Checkpointer(args.ckpt_dir, every=args.ckpt_every, keep=2)
    wd = StragglerWatchdog(threshold=4.0)

    losses = []
    for step in range(start, args.steps):
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in
                 lm_batch(stream, step).items()}
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        wd.record(step, time.perf_counter() - t0)
        losses.append(loss)
        ck.maybe_save(state, step)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {loss:7.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"({time.perf_counter() - t0:.2f}s)")

        if args.simulate_failure and step == args.steps // 2:
            print("\n--- simulating host failure: 16 of 256 devices lost ---")
            plan = replan_after_failure(256, failed=16, model_parallel=16)
            for action in plan["actions"]:
                print("   ", action)
            print(f"    new mesh: {plan['mesh_shape']} {plan['mesh_axes']}")
            ck.finalize()
            resumed = store.latest_step(args.ckpt_dir)
            assert resumed is not None, "no verified checkpoint to resume!"
            state = store.restore(state, args.ckpt_dir, resumed)
            print(f"    restored verified checkpoint step {resumed}; "
                  f"resuming\n")

    ck.finalize()
    first = np.mean(losses[:5]) if len(losses) >= 5 else losses[0]
    last = np.mean(losses[-5:])
    print(f"\nloss {first:.3f} -> {last:.3f} over {len(losses)} steps "
          f"(stragglers flagged: {len(wd.flagged)})")
    if len(losses) >= 40:
        assert last < first - 0.3, "training did not reduce loss"
        print("OK: loss decreased")


if __name__ == "__main__":
    main()
