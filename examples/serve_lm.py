"""Batched LM serving driver on the repro.serve layer.

Loads a small LM (random weights — the point is the serving machinery),
takes a set of *variable-length* prompts, groups them through the serve
layer's :class:`PaddingBucketer` (one compiled prefill/decode pair per
bucket shape instead of one per request shape), decodes tokens, and
reports throughput + padding efficiency via :class:`ServeStats`.

With ``--frozen-sparse`` the final-projection matmul additionally runs
through the paper's FixedMatrix pipeline (int8 + CSD digit planes) and
reports the cost-model numbers — the LM-serving face of the paper's
fixed-matrix specialization.

Run:  PYTHONPATH=src python examples/serve_lm.py --tokens 16
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.transformer import LM
from repro.serve import PaddingBucketer, RolloutRequest, ServeStats

CFG = ModelConfig(
    name="serve-demo", family="dense", n_layers=4, d_model=256, n_heads=4,
    n_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=2048,
    tie_embeddings=True, remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--min-prompt", type=int, default=24)
    ap.add_argument("--max-prompt", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--frozen-sparse", action="store_true")
    args = ap.parse_args()

    lm = LM(CFG)
    mesh = make_host_mesh()
    params = lm.init(jax.random.PRNGKey(0)).params
    rng = np.random.default_rng(0)

    # Ragged prompts -> padded microbatches via the serve layer's bucketer.
    reqs = [RolloutRequest(
                uid=i,
                inputs=rng.integers(
                    0, CFG.vocab_size,
                    (int(rng.integers(args.min_prompt, args.max_prompt + 1)),
                     1)).astype(np.int32))
            for i in range(args.requests)]
    bucketer = PaddingBucketer(len_buckets=(32, 64, 128, 256),
                               batch_buckets=(1, 2, 4, 8, 16))
    stats = ServeStats()
    decoded = {}
    step_cache = {}  # (bucket_len,) -> jitted prefill/decode pair

    for mb in bucketer.group(reqs):
        bpad, tpad, _ = mb.inputs.shape
        cache_len = tpad + args.tokens
        if tpad not in step_cache:
            step_cache[tpad] = (
                jax.jit(make_prefill_step(lm, mesh, cache_len)),
                jax.jit(make_decode_step(lm, mesh), donate_argnums=1))
        prefill, decode = step_cache[tpad]
        prompts = jnp.asarray(mb.inputs[:, :, 0])  # (bpad, tpad) tokens

        t0 = time.perf_counter()
        logits, caches = prefill(params, {"tokens": prompts})
        logits.block_until_ready()
        stats.record_call(batch=bpad, steps=tpad,
                          seconds=time.perf_counter() - t0,
                          real_steps=mb.real_steps)

        # Seed decode from each request's REAL last prompt token, not the
        # padded position.  (Right-padding does leave pad tokens in the KV
        # cache — acceptable for this random-weights demo; production
        # serving would mask them in attention.)
        lens = np.asarray(mb.lengths + [tpad] * (bpad - len(mb.requests)))
        tok = jnp.argmax(
            logits[jnp.arange(bpad), lens - 1], axis=-1
        ).astype(jnp.int32)[:, None]
        out = [tok]
        t0 = time.perf_counter()
        for _ in range(args.tokens - 1):
            logits, caches = decode(params, caches, tok)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(tok)
        jax.block_until_ready(tok)
        stats.record_call(batch=bpad, steps=args.tokens - 1,
                          seconds=time.perf_counter() - t0,
                          real_steps=(args.tokens - 1) * len(mb.requests))
        seq = np.concatenate([np.asarray(t) for t in out], axis=1)
        for j, req in enumerate(mb.requests):
            decoded[req.uid] = seq[j]

    assert len(decoded) == args.requests
    for uid, seq in decoded.items():
        assert seq.shape == (args.tokens,)
        assert (seq >= 0).all() and (seq < CFG.vocab_size).all()
    print(f"served {args.requests} ragged prompts "
          f"({args.min_prompt}-{args.max_prompt} tokens) through "
          f"{len(step_cache)} bucket shapes")
    print("serve stats:", stats.render())

    if args.frozen_sparse:
        from repro.core.sparse import FixedMatrix
        table = np.asarray(params["embed"], np.float32)  # (V, d) tied head
        t0 = time.perf_counter()
        fm = FixedMatrix.compile(table.T, weight_bits=8, mode="csd")
        t_compile = time.perf_counter() - t0
        cost = fm.fpga_cost()
        dense_bytes = table.size * 2
        plane_bytes = fm.ones / 8 + fm.plan().stats.blocks_nnz * 16
        print(f"\nfrozen-sparse head: compiled in {t_compile:.1f}s — "
              f"{fm.ones} ones, element sparsity {fm.element_sparsity:.2f}")
        print(f"  spatial-model latency {cost.latency_ns:.0f} ns/token; "
              f"bf16 stream {dense_bytes / 1e6:.1f} MB vs digit-plane "
              f"{plane_bytes / 1e6:.1f} MB per read")
    print("OK")


if __name__ == "__main__":
    main()
