"""Batched serving driver: prefill + decode loop with the production steps.

Loads a small LM (random weights — the point is the serving machinery),
prefills a batch of prompts, then decodes tokens with the same jitted
``decode_step`` the 512-chip dry-run lowers.  With ``--frozen-sparse`` the
final-projection matmul additionally runs through the paper's FixedMatrix
pipeline (int8 + CSD digit planes) and reports the cost-model numbers —
the LM-serving face of the paper's fixed-matrix specialization.

Run:  PYTHONPATH=src python examples/serve_lm.py --tokens 16
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.transformer import LM

CFG = ModelConfig(
    name="serve-demo", family="dense", n_layers=4, d_model=256, n_heads=4,
    n_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=2048,
    tie_embeddings=True, remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--frozen-sparse", action="store_true")
    args = ap.parse_args()

    lm = LM(CFG)
    mesh = make_host_mesh()
    params = lm.init(jax.random.PRNGKey(0)).params
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, CFG.vocab_size,
                                       (args.batch, args.prompt_len)))
    cache_len = args.prompt_len + args.tokens

    prefill = jax.jit(make_prefill_step(lm, mesh, cache_len))
    decode = jax.jit(make_decode_step(lm, mesh), donate_argnums=1)

    t0 = time.perf_counter()
    logits, caches = prefill(params, {"tokens": prompts})
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0
    print(f"prefill: batch={args.batch} len={args.prompt_len} "
          f"in {t_prefill * 1e3:.0f} ms "
          f"({args.batch * args.prompt_len / t_prefill:.0f} tok/s)")

    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.tokens - 1):
        logits, caches = decode(params, caches, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    seq = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"decode:  {args.tokens - 1} steps x batch {args.batch} "
          f"in {dt * 1e3:.0f} ms "
          f"({args.batch * (args.tokens - 1) / dt:.0f} tok/s)")
    assert seq.shape == (args.batch, args.tokens)
    assert (seq >= 0).all() and (seq < CFG.vocab_size).all()

    if args.frozen_sparse:
        from repro.core.sparse import FixedMatrix
        table = np.asarray(params["embed"], np.float32)  # (V, d) tied head
        t0 = time.perf_counter()
        fm = FixedMatrix.compile(table.T, weight_bits=8, mode="csd")
        t_compile = time.perf_counter() - t0
        cost = fm.fpga_cost()
        dense_bytes = table.size * 2
        plane_bytes = fm.ones / 8 + fm.blocks.n_blocks_nnz * 16
        print(f"\nfrozen-sparse head: compiled in {t_compile:.1f}s — "
              f"{fm.ones} ones, element sparsity {fm.element_sparsity:.2f}")
        print(f"  spatial-model latency {cost.latency_ns:.0f} ns/token; "
              f"bf16 stream {dense_bytes / 1e6:.1f} MB vs digit-plane "
              f"{plane_bytes / 1e6:.1f} MB per read")
    print("OK")


if __name__ == "__main__":
    main()
