"""Plan specialization: regimes, CSD folding, shift-add, parity, caches.

The contract is the tentpole's: whatever regime the plan selects
(resident / double-buffered pipeline), whatever the schedule folds or
strength-reduces, the specialized rollout is *bit-identical* to the
generic banded kernel — property-tested across
{fp32, int8-pn, int8-csd} x {resident, pipelined} x {one-shot, chunked}.
On top: regime selection against the VMEM budget, the constant-propagated
fold collapsing digit planes into the quantized block, shift-add emission
below the crossover, the specialized XLA schedules, and the bounded
plan/engine caches.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.esn import ESNConfig, ESNParams
from repro.core.sparse import FixedMatrix, random_sparse_matrix
from repro.kernels.reservoir_rollout.ops import FusedRollout
from repro.kernels.reservoir_rollout.specialized import SpecializedRollout
from repro.plan import plan_for, specialize_rollout, specialize_summary
from repro.plan.plan import plan_cache_stats
from repro.plan.specialize import MM, SA, int8_recur_reference
from repro.serve.engine import (ENGINE_CACHE_MAX, ReservoirEngine,
                                engine_cache_clear, engine_cache_stats,
                                engine_for)

DIM, BLOCK = 256, 64
TILE = BLOCK * BLOCK
# budgets that force the pipelined regime at DIM/BLOCK (cap = budget // 2
# still fits each single column's tiles, total does not fit)
PIPELINE_BUDGET = {"fp32": TILE * 4 * 10, "int8": TILE * 10}


def _fixed_matrix(digit_mode="csd", es=0.9, seed=0, dim=DIM, block=BLOCK):
    rng = np.random.default_rng(seed)
    w = random_sparse_matrix(dim, dim, es, rng) * 0.05
    return FixedMatrix.compile(w, weight_bits=8, mode=digit_mode,
                               block=block, rng=rng)


def _params(fm, esn_mode, seed=0, w_out=True):
    dim = fm.shape[0]
    rng = np.random.default_rng(seed + 100)
    cfg = ESNConfig(reservoir_dim=dim, input_dim=4, mode=esn_mode,
                    block=fm.blocks.block, seed=seed)
    return ESNParams(
        w=fm,
        w_in=jnp.asarray(rng.uniform(-0.5, 0.5, (4, dim)), jnp.float32),
        w_out=jnp.asarray(rng.uniform(-0.1, 0.1, (dim, 4)), jnp.float32)
        if w_out else None,
        config=cfg)


_FMS = {}


def _fm_for(esn_mode):
    if esn_mode not in _FMS:
        digit = "csd" if esn_mode != "int8-pn" else "pn"
        _FMS[esn_mode] = _fixed_matrix(digit)
    return _FMS[esn_mode]


_PAIRS = {}


def _kernel_pair(esn_mode, regime):
    """(generic banded, specialized) rollout ops for one mode/regime."""
    key = (esn_mode, regime)
    if key not in _PAIRS:
        fm = _fm_for(esn_mode)
        kmode = "fp32" if esn_mode == "fp32" else "int8"
        budget = None if regime == "resident" else PIPELINE_BUDGET[kmode]
        rng = np.random.default_rng(7)
        w_in = rng.uniform(-0.5, 0.5, (4, DIM)).astype(np.float32)
        w_out = rng.uniform(-0.1, 0.1, (DIM, 4)).astype(np.float32)
        base = FusedRollout(plan_for(fm), w_in, leak=0.7, mode=kmode,
                            w_out=w_out)
        spec = SpecializedRollout(plan_for(fm), w_in, leak=0.7, mode=kmode,
                                  w_out=w_out, vmem_budget=budget,
                                  batch_tile_max=8)
        assert spec.regime == regime, (key, spec.regime)
        _PAIRS[key] = (base, spec)
    return _PAIRS[key]


class TestRegimeSelection:
    def test_resident_when_tiles_fit(self):
        plan = plan_for(_fm_for("fp32"))
        prog = specialize_rollout(plan, "fp32", vmem_budget=None)
        assert prog.regime == "resident" and prog.n_bands == 1

    def test_pipelined_when_budget_exceeded(self):
        plan = plan_for(_fm_for("fp32"))
        prog = specialize_rollout(plan, "fp32",
                                  vmem_budget=PIPELINE_BUDGET["fp32"])
        assert prog.regime == "pipelined" and prog.n_bands > 1
        # every band's tiles fit half the budget (double buffering)
        itemsize = 4
        for band in prog.schedules:
            terms = sum(1 for _ci, ts in band for t in ts if t[0] == MM)
            assert terms * TILE * itemsize <= PIPELINE_BUDGET["fp32"] // 2

    def test_column_larger_than_half_budget_raises(self):
        plan = plan_for(_fm_for("fp32"))
        with pytest.raises(ValueError, match="double buffering"):
            specialize_rollout(plan, "fp32", vmem_budget=TILE * 4 * 3)

    def test_program_cached_per_plan(self):
        plan = plan_for(_fm_for("fp32"))
        assert (specialize_rollout(plan, "fp32")
                is specialize_rollout(plan, "fp32"))

    def test_batch_tiling_balanced(self):
        prog = specialize_rollout(plan_for(_fm_for("fp32")), "fp32")
        assert prog.batch_tiling(64) == (16, 4, 64)
        assert prog.batch_tiling(5) == (5, 1, 5)
        assert prog.batch_tiling(20) == (10, 2, 20)
        b_tile, n, b_pad = prog.batch_tiling(17)
        assert b_tile * n == b_pad >= 17 and b_pad - 17 < n

    def test_summary_matches_program(self):
        plan = plan_for(_fm_for("int8-csd"))
        s = specialize_summary(plan, "int8",
                               vmem_budget=PIPELINE_BUDGET["int8"])
        prog = specialize_rollout(plan, "int8",
                                  vmem_budget=PIPELINE_BUDGET["int8"])
        assert s["regime"] == prog.regime
        assert s["n_bands"] == prog.n_bands
        assert s["n_matmul_terms"] == prog.n_matmul_terms
        assert s["n_shiftadd_terms"] == prog.n_shiftadd_terms
        assert s["resident_bytes"] == prog.resident_bytes

    def test_describe_reports_regime(self):
        plan = plan_for(_fm_for("int8-csd"))
        text = plan.describe()
        assert "specialized: fp32" in text and "specialized: int8" in text
        assert "matmul terms" in text and "shift-add" in text
        prog = specialize_rollout(plan, "int8")
        assert prog.regime in prog.describe()


class TestConstantPropagation:
    def test_full_fold_is_quantized_block(self):
        """With the crossover at 0 nothing is strength-reduced, so every
        block folds ALL its planes — and the fold must be exactly the
        quantized block: sum_w 2^w d_w == q."""
        fm = _fm_for("int8-csd")
        plan = plan_for(fm)
        prog = specialize_rollout(plan, "int8", vmem_budget=None, crossover=0)
        assert prog.n_shiftadd_terms == 0
        q = np.asarray(fm.q, np.int64)
        qpad = np.zeros((plan.rows_pad, plan.cols_pad), np.int64)
        qpad[: q.shape[0], : q.shape[1]] = q
        data = np.asarray(prog.data)
        for ci, terms in prog.schedules[0]:
            for tag, slot, shift, ri in terms:
                assert tag == MM and shift == 0
                tile = qpad[ri * BLOCK:(ri + 1) * BLOCK,
                            ci * BLOCK:(ci + 1) * BLOCK]
                assert (data[0, slot].astype(np.int64) == tile).all()

    def test_shiftadd_emitted_below_crossover(self):
        """A huge crossover strength-reduces every plane: no matmul terms
        survive, the digit count equals the matrix's set-digit count, and
        the schedule is still exact."""
        fm = _fixed_matrix("csd", es=0.995, seed=3, dim=128, block=32)
        plan = plan_for(fm)
        prog = specialize_rollout(plan, "int8", vmem_budget=None,
                                  crossover=10**9)
        assert prog.n_matmul_terms == 0 and prog.n_shiftadd_terms > 0
        assert prog.shiftadd_digits == int(
            np.count_nonzero(plan.int8_tiles))
        rng = np.random.default_rng(0)
        xq = jnp.asarray(rng.integers(-128, 128, (3, 128)), jnp.int32)
        ref = fm.matvec_int_exact(xq)
        got = int8_recur_reference(prog, xq, plan.rows_pad, 128)
        assert (np.asarray(ref) == np.asarray(got)).all()

    def test_mixed_schedule_is_exact(self):
        """Default crossover on a sparse matrix mixes folded matmuls and
        shift-adds; the int32 total must still equal the exact plane sum."""
        fm = _fixed_matrix("csd", es=0.97, seed=4, dim=128, block=32)
        plan = plan_for(fm)
        prog = specialize_rollout(plan, "int8", vmem_budget=None)
        assert prog.n_matmul_terms > 0 and prog.n_shiftadd_terms > 0
        rng = np.random.default_rng(1)
        xq = jnp.asarray(rng.integers(-128, 128, (4, 128)), jnp.int32)
        ref = fm.matvec_int_exact(xq)
        got = int8_recur_reference(prog, xq, plan.rows_pad, 128)
        assert (np.asarray(ref) == np.asarray(got)).all()

    def test_sa_terms_reference_real_digits(self):
        fm = _fixed_matrix("csd", es=0.97, seed=4, dim=128, block=32)
        plan = plan_for(fm)
        prog = specialize_rollout(plan, "int8", vmem_budget=None)
        tiles = plan.int8_tiles
        rows, cols = plan.block_rows, plan.block_cols
        for band in prog.schedules:
            for ci, terms in band:
                for term in terms:
                    if term[0] != SA:
                        continue
                    _tag, ri, digits = term
                    # locate the source block and check each digit
                    (di,) = [int(d) for d in np.flatnonzero(
                        (cols == ci) & (rows == ri))]
                    for i, j, s, w in digits:
                        assert int(tiles[w, di][i, j]) == s != 0


MODES = ("fp32", "int8-pn", "int8-csd")
REGIMES = ("resident", "pipelined")


class TestSpecializedParity:
    @given(st.sampled_from(MODES), st.sampled_from(REGIMES),
           st.booleans(), st.integers(1, 20), st.integers(0, 10**6))
    @settings(max_examples=15, deadline=None)
    def test_bitwise_parity_with_banded_kernel(self, mode, regime, chunked,
                                               batch, seed):
        """Specialized == generic banded kernel, bit for bit, across
        modes x regimes x chunked/one-shot (states, preds, final state)."""
        base, spec = _kernel_pair(mode, regime)
        rng = np.random.default_rng(seed)
        t = 8
        u = jnp.asarray(rng.standard_normal((t, batch, 4)), jnp.float32)
        ref_s, ref_f = base(u, want_states=True, want_final=True)
        ref_p = base(u, want_states=False, want_preds=True)
        if chunked:
            # two chunks resuming from the carried final state
            s1, f1 = spec(u[: t // 2], want_states=True, want_final=True)
            s2, f2 = spec(u[t // 2:], x0=f1, want_states=True,
                          want_final=True)
            got_s = jnp.concatenate([s1, s2], axis=0)
            got_f = f2
            p1, g1 = spec(u[: t // 2], want_states=False,
                          want_preds=True, want_final=True)
            p2 = spec(u[t // 2:], x0=g1, want_states=False,
                      want_preds=True)
            got_p = jnp.concatenate([p1, p2], axis=0)
        else:
            got_s, got_f = spec(u, want_states=True, want_final=True)
            got_p = spec(u, want_states=False, want_preds=True)
        assert (np.asarray(ref_s) == np.asarray(got_s)).all()
        assert (np.asarray(ref_f) == np.asarray(got_f)).all()
        assert (np.asarray(ref_p) == np.asarray(got_p)).all()


class TestSpecializedEpilogues:
    def test_readout_every_k_matches_generic(self):
        fm = _fm_for("fp32")
        rng = np.random.default_rng(9)
        w_in = rng.uniform(-0.5, 0.5, (4, DIM)).astype(np.float32)
        w_out = rng.uniform(-0.1, 0.1, (DIM, 4)).astype(np.float32)
        base = FusedRollout(plan_for(fm), w_in, leak=0.6, mode="fp32",
                            w_out=w_out, readout_every=4)
        spec = SpecializedRollout(plan_for(fm), w_in, leak=0.6, mode="fp32",
                                  w_out=w_out, readout_every=4,
                                  batch_tile_max=4)
        u = jnp.asarray(rng.standard_normal((8, 6, 4)), jnp.float32)
        ref = base(u, want_states=False, want_preds=True)
        got = spec(u, want_states=False, want_preds=True)
        assert ref.shape == got.shape == (2, 6, 4)
        assert (np.asarray(ref) == np.asarray(got)).all()


class TestSpecializedXla:
    @pytest.mark.parametrize("esn_mode", ["int8-csd", "int8-pn"])
    def test_folded_dense_matches_plane_exact(self, esn_mode):
        p = _params(_fm_for(esn_mode), esn_mode, w_out=True)
        base = ReservoirEngine(p, specialize=False)
        spec = ReservoirEngine(p)
        assert spec.xla_schedule == "int8-folded-dense"
        rng = np.random.default_rng(2)
        u = jnp.asarray(rng.standard_normal((5, 7, 4)), jnp.float32)
        z = jnp.zeros((5, DIM), jnp.float32)
        for want_states in (True, False):
            a, fa = base.run_segment(u, z, want_states=want_states)
            b, fb = spec.run_segment(u, z, want_states=want_states)
            assert (np.asarray(a) == np.asarray(b)).all()
            assert (np.asarray(fa) == np.asarray(fb)).all()

    def test_folded_culled_matches_plane_exact(self):
        fm = _fixed_matrix("csd", es=0.97, seed=4, dim=128, block=32)
        p = _params(fm, "int8-csd")
        # force the culled schedule regardless of block density
        base = ReservoirEngine(p, specialize=False)
        spec = ReservoirEngine(p, dense_dispatch_density=2.0)
        assert spec.xla_schedule == "int8-folded-culled"
        rng = np.random.default_rng(3)
        u = jnp.asarray(rng.standard_normal((6, 3, 4)), jnp.float32)
        a = base.rollout(u)
        b = spec.rollout(u)
        assert (np.asarray(a) == np.asarray(b)).all()

    def test_fp32_unchanged_by_specialization(self):
        p = _params(_fm_for("fp32"), "fp32")
        base = ReservoirEngine(p, specialize=False)
        spec = ReservoirEngine(p)
        rng = np.random.default_rng(4)
        u = jnp.asarray(rng.standard_normal((4, 5, 4)), jnp.float32)
        assert (np.asarray(base.rollout(u))
                == np.asarray(spec.rollout(u))).all()


class TestBoundedCaches:
    def test_plan_cache_counts_hits_and_misses(self):
        before = plan_cache_stats()
        fm = _fixed_matrix("csd", seed=11, dim=128, block=64)
        plan_for(fm)
        plan_for(fm)
        after = plan_cache_stats()
        assert after["misses"] == before["misses"] + 1
        assert after["hits"] >= before["hits"] + 1

    def test_engine_cache_is_bounded_lru(self):
        engine_cache_clear()
        engine_cache_stats(reset=True)
        fm = _fixed_matrix("csd", seed=12, dim=128, block=64)
        keep = []
        for i in range(ENGINE_CACHE_MAX + 4):
            p = _params(fm, "fp32", seed=i, w_out=False)
            keep.append(p)                    # keep params alive: evictions
            engine_for(p)                     # must come from the LRU bound
        s = engine_cache_stats()
        assert s["size"] <= ENGINE_CACHE_MAX
        assert s["evictions"] >= 4
        assert s["misses"] == ENGINE_CACHE_MAX + 4

    def test_engine_cache_hit_and_readout_invalidation(self):
        engine_cache_clear()
        engine_cache_stats(reset=True)
        fm = _fixed_matrix("csd", seed=13, dim=128, block=64)
        p = _params(fm, "fp32", w_out=False)
        e1 = engine_for(p)
        assert engine_for(p) is e1
        assert engine_cache_stats()["hits"] == 1
        # replacing the readout must invalidate the compiled engine
        p.w_out = jnp.zeros((128, 4), jnp.float32)
        e2 = engine_for(p)
        assert e2 is not e1 and e2.has_readout

    def test_lru_evicts_oldest_and_rebuilds_on_return(self):
        engine_cache_clear()
        engine_cache_stats(reset=True)
        fm = _fixed_matrix("csd", seed=14, dim=128, block=64)
        first = _params(fm, "fp32", seed=0, w_out=False)
        e_first = engine_for(first)
        for i in range(1, ENGINE_CACHE_MAX + 1):   # pushes `first` out
            engine_for(_params(fm, "fp32", seed=i, w_out=False))
        assert engine_cache_stats()["evictions"] >= 1
        e_again = engine_for(first)                 # miss: was evicted
        assert e_again is not e_first
        assert engine_cache_stats()["misses"] == ENGINE_CACHE_MAX + 2
