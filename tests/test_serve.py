"""Serving layer: engine dispatch, padding buckets, readout, telemetry."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.esn import (ESNConfig, fit_readout, init_esn, predict,
                            run_reservoir)
from repro.serve import (PaddingBucketer, ReservoirEngine, RolloutRequest,
                         ServeStats, engine_for)


def _params(mode="fp32", dim=96, leak=1.0, seed=1, block=32):
    cfg = ESNConfig(reservoir_dim=dim, element_sparsity=0.8, mode=mode,
                    leak=leak, seed=seed, block=block)
    return init_esn(cfg)


def _trained_params(mode="fp32", dim=96, seed=1, block=32, out=2):
    cfg = ESNConfig(reservoir_dim=dim, element_sparsity=0.8, mode=mode,
                    leak=0.7, seed=seed, block=block, output_dim=out)
    p = init_esn(cfg)
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.standard_normal((50, 1)), jnp.float32)
    states = run_reservoir(p, u, engine="scan")
    y = jnp.concatenate([u, jnp.roll(u, 1)], axis=-1)
    return fit_readout(p, states, y, lam=1e-2)


class TestPaddingBucketer:
    def test_pad_len_picks_next_bucket(self):
        b = PaddingBucketer(len_buckets=(16, 32, 64), batch_buckets=(1, 2, 4))
        assert b.pad_len(3) == 16
        assert b.pad_len(16) == 16
        assert b.pad_len(17) == 32
        # beyond the top bucket: round up to a multiple of it
        assert b.pad_len(100) == 128

    def test_pad_batch(self):
        b = PaddingBucketer(len_buckets=(16,), batch_buckets=(1, 2, 4, 8))
        assert b.pad_batch(1) == 1
        assert b.pad_batch(3) == 4
        assert b.pad_batch(8) == 8

    def test_pad_batch_rounds_up_past_top_bucket(self):
        """Regression: beyond the top bucket the batch must round *up* to
        a multiple of it — never hand back a buffer smaller than the
        batch."""
        b = PaddingBucketer(len_buckets=(16,), batch_buckets=(1, 2, 4, 8))
        assert b.pad_batch(9) == 16
        assert b.pad_batch(16) == 16
        assert b.pad_batch(17) == 24
        assert all(b.pad_batch(n) >= n for n in range(1, 40))

    def test_group_shapes_and_padding(self):
        b = PaddingBucketer(len_buckets=(8, 16), batch_buckets=(1, 2, 4))
        rng = np.random.default_rng(0)
        reqs = [RolloutRequest(uid=i,
                               inputs=rng.standard_normal((t, 3)).astype(
                                   np.float32))
                for i, t in enumerate([5, 7, 12, 8, 3])]
        mbs = b.group(reqs)
        # lengths {5,7,8,3} -> bucket 8 (4 reqs, batch 4); {12} -> bucket 16
        assert sorted(mb.inputs.shape for mb in mbs) == [(1, 16, 3),
                                                         (4, 8, 3)]
        assert sum(mb.real_steps for mb in mbs) == 5 + 7 + 12 + 8 + 3
        assert sum(len(mb.requests) for mb in mbs) == 5
        # padded region is zeros; real region is the request data
        big = next(mb for mb in mbs if mb.inputs.shape[0] == 4)
        for j, req in enumerate(big.requests):
            np.testing.assert_array_equal(big.inputs[j, :req.length],
                                          req.inputs)
            assert not big.inputs[j, req.length:].any()

    def test_chunking_respects_max_batch(self):
        b = PaddingBucketer(len_buckets=(8,), batch_buckets=(1, 2))
        reqs = [RolloutRequest(uid=i, inputs=np.ones((4, 1), np.float32))
                for i in range(5)]
        mbs = b.group(reqs)
        assert [mb.inputs.shape[0] for mb in mbs] == [2, 2, 1]


class TestServeStats:
    def test_counters_and_efficiency(self):
        s = ServeStats()
        s.record_call(batch=4, steps=8, seconds=0.5, real_steps=20)
        s.record_call(batch=2, steps=8, seconds=0.5)
        assert s.calls == 2 and s.sequences == 6
        assert s.steps_padded == 48 and s.steps_real == 36
        assert s.padding_efficiency == pytest.approx(36 / 48)
        assert s.steps_per_sec == pytest.approx(48.0)
        assert s.goodput_steps_per_sec == pytest.approx(36.0)
        assert "steps/s" in s.render()

    def test_latency_ewma_tracks(self):
        s = ServeStats()
        s.record_call(batch=1, steps=1, seconds=1.0)
        assert s.latency_ewma_s == pytest.approx(1.0)
        s.record_call(batch=1, steps=1, seconds=0.0)
        assert 0.0 < s.latency_ewma_s < 1.0


class TestEngineParity:
    @pytest.mark.parametrize("mode", ["fp32", "int8-csd"])
    @pytest.mark.parametrize("leak", [1.0, 0.4])
    def test_xla_engine_matches_scan(self, mode, leak):
        p = _params(mode=mode, leak=leak)
        rng = np.random.default_rng(0)
        u = jnp.asarray(rng.standard_normal((4, 30, 1)), jnp.float32)
        want = np.asarray(run_reservoir(p, u, engine="scan"))
        got = np.asarray(ReservoirEngine(p).rollout(u))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_pallas_engine_matches_scan(self):
        p = _params(mode="int8-csd", leak=0.4)
        rng = np.random.default_rng(1)
        u = jnp.asarray(rng.standard_normal((2, 12, 1)), jnp.float32)
        want = np.asarray(run_reservoir(p, u, engine="scan"))
        got = np.asarray(ReservoirEngine(p, backend="pallas").rollout(u))
        np.testing.assert_array_equal(got, want)  # int8: bit-exact

    def test_single_sequence_shape_contract(self):
        p = _params()
        u = jnp.ones((20, 1), jnp.float32)
        got = ReservoirEngine(p).rollout(u)
        assert got.shape == (20, 96)

    def test_x0_vector_broadcasts(self):
        p = _params()
        rng = np.random.default_rng(2)
        u = jnp.asarray(rng.standard_normal((3, 10, 1)), jnp.float32)
        x0 = jnp.asarray(rng.uniform(-0.3, 0.3, (96,)), jnp.float32)
        want = np.asarray(run_reservoir(p, u, x0=x0, engine="scan"))
        got = np.asarray(ReservoirEngine(p).rollout(u, x0=x0))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_composes_under_jit_and_grad(self):
        """Engine dispatch must stay traceable (and not poison the
        per-params engine cache with tracers when first built under a
        trace)."""
        import jax
        p = _params()
        u = jnp.ones((2, 8, 1), jnp.float32)
        want = np.asarray(run_reservoir(p, u, engine="scan"))
        got = np.asarray(jax.jit(lambda x: run_reservoir(p, x))(u))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
        # eager call after the traced one: cached engine still usable
        again = np.asarray(run_reservoir(p, u))
        np.testing.assert_allclose(again, want, rtol=1e-4, atol=1e-5)
        g = jax.grad(lambda x: run_reservoir(p, x).sum())(u)
        assert np.isfinite(np.asarray(g)).all()

    def test_run_reservoir_default_dispatches_to_engine(self):
        p = _params()
        u = jnp.ones((3, 10, 1), jnp.float32)
        got = np.asarray(run_reservoir(p, u))
        want = np.asarray(run_reservoir(p, u, engine="scan"))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
        # the dispatching engine is cached on the params object
        assert engine_for(p) is engine_for(p)
        assert engine_for(p).stats.calls >= 1


class TestServeRequests:
    def test_ragged_requests_roundtrip(self):
        p = _params(dim=64, block=32, seed=3)
        eng = ReservoirEngine(p)
        rng = np.random.default_rng(3)
        reqs = [RolloutRequest(
                    uid=f"r{i}",
                    inputs=rng.standard_normal((t, 1)).astype(np.float32))
                for i, t in enumerate([5, 17, 17, 30, 9])]
        res = eng.serve(reqs, bucketer=PaddingBucketer(
            len_buckets=(8, 16, 32), batch_buckets=(1, 2, 4)))
        assert set(res) == {f"r{i}" for i in range(5)}
        for r in reqs:
            want = np.asarray(run_reservoir(p, jnp.asarray(r.inputs),
                                            engine="scan"))
            got = np.asarray(res[r.uid])
            assert got.shape == (r.length, 64)
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_serve_returns_predictions_with_trained_readout(self):
        """Acceptance: serve() answers with W_out applied in the fused
        epilogue, matching predict() over the scan-baseline states."""
        p = _trained_params(dim=64, block=32, seed=5)
        eng = ReservoirEngine(p)
        rng = np.random.default_rng(5)
        reqs = [RolloutRequest(
                    uid=i,
                    inputs=rng.standard_normal((t, 1)).astype(np.float32))
                for i, t in enumerate([6, 14, 9])]
        res = eng.serve(reqs, bucketer=PaddingBucketer(
            len_buckets=(8, 16), batch_buckets=(1, 2, 4)))
        for r in reqs:
            states = run_reservoir(p, jnp.asarray(r.inputs), engine="scan")
            want = np.asarray(predict(p, states))
            got = np.asarray(res[r.uid])
            assert got.shape == (r.length, 2)
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_serve_return_states_preserves_old_contract(self):
        p = _trained_params(dim=64, block=32, seed=6)
        eng = ReservoirEngine(p)
        req = RolloutRequest(uid="a", inputs=np.ones((7, 1), np.float32))
        with pytest.warns(DeprecationWarning, match="want_states"):
            res = eng.serve([req], return_states=True)
        assert res["a"].shape == (7, 64)
        want = np.asarray(run_reservoir(p, jnp.asarray(req.inputs),
                                        engine="scan"))
        np.testing.assert_allclose(np.asarray(res["a"]), want,
                                   rtol=1e-4, atol=1e-5)

    def test_serve_without_readout_falls_back_to_states(self):
        p = _params(dim=64, block=32)
        eng = ReservoirEngine(p)
        res = eng.serve([RolloutRequest(uid=0,
                                        inputs=np.ones((5, 1), np.float32))])
        assert res[0].shape == (5, 64)
        with pytest.raises(ValueError, match="readout not trained"):
            eng.predictions(jnp.ones((1, 5, 1), jnp.float32))

    @pytest.mark.parametrize("backend", ["xla", "pallas"])
    def test_pallas_and_xla_serve_predictions_agree(self, backend):
        p = _trained_params(mode="int8-csd", dim=64, block=32, seed=7)
        eng = ReservoirEngine(p, backend=backend)
        rng = np.random.default_rng(7)
        u = jnp.asarray(rng.standard_normal((2, 8, 1)), jnp.float32)
        got = np.asarray(eng.predictions(u))
        want = np.asarray(predict(p, run_reservoir(p, u, engine="scan")))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


    def test_serve_honors_request_x0(self):
        """Regression: serve() used to drop initial state — a request's
        x0 must seed its row of the padded batch."""
        p = _params(dim=64, block=32, seed=11)
        eng = ReservoirEngine(p)
        rng = np.random.default_rng(11)
        u0 = rng.standard_normal((8, 1)).astype(np.float32)
        u1 = rng.standard_normal((8, 1)).astype(np.float32)
        x0 = rng.uniform(-0.4, 0.4, (64,)).astype(np.float32)
        bucketer = PaddingBucketer(len_buckets=(8,), batch_buckets=(2,))
        res = eng.serve([RolloutRequest(uid=0, inputs=u0),
                         RolloutRequest(uid=1, inputs=u1, x0=x0)],
                        bucketer=bucketer)
        # bit-identical to the same batched rollout with the x0 row seeded
        x0b = np.zeros((2, 64), np.float32)
        x0b[1] = x0
        want = np.asarray(eng.rollout(jnp.asarray(np.stack([u0, u1])),
                                      x0=jnp.asarray(x0b)))
        np.testing.assert_array_equal(np.asarray(res[0]), want[0])
        np.testing.assert_array_equal(np.asarray(res[1]), want[1])
        # requests without x0 still start from zero
        plain = eng.serve([RolloutRequest(uid=0, inputs=u0)],
                          bucketer=PaddingBucketer(len_buckets=(8,),
                                                   batch_buckets=(1,)))
        np.testing.assert_allclose(
            np.asarray(plain[0]),
            np.asarray(eng.rollout(jnp.asarray(u0))),
            rtol=1e-5, atol=1e-6)

    def test_padding_overhead_lands_in_stats(self):
        p = _params(dim=64, block=32)
        eng = ReservoirEngine(p)
        reqs = [RolloutRequest(uid=0,
                               inputs=np.ones((5, 1), np.float32))]
        eng.serve(reqs, bucketer=PaddingBucketer(len_buckets=(16,),
                                                 batch_buckets=(2,)))
        assert eng.stats.steps_real == 5
        assert eng.stats.steps_padded == 32
        assert eng.stats.padding_efficiency == pytest.approx(5 / 32)


class TestEngineCache:
    def test_engine_cache_reused_for_same_readout(self):
        p = _trained_params(dim=64, block=32, seed=8)
        assert engine_for(p) is engine_for(p)

    def test_engine_cache_invalidated_when_readout_replaced(self):
        """Satellite: engine_for must not serve a stale compiled rollout
        after the readout is swapped on the same params object."""
        p = _trained_params(dim=64, block=32, seed=9)
        eng_old = engine_for(p)
        u = jnp.asarray(np.random.default_rng(9).standard_normal((2, 6, 1)),
                        jnp.float32)
        old = np.asarray(eng_old.predictions(u))
        p.w_out = p.w_out * 2.0              # in-place readout replacement
        eng_new = engine_for(p)
        assert eng_new is not eng_old
        got = np.asarray(eng_new.predictions(u))
        np.testing.assert_allclose(got, 2.0 * old, rtol=1e-5, atol=1e-6)

    def test_fit_readout_produces_freshly_keyed_engine(self):
        p = _params(dim=64, block=32, seed=10)
        eng0 = engine_for(p)
        rng = np.random.default_rng(10)
        u = jnp.asarray(rng.standard_normal((30, 1)), jnp.float32)
        states = run_reservoir(p, u, engine="scan")
        p2 = fit_readout(p, states, jnp.concatenate([u, u], axis=-1),
                         lam=1e-2)
        eng1 = engine_for(p2)
        assert eng1 is not eng0
        assert eng1._w_out is p2.w_out
