"""Shared test configuration.

Provides a deterministic fallback implementation of the small slice of the
``hypothesis`` API these tests use (``given``, ``settings``,
``strategies.integers/floats/sampled_from``) when the real package is not
installed.  CI installs real hypothesis from requirements.txt, so the
fallback only activates in minimal environments — it draws examples from a
seeded ``numpy`` generator, keeping the property tests meaningful and
reproducible rather than silently skipped.
"""

from __future__ import annotations

import functools
import sys
import types
import zlib

import numpy as np


def _install_hypothesis_fallback() -> None:
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_from(self, rng):
            return self._draw(rng)

    st = types.ModuleType("hypothesis.strategies")
    st.integers = lambda lo, hi: _Strategy(
        lambda rng: int(rng.integers(lo, hi + 1)))
    st.floats = lambda lo, hi: _Strategy(
        lambda rng: float(rng.uniform(lo, hi)))
    st.sampled_from = lambda seq: _Strategy(
        lambda rng: seq[int(rng.integers(0, len(seq)))])
    st.booleans = lambda: _Strategy(lambda rng: bool(rng.integers(0, 2)))

    def settings(max_examples: int = 20, deadline=None, **_kw):
        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            # NB: no functools.wraps — it would expose the wrapped signature
            # (including the drawn parameters) and pytest would try to
            # resolve those as fixtures.
            def wrapper(*args, **kwargs):
                n = getattr(fn, "_fallback_max_examples", 20)
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for _ in range(n):
                    drawn = [s.example_from(rng) for s in strats]
                    fn(*args, *drawn, **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    mod.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - depends on environment
    _install_hypothesis_fallback()
