"""Backpressure + admission control: bounded queues, deadline shedding,
tenant fairness, and the two deadline bug regressions.

Admission contracts from the ISSUE:

* a refused submission gets an explicit ``RolloutResult`` with
  ``status="rejected"`` (reason + retry-after hint in ``timings``) —
  never a silent drop, never an unbounded queue;
* shedding keeps the engine's latency promise: a request the queue-delay
  estimate already dooms is refused at the door instead of timing out
  later;
* admitted requests are untouched — their outputs stay bit-identical to
  an unpoliced run;
* (regression) the one-shot engine path records
  ``timings["deadline_ignored"]`` and warns once instead of silently
  swallowing ``spec.deadline``;
* (regression) a queued request behind a full pool is dropped the step
  its deadline passes, not when a slot finally frees.
"""

import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.esn import ESNConfig, fit_readout, init_esn, run_reservoir
from repro.serve import (AsyncReservoirServer, BoundedQueuePolicy,
                         CompositePolicy, DeadlineShedPolicy, ModelRegistry,
                         ReservoirEngine, Rejection, ServeStats, SubmitSpec,
                         TenantFairnessPolicy, default_policy)
from repro.serve.admission import (estimate_chunk_seconds,
                                   estimate_queue_delay)


def _params(mode="fp32", dim=96, leak=0.7, seed=1, block=32):
    cfg = ESNConfig(reservoir_dim=dim, element_sparsity=0.8, mode=mode,
                    leak=leak, seed=seed, block=block, output_dim=2)
    p = init_esn(cfg)
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.standard_normal((50, 1)), jnp.float32)
    states = run_reservoir(p, u, engine="scan")
    y = jnp.concatenate([u, jnp.roll(u, 1)], axis=-1)
    return fit_readout(p, states, y, lam=1e-2)


def _requests(lengths, seed=0, in_dim=1):
    rng = np.random.default_rng(seed)
    return [SubmitSpec(rng.standard_normal((t, in_dim)).astype(np.float32),
                       uid=i)
            for i, t in enumerate(lengths)]


def _server(p, **kw):
    eng = ReservoirEngine(p, backend="xla", stats=ServeStats())
    kw.setdefault("chunk_time", 1.0)        # deterministic virtual clock
    return eng, AsyncReservoirServer(eng, stats=ServeStats(), **kw)


# -- fakes for pure policy-math units ----------------------------------------

class _FakeQ:
    def __init__(self, model, length=8):
        self.model = model
        self.length = length
        self.deadline = None
        self.arrival_time = 0.0


class _FakeServer:
    def __init__(self, seated, queued, n_slots):
        class B:
            pass
        self.batcher = B()
        self.batcher.n_slots = n_slots
        self.batcher.chunk_steps = 4
        self.batcher._slots = list(seated) + [None] * (n_slots - len(seated))
        self.batcher._pos = [0] * n_slots
        self._queue = [(0.0, i, q) for i, q in enumerate(queued)]
        self.chunk_time = 1.0

    @property
    def pending(self):
        return len(self._queue)


class TestEstimators:
    def test_chunk_time_wins(self):
        srv = _FakeServer([], [], n_slots=4)
        assert estimate_chunk_seconds(srv) == 1.0

    def test_cost_model_used_before_any_measurement(self):
        # chunk_time=None and no chunks run yet: the PR-7 cost model's
        # analytic prediction kicks in (positive, finite) so admission
        # is cost-aware from the first submit
        eng, srv = _server(_params(), n_slots=2, chunk_steps=8,
                           chunk_time=None)
        assert srv.stats.chunks == 0
        est = estimate_chunk_seconds(srv)
        assert 0 < est < float("inf")

    def test_queue_delay_zero_when_idle(self):
        srv = _FakeServer([], [], n_slots=4)
        assert estimate_queue_delay(srv) == 0.0

    def test_queue_delay_grows_with_backlog(self):
        a = _FakeServer([], [_FakeQ(None, 8)] * 2, n_slots=2)
        b = _FakeServer([], [_FakeQ(None, 8)] * 8, n_slots=2)
        assert estimate_queue_delay(b) > estimate_queue_delay(a) > 0


class TestBoundedQueuePolicy:
    def test_rejects_past_depth_with_explicit_result(self):
        p = _params()
        eng, srv = _server(p, n_slots=1, chunk_steps=4,
                           admission=BoundedQueuePolicy(max_depth=1))
        specs = _requests([8, 8, 8, 8], seed=3)
        outcomes = [srv.submit(s, arrival_time=0.0) for s in specs]
        rejected = [r for r in outcomes if hasattr(r, "status")
                    and r.rejected]
        assert len(rejected) == 3 and srv.pending == 1
        for r in rejected:
            assert r.status == "rejected" and r.output is None
            assert r.timings["reason"] == "queue_full"
            assert r.timings["retry_after_s"] > 0
        assert srv.stats.rejected == 3 and srv.stats.shed == 0
        # rejections never enter the queue accounting
        assert srv.stats.enqueued == 1 and srv.stats.timed_out == 0

    def test_admitted_requests_bit_identical_to_unpoliced(self):
        p = _params()
        specs = _requests([8, 8, 8], seed=4)
        _, ref_srv = _server(p, n_slots=1, chunk_steps=4)
        for s in specs:
            ref_srv.submit(s, arrival_time=0.0)
        ref = ref_srv.run()
        _, srv = _server(p, n_slots=1, chunk_steps=4,
                         admission=BoundedQueuePolicy(max_depth=64))
        for s in specs:
            srv.submit(s, arrival_time=0.0)
        res = srv.run()
        assert len(res) == 3
        for uid in ref:
            np.testing.assert_array_equal(np.asarray(res[uid].output),
                                          np.asarray(ref[uid].output))


class TestDeadlineShedPolicy:
    def test_sheds_unmeetable_deadline_at_the_door(self):
        p = _params()
        eng, srv = _server(p, n_slots=1, chunk_steps=4,
                           admission=DeadlineShedPolicy())
        # 32 steps of backlog behind a 1-slot x 4-step pool: 8 chunks
        # (8 virtual seconds) before a new arrival is guaranteed a seat
        srv.submit(SubmitSpec(np.ones((32, 1), np.float32), uid="long"),
                   arrival_time=0.0)
        doomed = srv.submit(
            SubmitSpec(np.ones((4, 1), np.float32), uid="tight",
                       deadline=2.0), arrival_time=0.0)
        assert doomed.rejected
        assert doomed.timings["reason"] == "deadline_unmeetable"
        assert doomed.timings["retry_after_s"] > 0
        assert srv.stats.shed == 1 and srv.stats.rejected == 0
        ok = srv.submit(SubmitSpec(np.ones((4, 1), np.float32), uid="lax"),
                        arrival_time=0.0)
        assert not hasattr(ok, "status") or not getattr(ok, "rejected", False)
        res = srv.run()
        assert "tight" not in res or res["tight"].rejected
        assert srv.stats.timed_out == 0     # shed at the door, not later


class TestTenantFairnessPolicy:
    def test_never_fires_below_contention(self):
        pol = TenantFairnessPolicy()
        srv = _FakeServer([_FakeQ("a")], [], n_slots=4)
        assert pol.admit(srv, _FakeQ("a")) is None

    def test_equal_weights_split_the_pool(self):
        pol = TenantFairnessPolicy()
        seated = [_FakeQ("a")] * 3 + [_FakeQ("b")] * 1
        srv = _FakeServer(seated, [_FakeQ("a"), _FakeQ("a")], n_slots=4)
        # in_system=8 incl. candidate, equal split cap=4: "a" holds 5
        verdict = pol.admit(srv, _FakeQ("a"))
        assert isinstance(verdict, Rejection)
        assert verdict.reason == "tenant_over_share" and not verdict.shed
        # the underrepresented tenant still gets in
        assert pol.admit(srv, _FakeQ("b")) is None

    def test_weights_tilt_the_split(self):
        seated = [_FakeQ("a")] * 3 + [_FakeQ("b")] * 2
        srv = _FakeServer(seated, [], n_slots=4)
        equal = TenantFairnessPolicy()
        assert equal.admit(srv, _FakeQ("a")) is not None
        tilted = TenantFairnessPolicy(weights={"a": 3.0, "b": 1.0})
        assert tilted.admit(srv, _FakeQ("a")) is None

    def test_multi_tenant_server_integration(self):
        reg = ModelRegistry(backend="xla")
        reg.register("a", _params(seed=1))
        reg.register("b", _params(seed=2))
        eng = reg.engine("a")
        eng.stats = ServeStats()
        srv = AsyncReservoirServer(eng, n_slots=2, chunk_steps=4,
                                   chunk_time=1.0, registry=reg,
                                   stats=ServeStats(),
                                   admission=TenantFairnessPolicy())
        def spec(model, uid):
            return SubmitSpec(np.ones((8, 1), np.float32), model=model,
                              uid=uid)
        for i in range(4):
            assert not getattr(srv.submit(spec("a", f"a{i}"),
                                          arrival_time=0.0),
                               "rejected", False)
        # under contention the second tenant still gets in ...
        assert not getattr(srv.submit(spec("b", "b0"), arrival_time=0.0),
                           "rejected", False)
        # ... and the hog is the one refused
        hog = srv.submit(spec("a", "a4"), arrival_time=0.0)
        assert hog.rejected and hog.timings["reason"] == "tenant_over_share"
        res = srv.run()
        assert srv.stats.completed == 5 and len(res) == 6  # 5 ok + 1 reject


class TestCompositeAndDefault:
    def test_first_rejection_wins(self):
        always = BoundedQueuePolicy(max_depth=0)
        srv = _FakeServer([], [_FakeQ(None)], n_slots=2)
        verdict = CompositePolicy(DeadlineShedPolicy(), always).admit(
            srv, _FakeQ(None))
        assert verdict is not None and verdict.reason == "queue_full"

    def test_default_policy_shape(self):
        pol = default_policy(max_depth=7, weights={"a": 2.0})
        kinds = [type(p) for p in pol.policies]
        assert kinds == [BoundedQueuePolicy, DeadlineShedPolicy,
                         TenantFairnessPolicy]
        assert pol.policies[0].max_depth == 7
        assert pol.policies[2].weights == {"a": 2.0}


class TestEngineDeadlineIgnoredRegression:
    """Satellite bugfix 1: the one-shot engine path used to swallow
    ``spec.deadline`` silently."""

    def test_timings_record_and_warn_once(self):
        import repro.serve.engine as engine_mod
        p = _params()
        eng = ReservoirEngine(p, backend="xla")
        u = np.ones((8, 1), np.float32)
        engine_mod._WARNED_DEADLINE = False
        with pytest.warns(UserWarning, match="deadline"):
            res = eng.submit(SubmitSpec(u, deadline=5.0))
        assert res.timings["deadline_ignored"] is True
        # warn-once: the second deadline-bearing submit stays silent but
        # still records the timings flag
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            res2 = eng.submit(SubmitSpec(u, deadline=5.0))
        assert res2.timings["deadline_ignored"] is True
        # no flag at all when no deadline was asked for
        res3 = eng.submit(SubmitSpec(u))
        assert "deadline_ignored" not in res3.timings


class TestDeadlineDropOnClockAdvanceRegression:
    """Satellite bugfix 2: the admission sweep only examines the queue
    head while slots are free, so a request waiting behind a full pool
    used to linger past its deadline until a slot freed."""

    def test_expired_request_dropped_while_pool_still_full(self):
        p = _params()
        _, srv = _server(p, n_slots=1, chunk_steps=2)
        # A occupies the only slot for 4 chunks (t=4); B's deadline
        # passes at t=2 while A is still running
        srv.submit(SubmitSpec(np.ones((8, 1), np.float32), uid="A"),
                   arrival_time=0.0)
        srv.submit(SubmitSpec(np.ones((2, 1), np.float32), uid="B",
                              deadline=2.0), arrival_time=0.0)
        srv.step()                            # seats A, now=1.0
        srv.step()                            # now=2.0 (== deadline: holds)
        assert srv.stats.timed_out == 0 and srv.pending == 1
        srv.step()                            # now=3.0 > deadline
        # dropped NOW, with the pool still full — not at slot-free time
        assert srv.stats.timed_out == 1
        assert srv.pending == 0 and srv.batcher.live == 1
        res = srv.run()
        assert "B" not in res and srv.stats.completed == 1
