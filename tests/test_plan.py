"""ExecutionPlan compiler: one lowering shared by every kernel family.

The contract mirrors the paper's synthesis step: the FixedMatrix is
lowered exactly once (``plan_for`` caches per instance), every consumer —
bitplane gemv, BCSR matmul, fused rollout, serve engine — builds from the
same plan, and with a power-of-two dequant scale all three kernel
families produce *bit-identical* integer results.  On top of the shared
plan: fused-readout parity and banded-vs-unbanded state equality,
including the dim-2048 fp32 acceptance point.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.esn import (ESNConfig, fit_readout, init_esn, predict,
                            run_readout, run_reservoir)
from repro.core.sparse import FixedMatrix, random_sparse_matrix
from repro.kernels.bcsr_matmul.ops import BcsrMatmul
from repro.kernels.bitplane_gemv.ops import BitplaneGemv
from repro.kernels.reservoir_rollout.ops import FusedRollout
from repro.kernels.reservoir_rollout.ref import rollout_fp32_ref
from repro.plan import DEFAULT_VMEM_BUDGET, ExecutionPlan, plan_for
from repro.serve.engine import ReservoirEngine


def _unit_scale_matrix(dim=256, block=64, seed=0):
    """Integer matrix with amax == qmax so scale == 1.0 exactly: float and
    integer kernel paths then agree bit for bit (products stay < 2**24).
    Row blocks past the first half are zeroed so block culling is real."""
    rng = np.random.default_rng(seed)
    q = rng.integers(-127, 128, size=(dim, dim)).astype(np.float64)
    q[rng.random((dim, dim)) < 0.9] = 0
    q[dim // 2:, :] = 0                      # structural zeros -> culled blocks
    q[0, 0] = 127                            # pins amax -> scale = 1.0
    fm = FixedMatrix.compile(q, weight_bits=8, mode="csd", block=block,
                             rng=rng)
    assert fm.scale == 1.0
    return fm


class TestPlanCompile:
    def test_plan_cached_per_matrix(self):
        fm = _unit_scale_matrix()
        plan = plan_for(fm)
        assert plan_for(fm) is plan
        assert fm.plan() is plan
        assert isinstance(plan, ExecutionPlan)
        # layouts are cached per (mode, budget) too
        assert plan.rollout_layout("fp32") is plan.rollout_layout("fp32")

    def test_stats_report_real_culling(self):
        fm = _unit_scale_matrix()
        s = plan_for(fm).stats
        assert s.blocks_nnz == fm.blocks.n_blocks_nnz
        assert s.fp32_terms_culled > 0           # zeroed row blocks
        assert s.int8_terms_culled > 0           # plane-level culling on top
        assert s.int8_terms_kept <= s.width * s.blocks_nnz
        assert s.ones == fm.ones
        d = s.as_dict()
        assert d["fp32_terms_culled"] == s.fp32_terms_culled
        assert 0.0 < d["block_density"] < 1.0

    def test_fpga_cost_uses_exact_ones(self):
        fm = _unit_scale_matrix()
        plan = plan_for(fm)
        dp = plan.fpga_cost()
        assert dp.ones == fm.ones
        assert dp.cycles == fm.fpga_cost().cycles      # Eq. 5
        assert "culled" in plan.describe()

    def test_col_terms_cull_zero_blocks(self):
        fm = _unit_scale_matrix()
        plan = plan_for(fm)
        for mode in ("fp32", "int8"):
            rows_used = {t[-1] for terms in plan.col_terms(mode)
                         for t in terms}
            # only the populated top half of the row blocks appears
            assert rows_used <= set(range(plan.nbr // 2))


class TestCrossKernelEquivalence:
    """All three kernel families, one shared plan, bit-identical results."""

    def test_bit_identical_across_families(self):
        fm = _unit_scale_matrix()
        plan = plan_for(fm)
        rng = np.random.default_rng(1)
        xq = rng.integers(-4, 5, size=(3, 256)).astype(np.int32)

        # family 1: digit-plane gemv, exact integer
        y_int = np.asarray(BitplaneGemv(plan)(jnp.asarray(xq)))
        np.testing.assert_array_equal(
            y_int, xq @ np.asarray(fm.q, np.int64).astype(np.int32))

        # family 2: BCSR float matmul — scale 1.0 keeps it exact integers
        y_bcsr = np.asarray(BcsrMatmul(plan)(jnp.asarray(xq, jnp.float32)))
        np.testing.assert_array_equal(y_bcsr, y_int.astype(np.float32))

        # family 3: fused rollout, int8 mode, one step with w_in = 0 and
        # x0 chosen so the per-step requantization recovers xq exactly.
        w_in = np.zeros((1, 256), np.float32)
        fr = FusedRollout(plan, w_in, leak=1.0, mode="int8")
        x0 = jnp.asarray(xq, jnp.float32) / fr.smax
        u = jnp.zeros((1, 3, 1), jnp.float32)
        got = np.asarray(fr(u, x0))[0]
        # expectation via jnp so the tanh implementation matches bit for bit
        want = np.asarray(jnp.tanh(jnp.asarray(y_int, jnp.float32)
                                   * np.float32(fr.recur_scale)))
        np.testing.assert_array_equal(got, want)

    def test_consumers_share_the_same_plan_object(self):
        fm = _unit_scale_matrix(dim=128, block=64, seed=2)
        plan = plan_for(fm)
        assert BitplaneGemv(fm).plan is plan
        assert BcsrMatmul(fm).layout is plan.bcsr
        assert FusedRollout(fm, np.zeros((1, 128), np.float32)).plan is plan

    def test_fp32_rollout_matches_blocksparse_reference(self):
        rng = np.random.default_rng(3)
        w = random_sparse_matrix(192, 192, 0.9, rng) * 0.05
        w[96:, :] = 0.0                       # culled blocks stay in play
        fm = FixedMatrix.compile(w, weight_bits=8, mode="csd", block=64,
                                 rng=rng)
        w_in = rng.uniform(-0.5, 0.5, (1, 192)).astype(np.float32)
        fr = FusedRollout(plan_for(fm), w_in, leak=0.4, mode="fp32")
        u = jnp.asarray(rng.standard_normal((5, 2, 1)), jnp.float32)
        got = np.asarray(fr(u))
        ref = np.asarray(rollout_fp32_ref(
            u, jnp.asarray(fm.dense_f32()), jnp.asarray(w_in),
            jnp.zeros((2, 192), jnp.float32), leak=0.4))
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)


class TestBandedRollout:
    def _banded(self, dim=256, block=64, seed=1, budget_tiles=6):
        cfg = ESNConfig(reservoir_dim=dim, element_sparsity=0.8, leak=0.5,
                        seed=seed, block=block)
        p = init_esn(cfg)
        budget = budget_tiles * block * block * 4
        return p, budget

    def test_partition_respects_budget(self):
        p, budget = self._banded()
        layout = plan_for(p.w).rollout_layout("fp32", vmem_budget=budget)
        assert layout.n_bands > 1
        assert layout.band_data_bytes <= budget
        assert all(b.data_bytes <= budget for b in layout.bands)
        # bands tile the output column blocks contiguously and completely
        edges = [(b.col_lo, b.col_hi) for b in layout.bands]
        assert edges[0][0] == 0 and edges[-1][1] == plan_for(p.w).nbc
        assert all(a[1] == b[0] for a, b in zip(edges, edges[1:]))

    @pytest.mark.parametrize("mode,esn_mode", [("fp32", "fp32"),
                                               ("int8", "int8-csd")])
    def test_banded_bitwise_equals_unbanded(self, mode, esn_mode):
        cfg = ESNConfig(reservoir_dim=256, element_sparsity=0.8, leak=0.5,
                        mode=esn_mode, seed=4, block=64)
        p = init_esn(cfg)
        plan = plan_for(p.w)
        # int8 columns carry up to width x row-block plane tiles, so the
        # budget floor (one column per band) is higher than in fp32
        budget = 6 * 64 * 64 * 4 if mode == "fp32" else 40 * 64 * 64
        fr_un = FusedRollout(plan, np.asarray(p.w_in), leak=0.5, mode=mode,
                             vmem_budget=None)
        fr_b = FusedRollout(plan, np.asarray(p.w_in), leak=0.5, mode=mode,
                            vmem_budget=budget)
        assert fr_un.n_bands == 1 and fr_b.n_bands > 1
        rng = np.random.default_rng(0)
        u = jnp.asarray(rng.standard_normal((4, 2, 1)), jnp.float32)
        np.testing.assert_array_equal(np.asarray(fr_un(u)),
                                      np.asarray(fr_b(u)))

    def test_budget_smaller_than_one_column_raises(self):
        p, _ = self._banded()
        with pytest.raises(ValueError, match="vmem_budget"):
            plan_for(p.w).rollout_layout("fp32", vmem_budget=1024)

    def test_dim_2048_fp32_fits_budget_and_matches_reference(self):
        """Acceptance: dim-2048 fp32 compiles banded under a 2 MiB tile
        budget (16 MiB unbanded would overflow VMEM) and matches the
        unbanded jnp reference."""
        rng = np.random.default_rng(0)
        w = random_sparse_matrix(2048, 2048, 0.9, rng) * 0.05
        w[1024:, :] = 0.0                      # structured zeros at scale
        fm = FixedMatrix.compile(w, weight_bits=8, mode="csd", block=128,
                                 rng=rng)
        budget = 2 * 2**20
        plan = plan_for(fm)
        layout = plan.rollout_layout("fp32", vmem_budget=budget)
        assert layout.n_bands > 1
        assert layout.band_data_bytes <= budget
        assert all(b.data_bytes <= budget for b in layout.bands)
        w_in = rng.uniform(-0.5, 0.5, (1, 2048)).astype(np.float32)
        fr = FusedRollout(plan, w_in, leak=0.5, mode="fp32",
                          vmem_budget=budget)
        u = jnp.asarray(rng.standard_normal((2, 2, 1)), jnp.float32)
        got = np.asarray(fr(u))
        ref = np.asarray(rollout_fp32_ref(
            u, jnp.asarray(fm.dense_f32()), jnp.asarray(w_in),
            jnp.zeros((2, 2048), jnp.float32), leak=0.5))
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)


class TestFusedReadout:
    def _trained(self, mode="fp32", dim=128, block=64, seed=5):
        """ESN with a trained readout; targets are a smooth function of the
        input so the ridge solution keeps moderate weights (an overfit
        readout with huge weights would amplify float accumulation noise
        past any meaningful parity tolerance)."""
        cfg = ESNConfig(reservoir_dim=dim, element_sparsity=0.8, mode=mode,
                        leak=0.6, seed=seed, block=block, output_dim=2)
        p = init_esn(cfg)
        rng = np.random.default_rng(seed)
        u = jnp.asarray(rng.standard_normal((40, 1)), jnp.float32)
        states = run_reservoir(p, u, engine="scan")
        y = jnp.concatenate([u, jnp.roll(u, 1)], axis=-1)
        return fit_readout(p, states, y, lam=1e-2), u

    def test_pallas_epilogue_matches_states_then_matmul(self):
        p, _ = self._trained()
        fr = FusedRollout(plan_for(p.w), np.asarray(p.w_in), leak=0.6,
                          mode="fp32", w_out=np.asarray(p.w_out))
        rng = np.random.default_rng(0)
        u = jnp.asarray(rng.standard_normal((6, 3, 1)), jnp.float32)
        states, preds = fr(u, want_states=True, want_preds=True)
        want = np.asarray(states) @ np.asarray(p.w_out)
        np.testing.assert_allclose(np.asarray(preds), want,
                                   rtol=1e-5, atol=1e-6)
        # prediction-only launch (no states materialized) is identical
        only = fr(u, want_states=False, want_preds=True)
        np.testing.assert_array_equal(np.asarray(only), np.asarray(preds))

    def test_readout_every_k(self):
        p, _ = self._trained(seed=6)
        fr = FusedRollout(plan_for(p.w), np.asarray(p.w_in), leak=0.6,
                          mode="fp32", w_out=np.asarray(p.w_out),
                          readout_every=2)
        rng = np.random.default_rng(1)
        u = jnp.asarray(rng.standard_normal((6, 2, 1)), jnp.float32)
        states, preds = fr(u, want_states=True, want_preds=True)
        assert preds.shape == (3, 2, 2)
        want = np.asarray(states)[1::2] @ np.asarray(p.w_out)
        np.testing.assert_allclose(np.asarray(preds), want,
                                   rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("backend", ["xla", "pallas"])
    def test_engine_predictions_match_scan_predict(self, backend):
        p, _ = self._trained(mode="int8-csd" if backend == "pallas"
                             else "fp32", seed=7)
        eng = ReservoirEngine(p, backend=backend)
        rng = np.random.default_rng(2)
        u = jnp.asarray(rng.standard_normal((3, 12, 1)), jnp.float32)
        got = np.asarray(eng.predictions(u))
        want = np.asarray(predict(p, run_reservoir(p, u, engine="scan")))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_run_readout_fused_path(self):
        p, u = self._trained(seed=8)
        got = np.asarray(run_readout(p, u))
        want = np.asarray(predict(p, run_reservoir(p, u, engine="scan")))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
        scan = np.asarray(run_readout(p, u, engine="scan"))
        np.testing.assert_array_equal(scan, want)
