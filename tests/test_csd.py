"""CSD recoding tests — paper Section V invariants."""

import random

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.csd import (bits_to_int, convert_to_csd, csd_transform,
                            digits_to_int, int_to_bits, nonzero_digit_count,
                            pn_from_digits)


class TestListing1:
    """Faithful port of the paper's Listing 1."""

    def test_paper_example_15(self):
        # 15 = 16 - 1  <->  1111 -> 1000(-1): four set bits become two.
        rng = random.Random(0)
        d = convert_to_csd(int_to_bits(15, 4), rng)
        assert digits_to_int(d) == 15
        assert sum(1 for x in d if x) == 2
        assert d == [1, 0, 0, 0, -1]

    def test_width_grows_by_one(self):
        rng = random.Random(0)
        for v in (0, 1, 7, 255):
            assert len(convert_to_csd(int_to_bits(v, 8), rng)) == 9

    @given(st.integers(0, 2**16 - 1))
    @settings(max_examples=200, deadline=None)
    def test_value_preserved(self, v):
        rng = random.Random(v)
        d = convert_to_csd(int_to_bits(v, 16), rng)
        assert digits_to_int(d) == v

    @given(st.integers(0, 2**16 - 1))
    @settings(max_examples=200, deadline=None)
    def test_never_more_nonzeros(self, v):
        """CSD is 'strictly better': set-digit count never increases."""
        rng = random.Random(v * 7 + 1)
        bits = int_to_bits(v, 16)
        d = convert_to_csd(bits, rng)
        assert sum(1 for x in d if x) <= sum(bits)

    @given(st.integers(0, 2**12 - 1))
    @settings(max_examples=100, deadline=None)
    def test_chain3_strictly_reduces(self, v):
        """Any run of >= 3 ones strictly reduces the digit count."""
        rng = random.Random(v)
        bits = int_to_bits(v, 12)
        s = "".join(map(str, bits))
        has_chain3 = "111" in s
        d = convert_to_csd(bits, rng)
        if has_chain3:
            assert sum(1 for x in d if x) < sum(bits)

    def test_coin_flip_balances(self):
        """Length-2 chains recode ~half the time (the randomized tie-break)."""
        v = 0b011  # single length-2 chain
        outcomes = set()
        for seed in range(64):
            d = convert_to_csd(int_to_bits(v, 4), random.Random(seed))
            outcomes.add(tuple(d))
        assert len(outcomes) == 2  # both representations observed


class TestVectorized:
    def test_matches_reference_distributionally(self):
        vals = np.arange(4096) % 256
        digs = csd_transform(vals, 8, np.random.default_rng(0))
        w = 1 << np.arange(9)
        assert ((digs.astype(np.int64) * w).sum(-1) == vals).all()

    def test_pn_from_digits(self):
        vals = np.arange(256)
        digs = csd_transform(vals, 8, np.random.default_rng(1))
        p, n = pn_from_digits(digs)
        assert ((p - n) == vals).all()
        assert (p >= 0).all() and (n >= 0).all()

    def test_17pct_reduction_at_8bit(self):
        """Fig 9: CSD reduces hardware ~17% for uniform random matrices."""
        rng = np.random.default_rng(42)
        vals = rng.integers(0, 128, size=200_000)  # 7-bit magnitudes
        naive_ones = np.unpackbits(
            vals.astype(np.uint8)[:, None], axis=1).sum()
        digs = csd_transform(vals, 7, rng)
        csd_ones = nonzero_digit_count(digs)
        reduction = 1.0 - csd_ones / naive_ones
        assert 0.12 <= reduction <= 0.22, f"CSD reduction {reduction:.3f}"

    def test_rejects_out_of_range(self):
        with pytest.raises(ValueError):
            csd_transform(np.array([256]), 8)
        with pytest.raises(ValueError):
            csd_transform(np.array([-1]), 8)
