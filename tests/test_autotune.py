"""Plan autotuning: schedule search, cost model, caches, bit-identity.

The load-bearing property mirrors the specialized-vs-banded test in
test_specialize.py: EVERY schedule the autotuner can choose — any valid
point of the (budget, crossover, batch tile) grid, on either backend —
stays bit-identical to the default-heuristic program across
{fp32, int8-pn, int8-csd} x {one-shot, chunked}.  Tuning is a throughput
decision only; it can never change served bits.  On top: candidate
enumeration validity, analytic-vs-measured resolution, the persisted
schedule cache, coefficient fitting, the full-schedule summary-cache key
(the batch-tile collision bugfix), and the engine_for key/backend
unification (both route through the tuner).
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import costmodel
from repro.core.esn import ESNConfig, ESNParams
from repro.core.sparse import FixedMatrix, random_sparse_matrix
from repro.kernels.reservoir_rollout.ops import FusedRollout
from repro.kernels.reservoir_rollout.specialized import SpecializedRollout
from repro.launch.roofline import rollout_roofline
from repro.plan import plan_for, specialize_rollout, specialize_summary
from repro.plan.autotune import (BACKENDS, Schedule, ScheduleCache,
                                 autotune_rollout, candidate_schedules,
                                 default_schedule, hardware_fingerprint,
                                 plan_fingerprint, predict_cost,
                                 resolve_backend, resolve_schedule)
from repro.serve.engine import (ReservoirEngine, engine_cache_clear,
                                engine_cache_stats, engine_for)

DIM, BLOCK = 256, 64
TILE = BLOCK * BLOCK
# budgets that force the pipelined regime at DIM/BLOCK (see test_specialize)
PIPELINE_BUDGET = {"fp32": TILE * 4 * 10, "int8": TILE * 10}


def _fixed_matrix(digit_mode="csd", es=0.9, seed=0, dim=DIM, block=BLOCK):
    rng = np.random.default_rng(seed)
    w = random_sparse_matrix(dim, dim, es, rng) * 0.05
    return FixedMatrix.compile(w, weight_bits=8, mode=digit_mode,
                               block=block, rng=rng)


def _params(fm, esn_mode, seed=0, w_out=True):
    dim = fm.shape[0]
    rng = np.random.default_rng(seed + 100)
    cfg = ESNConfig(reservoir_dim=dim, input_dim=4, mode=esn_mode,
                    block=fm.blocks.block, seed=seed)
    return ESNParams(
        w=fm,
        w_in=jnp.asarray(rng.uniform(-0.5, 0.5, (4, dim)), jnp.float32),
        w_out=jnp.asarray(rng.uniform(-0.1, 0.1, (dim, 4)), jnp.float32)
        if w_out else None,
        config=cfg)


_FMS = {}


def _fm_for(esn_mode):
    if esn_mode not in _FMS:
        digit = "csd" if esn_mode != "int8-pn" else "pn"
        _FMS[esn_mode] = _fixed_matrix(digit)
    return _FMS[esn_mode]


MODES = ("fp32", "int8-pn", "int8-csd")


def _kmode(esn_mode):
    return "fp32" if esn_mode == "fp32" else "int8"


# One generic banded reference kernel per mode (the default-heuristic
# program's own reference), plus specialized kernels memoized per tuned
# schedule so hypothesis examples reuse compiles.
_BASE = {}
_SPEC = {}


def _base_for(esn_mode):
    if esn_mode not in _BASE:
        rng = np.random.default_rng(7)
        w_in = rng.uniform(-0.5, 0.5, (4, DIM)).astype(np.float32)
        w_out = rng.uniform(-0.1, 0.1, (DIM, 4)).astype(np.float32)
        _BASE[esn_mode] = FusedRollout(
            plan_for(_fm_for(esn_mode)), w_in, leak=0.7,
            mode=_kmode(esn_mode), w_out=w_out)
    return _BASE[esn_mode]


def _spec_for(esn_mode, sched: Schedule):
    key = (esn_mode, sched.vmem_budget, sched.crossover,
           sched.batch_tile_max)
    if key not in _SPEC:
        rng = np.random.default_rng(7)
        w_in = rng.uniform(-0.5, 0.5, (4, DIM)).astype(np.float32)
        w_out = rng.uniform(-0.1, 0.1, (DIM, 4)).astype(np.float32)
        _SPEC[key] = SpecializedRollout(
            plan_for(_fm_for(esn_mode)), w_in, leak=0.7,
            mode=_kmode(esn_mode), w_out=w_out,
            vmem_budget=sched.vmem_budget, crossover=sched.crossover,
            batch_tile_max=sched.batch_tile_max)
    return _SPEC[key]


_CANDS = {}


def _schedule_pool(esn_mode):
    """Every tuner candidate (deduped on the kernel-visible knobs), plus a
    pipeline-forcing budget so the regime axis is exercised at test dims."""
    if esn_mode not in _CANDS:
        km = _kmode(esn_mode)
        plan = plan_for(_fm_for(esn_mode))
        cands = candidate_schedules(plan, km, backends=("pallas",))
        pool, seen = [], set()
        for s in cands + [dataclasses.replace(
                default_schedule(plan, km, "pallas"),
                vmem_budget=PIPELINE_BUDGET[km])]:
            k = (s.vmem_budget, s.crossover, s.batch_tile_max)
            if k not in seen:
                seen.add(k)
                pool.append(s)
        _CANDS[esn_mode] = pool
    return _CANDS[esn_mode]


class TestAutotunedParity:
    # batch >= 2: at a single row XLA lowers the readout matmul as a gemv
    # whose accumulation order differs by an ulp (the caveat pinned in the
    # dist engine docstring) — that holds for the default-heuristic
    # program too, so it is not a property of the tuner's schedules.
    @given(st.sampled_from(MODES), st.booleans(), st.integers(2, 20),
           st.integers(0, 10**6), st.integers(0, 10**6))
    @settings(max_examples=15, deadline=None)
    def test_every_candidate_bit_identical_to_heuristic(
            self, mode, chunked, batch, seed, pick):
        """Any schedule the tuner can pick == the default-heuristic
        program, bit for bit, one-shot and chunked."""
        pool = _schedule_pool(mode)
        sched = pool[pick % len(pool)]
        base, spec = _base_for(mode), _spec_for(mode, sched)
        rng = np.random.default_rng(seed)
        t = 8
        u = jnp.asarray(rng.standard_normal((t, batch, 4)), jnp.float32)
        ref_s, ref_f = base(u, want_states=True, want_final=True)
        ref_p = base(u, want_states=False, want_preds=True)
        if chunked:
            s1, f1 = spec(u[: t // 2], want_states=True, want_final=True)
            s2, got_f = spec(u[t // 2:], x0=f1, want_states=True,
                             want_final=True)
            got_s = jnp.concatenate([s1, s2], axis=0)
            p1, g1 = spec(u[: t // 2], want_states=False,
                          want_preds=True, want_final=True)
            p2 = spec(u[t // 2:], x0=g1, want_states=False,
                      want_preds=True)
            got_p = jnp.concatenate([p1, p2], axis=0)
        else:
            got_s, got_f = spec(u, want_states=True, want_final=True)
            got_p = spec(u, want_states=False, want_preds=True)
        assert (np.asarray(ref_s) == np.asarray(got_s)).all()
        assert (np.asarray(ref_f) == np.asarray(got_f)).all()
        assert (np.asarray(ref_p) == np.asarray(got_p)).all()

    def test_measured_winner_engine_matches_default_engine(self):
        """The full predict -> prune -> measure loop's winner serves the
        same bits as the default-heuristic engine, for every mode."""
        for esn_mode in MODES:
            p = _params(_fm_for(esn_mode), esn_mode, seed=3)
            plan = plan_for(p.w)
            tuned = autotune_rollout(plan, _kmode(esn_mode), batch=4,
                                     steps=4, params=p, backends=("xla",),
                                     top_k=2, reps=1, refresh=True)
            ref = ReservoirEngine(p, backend="xla")
            eng = ReservoirEngine(p, backend="auto", schedule=tuned)
            rng = np.random.default_rng(9)
            u = jnp.asarray(rng.standard_normal((4, 6, 4)), jnp.float32)
            assert (np.asarray(eng.rollout(u))
                    == np.asarray(ref.rollout(u))).all()
            assert (np.asarray(eng.predictions(u))
                    == np.asarray(ref.predictions(u))).all()


class TestCandidatesAndPrediction:
    def test_candidates_valid_and_include_default(self):
        plan = plan_for(_fm_for("int8-csd"))
        cands = candidate_schedules(plan, "int8")
        assert {c.backend for c in cands} == set(BACKENDS)
        keys = {c.key() for c in cands}
        assert len(keys) == len(cands)
        assert default_schedule(plan, "int8").key() in keys
        for c in cands:  # every candidate must actually build
            specialize_rollout(plan, c.mode, vmem_budget=c.vmem_budget,
                               crossover=c.crossover,
                               batch_tile_max=c.batch_tile_max)

    def test_fp32_crossover_axis_collapses(self):
        plan = plan_for(_fm_for("fp32"))
        cands = candidate_schedules(plan, "fp32", backends=("xla",))
        assert len({c.crossover for c in cands}) == 1

    def test_predict_cost_orders_backends_on_cpu(self):
        """Interpret-mode pallas must never win the prune off-TPU."""
        plan = plan_for(_fm_for("int8-csd"))
        d = default_schedule(plan, "int8")
        assert predict_cost(plan, d, 8, 8) < predict_cost(
            plan, dataclasses.replace(d, backend="pallas"), 8, 8)

    def test_resolution_is_deterministic_and_xla_on_cpu(self):
        plan = plan_for(_fm_for("int8-csd"))
        a = resolve_schedule(plan, "int8")
        b = resolve_schedule(plan, "int8")
        assert a.schedule == b.schedule
        if jax.default_backend() == "cpu":
            assert a.schedule.backend == "xla"

    def test_measured_winner_never_loses_to_default(self):
        p = _params(_fm_for("int8-csd"), "int8-csd", seed=5)
        plan = plan_for(p.w)
        tuned = autotune_rollout(plan, "int8", batch=4, steps=4, params=p,
                                 backends=("xla",), top_k=2, reps=1,
                                 refresh=True)
        assert tuned.source == "measured"
        assert tuned.measured_s is not None and tuned.measured_s > 0
        assert tuned.default_measured_s >= tuned.measured_s
        assert any(Schedule.from_dict(s).key() == tuned.schedule.key()
                   for s, _p, _m in tuned.trials)

    def test_describe_reports_tuned_schedule(self):
        plan = plan_for(_fm_for("int8-csd"))
        resolve_schedule(plan, "int8")
        text = plan.describe()
        assert "autotuned[int8" in text
        assert hardware_fingerprint() in text


class TestScheduleCache:
    def test_roundtrip_and_zero_retune(self, tmp_path):
        p = _params(_fm_for("int8-pn"), "int8-pn", seed=6)
        plan = plan_for(p.w)
        cache = ScheduleCache()
        tuned = autotune_rollout(plan, "int8", batch=4, steps=4, params=p,
                                 backends=("xla",), top_k=1, reps=1,
                                 cache=cache)
        path = tmp_path / "autotune_cache.json"
        cache.save(path)
        fresh = ScheduleCache()
        assert fresh.load(path) == len(cache) >= 1
        # a fresh process resolving through the loaded cache replays the
        # measured winner without measuring (or even predicting) anything
        replay = resolve_schedule(plan, "int8", backend="xla", batch=4,
                                  steps=4, cache=fresh)
        assert replay.source == "cache"
        assert replay.schedule == tuned.schedule
        assert replay.measured_s == tuned.measured_s

    def test_cache_rejects_wrong_version(self, tmp_path):
        path = tmp_path / "bad.json"
        path.write_text(json.dumps({"version": 999, "entries": {}}))
        try:
            ScheduleCache().load(path)
        except ValueError as e:
            assert "version" in str(e)
        else:
            raise AssertionError("stale cache version must not load")

    def test_fingerprint_stable_across_rebuilds(self):
        fp1 = plan_fingerprint(plan_for(_fixed_matrix("csd", seed=21)))
        fp2 = plan_fingerprint(plan_for(_fixed_matrix("csd", seed=21)))
        fp3 = plan_fingerprint(plan_for(_fixed_matrix("csd", seed=22)))
        assert fp1 == fp2 != fp3


class TestCostModel:
    def test_fit_recovers_synthetic_coefficients(self):
        rng = np.random.default_rng(0)
        true = np.array([3e-11, 1e-9, 5e-11, 1e-6, 5e-7, 2e-6, 2e-4])
        feats, samples = [], []
        for _ in range(48):
            f = {
                "matmul_macs": float(rng.integers(1, 100)) * 1e6,
                "shiftadd_ops": float(rng.integers(0, 100)) * 1e3,
                "stream_bytes": float(rng.integers(1, 100)) * 1e5,
                "band_steps": float(rng.integers(1, 64)),
                "tile_steps": float(rng.integers(1, 256)),
                "steps": float(rng.integers(1, 32)),
            }
            y = float(np.array([f[k] for k in costmodel.ROLLOUT_FEATURES]
                               + [1.0]) @ true)
            feats.append(f)
            samples.append(("xla", f, y))
        model = costmodel.fit_rollout_cost(samples, platform="cpu")
        for f, (_bk, _f, y) in zip(feats, samples):
            pred = model.predict("xla", f)
            assert abs(pred - y) <= 0.05 * y + 1e-6
        # untouched backends keep their prior
        assert "pallas" in model.coeffs
        rt = costmodel.RolloutCostModel.from_dict(model.as_dict())
        assert rt.predict("xla", feats[0]) == model.predict("xla", feats[0])

    def test_features_price_the_regime(self):
        """Pipelined re-streams weights every step; resident pays once."""
        plan = plan_for(_fm_for("int8-csd"))
        res = specialize_summary(plan, "int8", vmem_budget=None)
        pipe = specialize_summary(plan, "int8",
                                  vmem_budget=PIPELINE_BUDGET["int8"])
        f_res = costmodel.rollout_cost_features(res, BLOCK, 8, steps=16)
        f_pipe = costmodel.rollout_cost_features(pipe, BLOCK, 8, steps=16)
        assert f_pipe["stream_bytes"] > f_res["stream_bytes"]
        assert f_pipe["band_steps"] > f_res["band_steps"]
        assert f_res["matmul_macs"] == f_pipe["matmul_macs"]

    def test_rollout_roofline_terms(self):
        plan = plan_for(_fm_for("int8-csd"))
        s = specialize_summary(plan, "int8",
                               vmem_budget=PIPELINE_BUDGET["int8"])
        r = rollout_roofline(s, BLOCK, batch=8, steps=64)
        assert set(r) >= {"compute_s", "memory_s", "dominant", "bound_s",
                          "advice"}
        assert r["compute_s"] > 0 and r["memory_s"] > 0
        assert r["bound_s"] == max(r["compute_s"], r["memory_s"])


class TestSummaryCacheKey:
    def test_summary_keyed_on_batch_tile(self):
        """Regression: the summary cache used to omit the batch tile, so
        tuned variants differing only in tiling collided."""
        plan = plan_for(_fm_for("int8-csd"))
        s8 = specialize_summary(plan, "int8", batch_tile_max=8)
        s32 = specialize_summary(plan, "int8", batch_tile_max=32)
        assert s8["batch_tile_max"] == 8
        assert s32["batch_tile_max"] == 32
        # a cached program for one tile size must not answer for another
        specialize_rollout(plan, "int8", batch_tile_max=8)
        assert specialize_summary(plan, "int8",
                                  batch_tile_max=32)["batch_tile_max"] == 32

    def test_summary_still_matches_program(self):
        plan = plan_for(_fm_for("int8-csd"))
        prog = specialize_rollout(plan, "int8", batch_tile_max=8)
        s = specialize_summary(plan, "int8", batch_tile_max=8)
        assert s["batch_tile_max"] == prog.batch_tile_max == 8
        assert s["n_matmul_terms"] == prog.n_matmul_terms


class TestEngineIntegration:
    def test_engine_for_key_and_backend_agree(self):
        """Regression: engine_for used to key "auto" as "xla" while the
        constructor got the raw string; both now route through the tuner."""
        engine_cache_clear()
        engine_cache_stats(reset=True)
        p = _params(_fm_for("int8-csd"), "int8-csd", seed=8)
        eng = engine_for(p)
        assert eng.backend == resolve_backend(p, "auto")
        # asking for the resolved backend explicitly hits the same entry
        assert engine_for(p, eng.backend) is eng
        assert engine_for(p) is eng
        assert engine_cache_stats()["hits"] >= 2

    def test_auto_engine_adopts_tuned_schedule(self):
        p = _params(_fm_for("int8-csd"), "int8-csd", seed=9)
        plan = plan_for(p.w)
        tuned = resolve_schedule(plan, "int8")
        eng = ReservoirEngine(p)
        assert eng.schedule == tuned.schedule
        assert eng.vmem_budget == tuned.schedule.vmem_budget
        assert eng.crossover == tuned.schedule.crossover
        assert eng.batch_tile_max == tuned.schedule.batch_tile_max

    def test_explicit_kwargs_beat_tuned_schedule(self):
        p = _params(_fm_for("int8-csd"), "int8-csd", seed=9)
        eng = ReservoirEngine(p, vmem_budget=12345, crossover=7,
                              batch_tile_max=4)
        assert eng.vmem_budget == 12345
        assert eng.crossover == 7 and eng.batch_tile_max == 4

    def test_unspecialized_auto_stays_xla(self):
        p = _params(_fm_for("fp32"), "fp32", seed=10)
        eng = ReservoirEngine(p, specialize=False)
        assert eng.backend == "xla" and eng.schedule is None

    def test_sharded_engine_inherits_tuned_schedule(self):
        from repro.dist.engine import ShardedReservoirEngine
        p = _params(_fm_for("int8-csd"), "int8-csd", seed=11)
        plan = plan_for(p.w)
        tuned = resolve_schedule(plan, "int8")
        eng = ShardedReservoirEngine(p, n_shards=1)
        assert eng.schedule == tuned.schedule
        assert eng.backend == tuned.schedule.backend
        sib = eng.like()
        assert sib.schedule == eng.schedule
        assert sib.vmem_budget == eng.vmem_budget
