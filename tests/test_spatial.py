"""Register-level bit-serial emulator vs exact integer gemv (Section III)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.bitplanes import decompose, from_bitplanes, pn_split, to_bitplanes
from repro.core.spatial import eq5_latency, simulate_gemv


class TestBitplanes:
    def test_pn_split_reconstructs(self):
        rng = np.random.default_rng(0)
        m = rng.integers(-128, 128, size=(32, 16))
        p, n = pn_split(m)
        assert ((p - n) == m).all()
        assert (p >= 0).all() and (n >= 0).all()
        assert ((p == 0) | (n == 0)).all()  # disjoint support

    @given(st.integers(1, 9), st.sampled_from(["pn", "csd"]))
    @settings(max_examples=20, deadline=None)
    def test_decompose_roundtrip(self, bits, mode):
        rng = np.random.default_rng(bits)
        lo, hi = -(1 << (bits - 1)), (1 << (bits - 1))
        m = rng.integers(lo, hi, size=(17, 9))
        dp = decompose(m, bits, mode=mode, rng=rng)
        assert (dp.to_dense() == m).all()

    def test_bitplane_roundtrip(self):
        rng = np.random.default_rng(3)
        m = rng.integers(0, 256, size=(8, 8))
        assert (from_bitplanes(to_bitplanes(m, 8)) == m).all()

    def test_csd_fewer_or_equal_ones(self):
        rng = np.random.default_rng(7)
        m = rng.integers(-128, 128, size=(64, 64))
        pn = decompose(m, 8, mode="pn")
        csd = decompose(m, 8, mode="csd", rng=rng)
        assert csd.ones <= pn.ones
        # Fig 9: ~17% for uniform random 8-bit
        assert csd.ones < 0.92 * pn.ones


class TestEmulator:
    """The emulator must compute the exact gemv — the architecture works."""

    @pytest.mark.parametrize("mode", ["pn", "csd"])
    @pytest.mark.parametrize("r,c,bi,bw", [
        (8, 4, 8, 8),
        (16, 8, 6, 8),
        (13, 5, 8, 8),     # non-power-of-two rows exercise leaf padding
        (64, 16, 8, 8),
        (100, 3, 4, 3),
        (5, 7, 12, 6),
    ])
    def test_exact_gemv(self, mode, r, c, bi, bw):
        rng = np.random.default_rng(r * 1000 + c)
        V = rng.integers(-(1 << (bw - 1)), 1 << (bw - 1), size=(r, c))
        a = rng.integers(-(1 << (bi - 1)), 1 << (bi - 1), size=(r,))
        res = simulate_gemv(V, a, input_bits=bi, weight_bits=bw, mode=mode,
                            rng=rng)
        np.testing.assert_array_equal(res.output, a @ V)

    def test_sparse_matrix_exact(self):
        rng = np.random.default_rng(11)
        V = rng.integers(-128, 128, size=(32, 8))
        V[rng.random(V.shape) < 0.9] = 0
        a = rng.integers(-128, 128, size=(32,))
        res = simulate_gemv(V, a, input_bits=8, weight_bits=8)
        np.testing.assert_array_equal(res.output, a @ V)

    def test_eq5_paper_example(self):
        """'given 8-bit inputs and weights and a 1024x1024 weight matrix, we
        perform the vector-matrix product in 8+8+log2(1024)+2 = 28 cycles'"""
        assert eq5_latency(8, 8, 1024) == 28

    def test_ones_metric_reported(self):
        rng = np.random.default_rng(2)
        V = rng.integers(-8, 8, size=(16, 4))
        res = simulate_gemv(V, np.ones(16, dtype=int), 4, 4, rng=rng)
        assert res.ones > 0
        zero = simulate_gemv(np.zeros((16, 4), int), np.ones(16, dtype=int), 4, 4)
        assert zero.ones == 0
        np.testing.assert_array_equal(zero.output, np.zeros(4, int))
