"""Multi-tenant registry: live swap, cross-tenant interleaving, quotas.

The acceptance contracts from the ISSUE:

* a ``publish()`` under a running Poisson trace drops nothing and times
  nothing out by the swap, and outputs are *bit-exact* on both sides of
  the cutover — pre-cutover admissions match the old engine, post-cutover
  the new one (version pinned at admission, never migrated);
* interleaving tenants in one slot pool is bit-identical to serving each
  tenant alone: every per-model chunk call runs at the FULL pool shape,
  so a row's arithmetic never depends on who its neighbours are;
* per-tenant quotas hold requests without head-of-line blocking, and
  registry deadline policies drop expired queued work — all accounted in
  per-tenant stats.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.esn import ESNConfig, fit_readout, init_esn, run_reservoir
from repro.plan import plan_cache_stats
from repro.serve import (AsyncReservoirServer, ModelRegistry, ReservoirEngine,
                         ServeStats, SubmitSpec, engine_cache_clear,
                         engine_cache_stats, engine_for)

DIM = 64


def _params(seed=1, leak=0.7):
    cfg = ESNConfig(reservoir_dim=DIM, element_sparsity=0.8, mode="fp32",
                    leak=leak, seed=seed, block=32, output_dim=2)
    p = init_esn(cfg)
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.standard_normal((50, 1)), jnp.float32)
    states = run_reservoir(p, u, engine="scan")
    y = jnp.concatenate([u, jnp.roll(u, 1)], axis=-1)
    return fit_readout(p, states, y, lam=1e-2)


def _pool_ref(engine, inputs, n_slots):
    """One-shot reference at the POOL batch shape: rows are independent,
    so tiling the request across all slots gives the exact bits its pool
    row produces."""
    batch = jnp.asarray(np.broadcast_to(
        inputs[None], (n_slots,) + inputs.shape))
    return np.asarray(engine.predictions(batch))[0]


class TestRegistryBasics:
    def test_register_version_activate_and_rollback(self):
        reg = ModelRegistry(backend="xla")
        v1 = reg.register("m", _params(1))
        assert (v1.version, reg.active_version("m")) == (1, 1)
        v2 = reg.register("m", _params(2))
        assert (v2.version, reg.active_version("m")) == (2, 2)
        assert reg.versions("m") == [1, 2] and reg.models == ["m"]
        with pytest.raises(ValueError, match="immutable"):
            reg.register("m", _params(3), version=2)
        plan = reg.publish("m", version=1)       # rollback
        assert reg.active_version("m") == 1
        assert plan["previous_version"] == 2 and plan["version"] == 1
        assert plan["prewarm_s"] >= 0.0 and len(plan["actions"]) == 5
        with pytest.raises(KeyError):
            reg.active_version("ghost")
        with pytest.raises(KeyError, match="no version"):
            reg.get("m", 9)
        with pytest.raises(ValueError, match="params"):
            reg.publish("m")

    def test_engine_cache_keyed_on_registry_identity(self):
        """Two versions with VALUE-equal params must get distinct cached
        engines — (name, version) is the key, not array identity."""
        engine_cache_clear()
        engine_cache_stats(reset=True)
        p = _params(4)
        import dataclasses as dc
        p2 = dc.replace(p)                       # same arrays, new object
        reg = ModelRegistry(backend="xla")
        reg.register("m", p)
        reg.register("m", p2)
        e1, e2 = reg.engine("m", 1), reg.engine("m", 2)
        assert e1 is not e2
        assert reg.engine("m", 1) is e1          # cache hit on the key
        st = engine_cache_stats()
        assert st["tenants"]["m"]["misses"] == 2
        assert st["tenants"]["m"]["hits"] >= 1

    def test_plan_cache_tenant_counters(self):
        plan_cache_stats(reset=True)
        reg = ModelRegistry(backend="xla")
        reg.register("counted", _params(5))
        reg.engine("counted")
        st = plan_cache_stats()
        assert "counted" in st["tenants"]
        assert st["tenants"]["counted"]["hits"] + \
            st["tenants"]["counted"]["misses"] >= 1

    def test_registry_submit_one_shot(self):
        reg = ModelRegistry(backend="xla")
        reg.register("m", _params(1))
        u = np.ones((9, 1), np.float32)
        res = reg.submit(SubmitSpec(u, model="m"))
        assert res.preds.shape == (9, 2) and res.final_state.shape == (DIM,)
        with pytest.raises(ValueError, match="spec.model"):
            reg.submit(SubmitSpec(u))

    def test_bare_engine_rejects_model_spec(self):
        eng = ReservoirEngine(_params(1))
        with pytest.raises(ValueError, match="registry"):
            eng.submit(SubmitSpec(np.ones((4, 1), np.float32), model="m"))
        srv = AsyncReservoirServer(ReservoirEngine(_params(1),
                                                   stats=ServeStats()),
                                   n_slots=1, chunk_time=1.0)
        with pytest.raises(ValueError, match="no registry"):
            srv.submit(SubmitSpec(np.ones((4, 1), np.float32), model="m"))

    def test_mismatched_dims_rejected_in_shared_pool(self):
        small = ESNConfig(reservoir_dim=32, element_sparsity=0.8,
                          mode="fp32", leak=0.7, seed=9, block=32,
                          output_dim=2)
        reg = ModelRegistry(backend="xla")
        reg.register("big", _params(1))
        reg.register("small", init_esn(small))
        eng = reg.engine("big")
        eng.stats = ServeStats()
        srv = AsyncReservoirServer(eng, n_slots=2, chunk_steps=8,
                                   chunk_time=1.0, registry=reg)
        srv.submit(SubmitSpec(np.ones((8, 1), np.float32), model="small",
                              want_states=True))
        with pytest.raises(ValueError, match="share input/reservoir dims"):
            srv.run()


class TestCrossTenantInterleaving:
    @pytest.mark.parametrize("backend", ["xla", "pallas"])
    def test_bit_identical_to_single_tenant(self, backend):
        """A/B interleaved in one pool == each served alone, bit for bit."""
        pA, pB = _params(1), _params(2, leak=0.55)
        rng = np.random.default_rng(0)
        n, t = 4, 24
        inputs = [rng.standard_normal((t, 1)).astype(np.float32)
                  for _ in range(n)]
        reg = ModelRegistry(backend=backend)
        reg.register("A", pA)
        reg.register("B", pB)
        eng = reg.engine("A")
        eng.stats = ServeStats()
        srv = AsyncReservoirServer(eng, n_slots=n, chunk_steps=8,
                                   chunk_time=1.0, registry=reg)
        for i, u in enumerate(inputs):
            srv.submit(SubmitSpec(u, model="A" if i % 2 == 0 else "B",
                                  uid=i), arrival_time=0.0)
        res = srv.run()
        # single-tenant references at the same (n_slots, T) pool shape
        batch = jnp.asarray(np.stack(inputs))
        refA = np.asarray(reg.engine("A").predictions(batch))
        refB = np.asarray(reg.engine("B").predictions(batch))
        for i in range(n):
            ref = refA if i % 2 == 0 else refB
            np.testing.assert_array_equal(
                np.asarray(res[i].output), ref[i])
            assert res[i].timings["model"] == ("A" if i % 2 == 0 else "B")
            assert res[i].timings["version"] == 1
        ts = srv.tenant_summary()
        assert ts.completed == n
        assert ts.shards["A"].completed == ts.shards["B"].completed == 2


class TestLiveSwap:
    def test_mid_traffic_swap_bit_exact_zero_drops(self):
        """Poisson trace against model "m"; v2 published mid-flight.

        Every request completes (nothing dropped or timed out by the
        swap), requests admitted before the cutover are bit-exact against
        the v1 engine, requests admitted after against v2."""
        p1, p2 = _params(1), _params(7, leak=0.5)
        rng = np.random.default_rng(3)
        n_slots, t, n_req = 4, 24, 14
        inputs = [rng.standard_normal((t, 1)).astype(np.float32)
                  for _ in range(n_req)]
        arrivals = np.cumsum(rng.exponential(0.4, n_req))
        arrivals -= arrivals[0]

        reg = ModelRegistry(backend="xla")
        reg.register("m", p1)
        eng = reg.engine("m")
        eng.stats = ServeStats()
        srv = AsyncReservoirServer(eng, n_slots=n_slots, chunk_steps=8,
                                   chunk_time=1.0, registry=reg)
        handles = [srv.submit(SubmitSpec(u, model="m", uid=i),
                              arrival_time=float(at))
                   for i, (u, at) in enumerate(zip(inputs, arrivals))]
        # serve a few chunks, then swap with work in flight and queued
        swapped_at = None
        while srv.step():
            if swapped_at is None and srv.stats.completed >= 3:
                assert srv.batcher.live > 0      # genuinely mid-traffic
                plan = reg.publish("m", p2)
                swapped_at = srv.now
                assert plan["version"] == 2
        res = srv.results

        assert len(res) == n_req                 # zero drops
        assert srv.stats.timed_out == 0
        assert swapped_at is not None
        e1, e2 = reg.engine("m", 1), reg.engine("m", 2)
        pinned = [q.pinned_version for q in handles]
        assert set(pinned) == {1, 2}             # trace straddles the swap
        for i, q in enumerate(handles):
            eng_v = e1 if q.pinned_version == 1 else e2
            ref = _pool_ref(eng_v, inputs[i], n_slots)
            np.testing.assert_array_equal(np.asarray(res[i].output), ref)
            assert res[i].timings["version"] == q.pinned_version
        # in-flight work admitted before the cutover finished on v1
        pre = [q for q in handles if q.admit_time is not None
               and q.admit_time < swapped_at]
        assert all(q.pinned_version == 1 for q in pre)

    def test_swap_prewarm_compiles_before_cutover(self):
        """During publish() the new version's chunk program is compiled
        against the pool shape — the first post-swap chunk retraces
        nothing."""
        p1, p2 = _params(1), _params(8)
        reg = ModelRegistry(backend="xla")
        reg.register("m", p1)
        eng = reg.engine("m")
        eng.stats = ServeStats()
        srv = AsyncReservoirServer(eng, n_slots=2, chunk_steps=8,
                                   chunk_time=1.0, registry=reg)
        # compile v1's chunk program via one served request
        srv.submit(SubmitSpec(np.ones((8, 1), np.float32), model="m",
                              uid="warm"))
        srv.run()
        reg.publish("m", p2)
        e2 = reg.engine("m", 2)
        traces_after_publish = dict(e2.trace_counts)
        assert traces_after_publish                  # prewarm traced it
        srv.submit(SubmitSpec(np.ones((8, 1), np.float32), model="m",
                              uid="post"))
        srv.run()
        assert dict(e2.trace_counts) == traces_after_publish
        # retired version demoted: (m, 1) sits at the LRU eviction front
        from repro.serve.engine import _engine_cache
        assert next(iter(_engine_cache))[0] == ("m", 1)


class TestQuotasAndDeadlines:
    def test_quota_holds_without_head_of_line_blocking(self):
        pA, pB = _params(1), _params(2)
        reg = ModelRegistry(backend="xla")
        reg.register("A", pA)
        reg.register("B", pB, quota=1)
        eng = reg.engine("A")
        eng.stats = ServeStats()
        srv = AsyncReservoirServer(eng, n_slots=3, chunk_steps=8,
                                   chunk_time=1.0, registry=reg)
        # two B requests up front, then an A request behind them
        for i in range(2):
            srv.submit(SubmitSpec(np.ones((16, 1), np.float32),
                                  model="B", uid=f"b{i}"), arrival_time=0.0)
        srv.submit(SubmitSpec(np.ones((8, 1), np.float32),
                              model="A", uid="a0"), arrival_time=0.0)
        max_b_live = 0
        while srv.step():
            b_live = sum(1 for q in srv.batcher._slots
                         if q is not None and q.model == "B")
            max_b_live = max(max_b_live, b_live)
        assert max_b_live == 1                   # quota enforced
        assert len(srv.results) == 3             # held, not dropped
        assert srv.stats.quota_held > 0
        assert srv.tenant_stats["B"].quota_held > 0
        assert srv.tenant_stats["A"].quota_held == 0
        # b1 queued behind the quota, but a0 seated past it at t=0
        assert srv.results["a0"].timings["admit_time"] == 0.0
        assert "quota_held" in srv.stats.summary()

    def test_registry_deadline_policy_applies_to_specs(self):
        p = _params(1)
        reg = ModelRegistry(backend="xla")
        reg.register("m", p, deadline_s=1.5)
        eng = reg.engine("m")
        eng.stats = ServeStats()
        srv = AsyncReservoirServer(eng, n_slots=1, chunk_steps=8,
                                   chunk_time=1.0, registry=reg)
        # slot busy for 4 ticks; the queued request expires at 1.5
        srv.submit(SubmitSpec(np.ones((32, 1), np.float32), model="m",
                              uid="busy"), arrival_time=0.0)
        doomed = srv.submit(SubmitSpec(np.ones((8, 1), np.float32),
                                       model="m", uid="late"),
                            arrival_time=0.0)
        res = srv.run()
        assert doomed.deadline == 1.5            # policy became absolute
        assert set(res) == {"busy"}
        assert srv.stats.timed_out == 1
        assert srv.tenant_stats["m"].timed_out == 1
        # an explicit spec deadline wins over the policy
        q = srv.submit(SubmitSpec(np.ones((4, 1), np.float32), model="m",
                                  deadline=99.0, uid="patient"))
        assert q.deadline == 99.0

    def test_legacy_engine_for_still_keyed_by_identity(self):
        """The tenant=None regime is unchanged: same params object hits,
        and kwargs bypass the cache entirely."""
        engine_cache_clear()
        p = _params(6)
        a = engine_for(p, "xla")
        assert engine_for(p, "xla") is a
        b = engine_for(p, "xla", interpret=True)   # kwargs -> no cache
        assert b is not a
