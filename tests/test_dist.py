"""Sharded serving: shard_map engine parity, sharded batcher, elastic shrink.

The acceptance contract is the ISSUE's: the sharded engine must be
*bit-identical per sequence* to the single-device engine on both backends
(states AND fused-readout predictions, fp32 + int8-csd, chunked +
one-shot).  Each shard runs the identical compiled rollout callable on
its batch slice and rows never mix through the recurrence, so equality is
exact, not approximate.

Multi-device tests (classes named ``*MultiDevice*``) need 8 devices; the
CI dist job runs this module under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.  In the plain
tier-1 run (1 device) they are covered instead by the subprocess test at
the bottom, which forces 8 virtual devices the way the HLO-walker test
does.
"""

import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.esn import ESNConfig, fit_readout, init_esn, run_reservoir
from repro.dist import (DistributedReservoirServer, ShardedContinuousBatcher,
                        ShardedReservoirEngine)
from repro.runtime.elastic import (AutoscalePolicy, grow_serve_plan,
                                   shrink_serve_plan)
from repro.runtime.faults import FaultEvent, FaultPlan
from repro.serve import (ReservoirEngine, RolloutRequest, ServeStats,
                         SubmitSpec)

N_DEV = len(jax.devices())
multi_device = pytest.mark.skipif(
    N_DEV < 8, reason="needs 8 devices (run by the CI dist job)")


def _params(mode="fp32", dim=96, leak=0.7, seed=1, block=32):
    cfg = ESNConfig(reservoir_dim=dim, element_sparsity=0.8, mode=mode,
                    leak=leak, seed=seed, block=block, output_dim=2)
    p = init_esn(cfg)
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.standard_normal((50, 1)), jnp.float32)
    states = run_reservoir(p, u, engine="scan")
    y = jnp.concatenate([u, jnp.roll(u, 1)], axis=-1)
    return fit_readout(p, states, y, lam=1e-2)


def _requests(lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [SubmitSpec(rng.standard_normal((t, 1)).astype(np.float32), uid=i)
            for i, t in enumerate(lengths)]


class TestServeStatsMerge:
    def _part(self, calls=2, steps=100, seconds=0.5, wait_max=0.1):
        s = ServeStats()
        for _ in range(calls):
            s.record_call(batch=4, steps=steps // calls // 4,
                          seconds=seconds / calls)
        s.record_enqueue()
        s.record_admission(wait_max)
        s.record_chunk(live_steps=steps // 2, total_steps=steps)
        return s

    def test_merge_sums_counters_and_maxes_maxima(self):
        a = self._part(wait_max=0.1)
        b = self._part(calls=4, wait_max=0.7)
        m = ServeStats.merge([a, b])
        assert m.calls == a.calls + b.calls
        assert m.steps_padded == a.steps_padded + b.steps_padded
        assert m.seconds == pytest.approx(a.seconds + b.seconds)
        assert m.queue_wait_max_s == pytest.approx(0.7)
        assert m.admitted == 2 and m.enqueued == 2
        # calls-weighted ewma
        want = (a.latency_ewma_s * a.calls + b.latency_ewma_s * b.calls) / 6
        assert m.latency_ewma_s == pytest.approx(want)

    def test_merge_timed_out_and_empty(self):
        a = ServeStats()
        a.record_timeout()
        a.record_timeout()
        m = ServeStats.merge([a, ServeStats()])
        assert m.timed_out == 2
        assert ServeStats.merge([]).calls == 0

    def test_shard_breakdown_in_summary_and_render(self):
        m = ServeStats.merge([self._part(), self._part()],
                             labels=["shard0", "shard1"])
        summ = m.summary()
        assert set(summ["shards"]) == {"shard0", "shard1"}
        assert summ["shards"]["shard0"]["calls"] == 2
        r = m.render()
        assert "shard0:" in r and "shard1:" in r and "occupancy" in r

    def test_timed_out_rendered(self):
        s = ServeStats()
        s.record_enqueue()
        s.record_timeout()
        assert "1 timed out" in s.render()
        assert s.summary()["timed_out"] == 1


class TestShrinkServePlan:
    def test_every_survivor_usable(self):
        plan = shrink_serve_plan(8, 3)
        assert plan["survivors"] == 5 and plan["usable_devices"] == 5
        assert plan["mesh_shape"] == (5, 1)

    def test_actions_cover_serving_recovery(self):
        acts = " ".join(shrink_serve_plan(8, 1)["actions"])
        assert "re-admit" in acts.lower()
        assert "snapshot" in acts.lower()
        assert "cached" in acts.lower()


class TestGrowServePlan:
    def test_inverse_of_shrink(self):
        plan = grow_serve_plan(5, 3)
        assert plan["n_shards_before"] == 5
        assert plan["n_shards_after"] == 8 and plan["added"] == 3
        assert plan["mesh_shape"] == (8, 1)

    def test_device_ceiling_caps_width(self):
        plan = grow_serve_plan(6, 4, max_shards=8)
        assert plan["n_shards_after"] == 8 and plan["added"] == 2
        assert grow_serve_plan(8, 2, max_shards=8)["added"] == 0

    def test_actions_cover_rebalance(self):
        acts = " ".join(grow_serve_plan(2, 2)["actions"])
        assert "rebalance" in acts.lower()
        assert "snapshot" in acts.lower()


class TestAutoscalePolicy:
    def test_grows_on_backlog(self):
        pol = AutoscalePolicy(max_shards=8, grow_queue_per_slot=1.0)
        assert pol.decide(pending=20, live=16, n_slots=16, n_shards=4) == 1
        # at the ceiling: never grows past max_shards
        assert pol.decide(pending=20, live=16, n_slots=16, n_shards=8) == 0

    def test_shrinks_only_when_idle(self):
        pol = AutoscalePolicy(min_shards=2, shrink_occupancy=0.25)
        assert pol.decide(pending=0, live=1, n_slots=16, n_shards=4) == -1
        # queued work blocks scale-down even at low occupancy
        assert pol.decide(pending=1, live=1, n_slots=16, n_shards=4) == 0
        # never below min_shards
        assert pol.decide(pending=0, live=0, n_slots=16, n_shards=2) == 0

    def test_steady_state_holds(self):
        pol = AutoscalePolicy()
        assert pol.decide(pending=4, live=12, n_slots=16, n_shards=4) == 0


class TestSingleShardParity:
    """n_shards=1 runs everywhere and must already be exactly the
    single-device engine (the shard_map wrapper adds nothing)."""

    @pytest.mark.parametrize("backend", ["xla", "pallas"])
    def test_bit_identical(self, backend):
        p = _params()
        single = ReservoirEngine(p, backend=backend, stats=ServeStats())
        sharded = ShardedReservoirEngine(p, n_shards=1, backend=backend,
                                         stats=ServeStats())
        rng = np.random.default_rng(0)
        u = jnp.asarray(rng.standard_normal((4, 12, 1)), jnp.float32)
        np.testing.assert_array_equal(np.asarray(sharded.rollout(u)),
                                      np.asarray(single.rollout(u)))
        z = jnp.zeros((4, 96), jnp.float32)
        pr_s, xf_s = sharded.run_segment(u, z)
        pr_1, xf_1 = single.run_segment(u, z)
        np.testing.assert_array_equal(np.asarray(pr_s), np.asarray(pr_1))
        np.testing.assert_array_equal(np.asarray(xf_s), np.asarray(xf_1))

    def test_serve_api_and_padding_accounting(self):
        p = _params()
        sharded = ShardedReservoirEngine(p, n_shards=1, stats=ServeStats())
        res = sharded.submit_many(_requests([5, 9, 12], seed=2))
        assert set(res) == {0, 1, 2} and res[1].output.shape == (9, 2)
        assert sharded.stats.steps_real > 0

    def test_distributed_server_matches_engine(self):
        p = _params()
        eng = ShardedReservoirEngine(p, n_shards=1, stats=ServeStats())
        single = ReservoirEngine(p, stats=ServeStats())
        srv = DistributedReservoirServer(eng, slots_per_shard=3,
                                         chunk_steps=8, chunk_time=1.0,
                                         stats=ServeStats())
        reqs = _requests([5, 17, 30, 9, 12, 23], seed=3)
        for i, r in enumerate(reqs):
            srv.submit(r, arrival_time=0.5 * i)
        res = srv.run()
        for r in reqs:
            want = np.asarray(single.predictions(jnp.asarray(r.inputs)))
            np.testing.assert_allclose(res[r.uid].output, want,
                                       rtol=1e-4, atol=1e-6)
        merged = srv.shard_summary()
        assert merged.completed == 6 and merged.shards is not None
        assert "shard0" in merged.summary()["shards"]


@multi_device
class TestMultiDeviceParity:
    """8-shard engine == single-device engine, bit for bit."""

    @pytest.mark.parametrize("backend", ["xla", "pallas"])
    @pytest.mark.parametrize("mode", ["fp32", "int8-csd"])
    def test_one_shot_and_chunked_bit_identical(self, backend, mode):
        p = _params(mode=mode)
        single = ReservoirEngine(p, backend=backend, stats=ServeStats())
        sharded = ShardedReservoirEngine(p, n_shards=8, backend=backend,
                                         stats=ServeStats())
        assert sharded.n_shards == 8
        rng = np.random.default_rng(4)
        u = jnp.asarray(rng.standard_normal((16, 12, 1)), jnp.float32)
        # states and fused-readout predictions, one-shot
        np.testing.assert_array_equal(np.asarray(sharded.rollout(u)),
                                      np.asarray(single.rollout(u)))
        np.testing.assert_array_equal(np.asarray(sharded.predictions(u)),
                                      np.asarray(single.predictions(u)))
        # chunked: carry the sharded final state, resume, compare the
        # stitched trajectory against the single-device one-shot
        p1, xf = sharded.run_segment(u[:, :6],
                                     jnp.zeros((u.shape[0], 96),
                                               jnp.float32))
        p2 = sharded.predictions(u[:, 6:], x0=xf)
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(p1), np.asarray(p2)], axis=1),
            np.asarray(single.predictions(u)))

    def test_ragged_batch_pads_to_shard_multiple(self):
        p = _params()
        single = ReservoirEngine(p, stats=ServeStats())
        sharded = ShardedReservoirEngine(p, n_shards=8, stats=ServeStats())
        rng = np.random.default_rng(5)
        u = jnp.asarray(rng.standard_normal((5, 10, 1)), jnp.float32)
        out = sharded.predictions(u)
        assert out.shape == (5, 10, 2)          # padding rows trimmed
        # local batch is 1 here, which XLA may lower as a gemv with a
        # different accumulation order — allow an ulp (the bit-identity
        # contract is tested at local batch >= 2 above)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(single.predictions(u)),
                                   rtol=1e-5, atol=1e-6)
        # padded rows counted as executed (8 rows ran for 5 real)
        assert sharded.stats.sequences == 8
        assert sharded.stats.steps_real == 50
        assert sharded.stats.steps_padded == 80


@multi_device
class TestMultiDeviceServer:
    def test_least_loaded_admission_spreads_shards(self):
        p = _params()
        eng = ShardedReservoirEngine(p, n_shards=8, stats=ServeStats())
        cb = ShardedContinuousBatcher(eng, slots_per_shard=2, chunk_steps=4)
        from repro.serve.scheduler import QueuedRequest
        for i in range(8):
            cb.admit(QueuedRequest(RolloutRequest(
                uid=i, inputs=np.ones((4, 1), np.float32))))
        # one request per shard before any shard takes a second
        assert cb.free_slots_by_shard() == [1] * 8
        for s in range(8):
            assert cb.shard_stats[s].admitted == 1

    def test_results_match_single_device(self):
        p = _params()
        eng = ShardedReservoirEngine(p, n_shards=8, stats=ServeStats())
        single = ReservoirEngine(p, stats=ServeStats())
        srv = DistributedReservoirServer(eng, slots_per_shard=2,
                                         chunk_steps=8, chunk_time=1.0,
                                         stats=ServeStats())
        reqs = _requests([5, 17, 30, 9, 12, 23, 8, 40, 11, 16], seed=6)
        for i, r in enumerate(reqs):
            srv.submit(r, arrival_time=0.25 * i)
        res = srv.run()
        assert len(res) == len(reqs)
        for r in reqs:
            want = np.asarray(single.predictions(jnp.asarray(r.inputs)))
            np.testing.assert_allclose(res[r.uid].output, want,
                                       rtol=1e-4, atol=1e-6)
        merged = srv.shard_summary()
        assert merged.completed == len(reqs)
        assert len(merged.shards) == 8


@multi_device
class TestMultiDeviceShrink:
    def test_shard_loss_loses_no_request(self):
        p = _params()
        eng = ShardedReservoirEngine(p, n_shards=8, stats=ServeStats())
        single = ReservoirEngine(p, stats=ServeStats())
        srv = DistributedReservoirServer(eng, slots_per_shard=1,
                                         chunk_steps=4, chunk_time=1.0,
                                         stats=ServeStats())
        reqs = _requests([16] * 12, seed=7)
        for r in reqs:
            srv.submit(r, arrival_time=0.0)
        srv.step()                               # 8 in flight, mid-rollout
        assert srv.batcher.live == 8
        plan = srv.shrink(failed=3)
        assert plan["n_shards_after"] == 5 and srv.n_shards == 5
        assert srv.readmitted == 8 and srv.reshards == 1
        assert srv.batcher.n_shards == 5
        res = srv.run()
        assert len(res) == 12                    # nothing lost
        # re-admissions must not double-count queue telemetry
        assert srv.stats.admitted == srv.stats.enqueued == 12
        assert srv.stats.completed == 12
        # shard telemetry spans both topology epochs: totals cover the
        # whole run, with per-epoch shard labels
        merged = srv.shard_summary()
        assert merged.completed == 12
        assert any(label.startswith("epoch0/") for label in merged.shards)
        assert any(label.startswith("epoch1/") for label in merged.shards)
        for r in reqs:
            want = np.asarray(single.predictions(jnp.asarray(r.inputs)))
            np.testing.assert_allclose(res[r.uid].output, want,
                                       rtol=1e-4, atol=1e-6)

    def test_shrink_resume_is_bit_exact_when_shapes_allow(self):
        """A sequence whose chunks all ran at the same pool shape stays
        bit-identical across the shrink: the carried state is exact and
        the resumed chunks recompute nothing."""
        p = _params()
        eng = ShardedReservoirEngine(p, n_shards=8, stats=ServeStats())
        srv = DistributedReservoirServer(eng, slots_per_shard=1,
                                         chunk_steps=4, chunk_time=1.0,
                                         stats=ServeStats())
        u = np.random.default_rng(8).standard_normal((8, 1)).astype(
            np.float32)
        srv.submit(SubmitSpec(u, uid="a"), arrival_time=0.0)
        srv.step()
        srv.shrink(failed=4)
        res = srv.run()
        assert res["a"].output.shape == (8, 2)


@multi_device
class TestMultiDeviceGrow:
    """Elastic grow under live traffic: the inverse of shrink, same
    snapshot/re-admit machinery, zero drops."""

    def test_shrink_grow_round_trip_bit_identical(self):
        """Property test: a pool shrunk then regrown under traffic
        serves every request with outputs bit-identical to an
        undisturbed run.  ``slots_per_shard=2`` keeps the local batch
        >= 2, where the per-shard program (whose shape is independent
        of the shard count) is exactly the contract's bit-identity
        regime."""
        p = _params()
        lengths = [12] * 12

        def serve(disturb):
            eng = ShardedReservoirEngine(p, n_shards=4, stats=ServeStats())
            srv = DistributedReservoirServer(eng, slots_per_shard=2,
                                             chunk_steps=4, chunk_time=1.0,
                                             stats=ServeStats())
            for r in _requests(lengths, seed=9):
                srv.submit(r, arrival_time=0.0)
            if disturb:
                srv.step()                      # 8 in flight, mid-rollout
                srv.shrink(failed=2)
                srv.step()                      # roll a chunk at width 2
                plan = srv.grow(2)
                assert plan["n_shards_after"] == 4 and srv.n_shards == 4
                assert srv.grows == 1 and srv.reshards == 1
            return srv.run(), srv

        ref, _ = serve(disturb=False)
        res, srv = serve(disturb=True)
        assert len(res) == len(ref) == 12       # zero drops
        assert srv.stats.completed == 12
        assert srv.stats.admitted == srv.stats.enqueued == 12
        for uid in ref:
            np.testing.assert_array_equal(np.asarray(res[uid].output),
                                          np.asarray(ref[uid].output))

    def test_grow_rebalances_subpools(self):
        """After a grow the least-loaded FIFO admission spreads carried
        + queued work over the new shards — the widened pool actually
        serves, it doesn't just exist."""
        p = _params()
        eng = ShardedReservoirEngine(p, n_shards=2, stats=ServeStats())
        srv = DistributedReservoirServer(eng, slots_per_shard=2,
                                         chunk_steps=4, chunk_time=1.0,
                                         stats=ServeStats())
        for r in _requests([16] * 12, seed=10):
            srv.submit(r, arrival_time=0.0)
        srv.step()
        assert srv.batcher.live == 4
        srv.grow(2)
        assert srv.n_shards == 4 and srv.batcher.n_slots == 8
        srv.step()
        # every shard of the widened pool holds seated work
        assert all(f < srv.slots_per_shard
                   for f in srv.batcher.free_slots_by_shard())
        res = srv.run()
        assert len(res) == 12 and srv.stats.completed == 12
        merged = srv.shard_summary()
        assert merged.completed == 12

    def test_fault_plan_shard_death_recovers_through_shrink(self):
        """An unplanned shard death scheduled by the fault plan is
        detected at the next step and converted into the shrink path:
        zero request loss, and an autoscale policy grows the pool back
        under the remaining backlog."""
        p = _params()
        plan = FaultPlan([FaultEvent("shard_loss", at=2.0, shard=1)])
        eng = ShardedReservoirEngine(p, n_shards=4, stats=ServeStats())
        srv = DistributedReservoirServer(
            eng, slots_per_shard=2, chunk_steps=4, chunk_time=1.0,
            stats=ServeStats(), fault_plan=plan,
            autoscale=AutoscalePolicy(min_shards=1, max_shards=4,
                                      cooldown_steps=2))
        reqs = _requests([12] * 20, seed=11)
        for r in reqs:
            srv.submit(r, arrival_time=0.0)
        res = srv.run()
        assert plan.injected.get("shard_loss") == 1
        assert srv.reshards >= 1                 # death -> shrink path
        assert srv.grows >= 1                    # backlog -> grow back
        assert len(res) == 20 and srv.stats.completed == 20

        # bit-identical to the undisturbed reference run
        eng2 = ShardedReservoirEngine(p, n_shards=4, stats=ServeStats())
        ref_srv = DistributedReservoirServer(eng2, slots_per_shard=2,
                                             chunk_steps=4, chunk_time=1.0,
                                             stats=ServeStats())
        for r in _requests([12] * 20, seed=11):
            ref_srv.submit(r, arrival_time=0.0)
        ref = ref_srv.run()
        for uid in ref:
            np.testing.assert_array_equal(np.asarray(res[uid].output),
                                          np.asarray(ref[uid].output))


@multi_device
class TestMultiDeviceMultiModel:
    """Registry-routed multi-tenant serving on the 8-shard pool: two
    models interleaved through one sharded FIFO, each bit-exact against
    its own single-tenant sharded serve at the same pool shape."""

    def test_two_models_share_sharded_pool_bit_exact(self):
        from repro.serve import ModelRegistry, SubmitSpec
        pA, pB = _params(seed=1), _params(seed=2, leak=0.55)
        rng = np.random.default_rng(12)
        n_req, t = 8, 16
        inputs = [rng.standard_normal((t, 1)).astype(np.float32)
                  for _ in range(n_req)]

        def serve(models):
            reg = ModelRegistry()
            reg.register("A", pA)
            reg.register("B", pB)
            eng = ShardedReservoirEngine(pA, n_shards=4, stats=ServeStats())
            srv = DistributedReservoirServer(
                eng, slots_per_shard=2, chunk_steps=8, chunk_time=1.0,
                stats=ServeStats(), registry=reg)
            for i, u in enumerate(inputs):
                srv.submit(SubmitSpec(u, model=models(i), uid=i),
                           arrival_time=0.0)
            return srv.run(), srv

        mixed, srv = serve(lambda i: "A" if i % 2 == 0 else "B")
        only_a, _ = serve(lambda i: "A")
        only_b, _ = serve(lambda i: "B")
        for i in range(n_req):
            ref = only_a if i % 2 == 0 else only_b
            np.testing.assert_array_equal(np.asarray(mixed[i].output),
                                          np.asarray(ref[i].output))
        ts = srv.tenant_summary()
        assert ts.shards["A"].completed == ts.shards["B"].completed == 4

    def test_publish_swaps_on_sharded_server(self):
        from repro.serve import ModelRegistry, SubmitSpec
        p1, p2 = _params(seed=3), _params(seed=4)
        reg = ModelRegistry()
        reg.register("m", p1)
        eng = ShardedReservoirEngine(p1, n_shards=4, stats=ServeStats())
        srv = DistributedReservoirServer(
            eng, slots_per_shard=1, chunk_steps=4, chunk_time=1.0,
            stats=ServeStats(), registry=reg)
        u = np.random.default_rng(5).standard_normal((12, 1)).astype(
            np.float32)
        pre = srv.submit(SubmitSpec(u, model="m", uid="pre"),
                         arrival_time=0.0)
        srv.step()                               # "pre" pinned to v1
        plan = reg.publish("m", p2)
        assert plan["version"] == 2
        post = srv.submit(SubmitSpec(u, model="m", uid="post"))
        res = srv.run()
        assert pre.pinned_version == 1 and post.pinned_version == 2
        assert srv.stats.timed_out == 0 and len(res) == 2
        # v2's mesh-mapped engine serves post; v1 finished pre in place
        ref1 = srv._tenant_engine("m", 1).predictions(
            jnp.asarray(np.broadcast_to(u[None], (4,) + u.shape)))
        ref2 = srv._tenant_engine("m", 2).predictions(
            jnp.asarray(np.broadcast_to(u[None], (4,) + u.shape)))
        np.testing.assert_array_equal(np.asarray(res["pre"].output),
                                      np.asarray(ref1)[0])
        np.testing.assert_array_equal(np.asarray(res["post"].output),
                                      np.asarray(ref2)[0])


class TestMultiDeviceSubprocess:
    """Tier-1 coverage of the 8-device tests when this process only has
    one device: re-run the MultiDevice classes under forced virtual
    devices, exactly like the HLO-walker ground-truth test."""

    @pytest.mark.skipif(N_DEV >= 8, reason="already running multi-device")
    def test_multi_device_suite(self):
        env = dict(os.environ)
        env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                            + env.get("XLA_FLAGS", "")).strip()
        env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
        out = subprocess.run(
            [sys.executable, "-m", "pytest", "-x", "-q",
             "tests/test_dist.py", "-k", "MultiDevice and not Subprocess"],
            capture_output=True, text=True, timeout=900, env=env,
            cwd=str(Path(__file__).parent.parent))
        assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-2000:]
        assert "passed" in out.stdout
