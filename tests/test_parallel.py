"""Sharding rules + MoE distribution + HLO cost walker."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.parallel.sharding import (batch_spec, data_axis_names,
                                     resolve_axes)

# jax 0.4.37's AbstractMesh takes a single tuple of (name, size) pairs.
MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH3 = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


class TestLogicalRules:
    def test_tp_dims(self):
        # d_ff over model; d_model over data (FSDP)
        spec = resolve_axes(("embed", "ffn"), (5120, 25600), MESH)
        assert spec == P("data", "model")

    def test_kv_not_divisible_stays_replicated(self):
        # MQA: 1 kv head cannot shard over a 16-way model axis
        spec = resolve_axes(("embed", "kv", None), (2048, 1, 256), MESH)
        assert spec == P("data", None, None)

    def test_heads_divisible(self):
        spec = resolve_axes(("embed", "heads", None), (5120, 64, 128), MESH)
        assert spec == P("data", "model", None)

    def test_multi_pod_fsdp_axes(self):
        spec = resolve_axes(("embed", "ffn"), (5120, 25600), MESH3)
        assert spec == P(("pod", "data"), "model")

    def test_embed_not_divisible(self):
        # 100 doesn't divide by 16 -> replicated rather than invalid
        spec = resolve_axes(("embed",), (100,), MESH)
        assert spec == P(None)

    def test_one_mesh_axis_used_once(self):
        # vocab and heads can't both take 'model'
        spec = resolve_axes(("vocab", "heads"), (512, 64), MESH)
        assert spec == P("model", None)

    def test_layers_dim_replicated(self):
        spec = resolve_axes(("layers", "embed", "ffn"), (64, 5120, 1024),
                            MESH)
        assert spec == P(None, "data", "model")

    def test_batch_spec(self):
        assert batch_spec(MESH) == P("data")
        assert batch_spec(MESH3) == P(("pod", "data"))
        assert data_axis_names(MESH3) == ("pod", "data")


class TestMoEDispatch:
    def _setup(self, t=64, d=16, e=8, k=2, cap=4):
        from repro.models.moe import _dispatch_compute, _route
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
        router = jnp.asarray(rng.standard_normal((d, e)), jnp.float32)
        w_g = jnp.asarray(rng.standard_normal((e, d, 8)) * 0.1, jnp.float32)
        w_u = jnp.asarray(rng.standard_normal((e, d, 8)) * 0.1, jnp.float32)
        w_d = jnp.asarray(rng.standard_normal((e, 8, d)) * 0.1, jnp.float32)
        return x, router, (w_g, w_u, w_d)

    def test_sharded_expert_partition_sums_to_full(self):
        """Sum of per-shard partial outputs == single-shard full output."""
        from repro.models.moe import _dispatch_compute, _route
        from repro.configs.base import MoEConfig
        x, router, (w_g, w_u, w_d) = self._setup()
        m = MoEConfig(n_experts=8, top_k=2, d_expert=8, capacity_factor=16.0)
        idx, gate, aux = _route(x, router, m)
        cap = 64  # no drops
        full = _dispatch_compute(x, idx, gate, w_g, w_u, w_d, 0, 8, cap)
        part = sum(
            _dispatch_compute(x, idx, gate, w_g[lo:lo + 2], w_u[lo:lo + 2],
                              w_d[lo:lo + 2], lo, 2, cap)
            for lo in range(0, 8, 2))
        np.testing.assert_allclose(np.asarray(part), np.asarray(full),
                                   rtol=2e-4, atol=2e-5)

    def test_capacity_drops_tokens(self):
        from repro.models.moe import _dispatch_compute, _route
        from repro.configs.base import MoEConfig
        x, router, (w_g, w_u, w_d) = self._setup()
        m = MoEConfig(n_experts=8, top_k=2, d_expert=8)
        idx, gate, _ = _route(x, router, m)
        tiny = _dispatch_compute(x, idx, gate, w_g, w_u, w_d, 0, 8, 1)
        big = _dispatch_compute(x, idx, gate, w_g, w_u, w_d, 0, 8, 64)
        # capacity 1 must zero-out some tokens' contributions
        assert float(jnp.abs(tiny - big).max()) > 0

    def test_router_normalizes_gates(self):
        from repro.models.moe import _route
        from repro.configs.base import MoEConfig
        x, router, _ = self._setup()
        m = MoEConfig(n_experts=8, top_k=2, d_expert=8)
        idx, gate, aux = _route(x, router, m)
        np.testing.assert_allclose(np.asarray(gate.sum(-1)), 1.0, rtol=1e-5)
        assert float(aux) > 0


class TestHloCostWalker:
    """Ground-truth validation in a subprocess (needs >1 fake device)."""

    SCRIPT = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.hlo_cost import analyze_hlo
        mesh = jax.make_mesh((4, 4), ("data", "model"))
        w1 = jax.ShapeDtypeStruct((512, 1024), jnp.bfloat16)
        w2 = jax.ShapeDtypeStruct((1024, 512), jnp.bfloat16)
        x = jax.ShapeDtypeStruct((64, 512), jnp.bfloat16)
        def f(x, w1, w2):
            def body(c, _):
                return jnp.maximum(c @ w1, 0) @ w2, ()
            out, _ = jax.lax.scan(body, x, None, length=7)
            return out
        sh = lambda *s: NamedSharding(mesh, P(*s))
        jf = jax.jit(f, in_shardings=(sh("data", None), sh(None, "model"),
                                      sh("model", None)),
                     out_shardings=sh("data", None))
        res = analyze_hlo(jf.lower(x, w1, w2).compile().as_text())
        # per-device truth: 7 iters x 2 dots x 2*16*512*256-ish partitions
        expect = 7 * 2 * (2 * 64 * 512 * 1024) / 16
        assert abs(res["dot_flops"] - expect) / expect < 0.01, res
        assert res["collective_bytes"].get("all-reduce", 0) > 0
        print("WALKER_OK", res["dot_flops"])
    """)

    def test_walker_ground_truth(self):
        out = subprocess.run([sys.executable, "-c", self.SCRIPT],
                             capture_output=True, text=True, timeout=300,
                             cwd=str(__import__("pathlib").Path(
                                 __file__).parent.parent))
        assert "WALKER_OK" in out.stdout, out.stderr[-2000:]

    def test_parser_handles_index_comments(self):
        from repro.launch.hlo_cost import HloModule
        txt = """ENTRY %main.1 (p0: f32[4,4], /*index=1*/p1: f32[4,4]) -> f32[4,4] {
  %p0 = f32[4,4]{1,0} parameter(0)
  %p1 = f32[4,4]{1,0} parameter(1)
  ROOT %dot.1 = f32[4,4]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}"""
        res = HloModule(txt).analyze()
        assert res["dot_flops"] == 2 * 4 * 4 * 4

    def test_trip_count_attr_preferred(self):
        from repro.launch.hlo_cost import HloModule
        txt = """%body.1 (p: (s32[], f32[2,2])) -> (s32[], f32[2,2]) {
  %p = (s32[], f32[2,2]{1,0}) parameter(0)
  %a = f32[2,2]{1,0} get-tuple-element(%p), index=1
  %d = f32[2,2]{1,0} dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%cond.1 (p: (s32[], f32[2,2])) -> pred[] {
  %p = (s32[], f32[2,2]{1,0}) parameter(0)
  %c = s32[] constant(99)
}

ENTRY %main.2 (p0: (s32[], f32[2,2])) -> (s32[], f32[2,2]) {
  %p0 = (s32[], f32[2,2]{1,0}) parameter(0)
  ROOT %w = (s32[], f32[2,2]{1,0}) while(%p0), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
}"""
        res = HloModule(txt).analyze()
        # known_trip_count=5 wins over the constant 99 in the condition
        assert res["dot_flops"] == 5 * 2 * 2 * 2 * 2
