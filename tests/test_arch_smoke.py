"""Per-architecture smoke tests: reduced configs, one train/forward step on
CPU, shape + finiteness assertions, and prefill/decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_archs, reduced, supports_shape
from repro.models.transformer import LM

ARCHS = list_archs()


def _batch(cfg, rng, b=2, s=16):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s + 1)))}
    if cfg.frontend == "vision":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((b, 4, cfg.d_model)), jnp.float32)
    if cfg.encoder is not None:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder.seq_len, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.fixture(scope="module")
def arch_state():
    """Init each reduced arch once per test session."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = reduced(get_config(arch))
            lm = LM(cfg)
            pax = lm.init(jax.random.PRNGKey(1))
            cache[arch] = (cfg, lm, pax)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_shapes_and_finite(arch, arch_state):
    cfg, lm, pax = arch_state(arch)
    rng = np.random.default_rng(hash(arch) % 2**31)
    batch = _batch(cfg, rng)
    loss, grads = jax.value_and_grad(lm.loss)(pax.params, batch)
    assert np.isfinite(float(loss)), arch
    assert 3.0 < float(loss) < 12.0  # ~ln(vocab) at random init
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    # grads match param shapes
    jax.tree.map(lambda p, g: None if p.shape == g.shape else 1 / 0,
                 pax.params, grads)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_finite(arch, arch_state):
    cfg, lm, pax = arch_state(arch)
    rng = np.random.default_rng(0)
    b, s = 2, 16
    batch = _batch(cfg, rng, b, s)
    pf = {k: (v[:, :s] if k == "tokens" else v) for k, v in batch.items()}
    n_extra = pf["patches"].shape[1] if "patches" in pf else 0
    logits, caches = lm.prefill(pax.params, pf, cache_len=s + n_extra + 8)
    assert logits.shape == (b, 1, cfg.vocab_size)
    tok = batch["tokens"][:, s:s + 1]
    lg, caches = lm.decode_step(pax.params, caches, tok)
    assert lg.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(lg).all()), arch
    # vision archs hold the patch positions in the cache too
    assert int(caches["index"]) == s + n_extra + 1


@pytest.mark.parametrize("arch", ["qwen3-32b", "gemma-2b", "stablelm-1.6b",
                                  "recurrentgemma-2b", "xlstm-350m",
                                  "deepseek-v2-236b"])
def test_decode_matches_teacher_forcing(arch, arch_state):
    """Greedy decode logits == full-sequence forward logits (same params).

    The strongest cheap correctness check: the cached/incremental path and
    the parallel path implement the same function.
    """
    cfg, lm, pax = arch_state(arch)
    rng = np.random.default_rng(3)
    b, s = 1, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))

    # parallel: prefill the whole sequence, logits at last position
    full_logits, _ = lm.prefill(pax.params, {"tokens": toks}, cache_len=s)

    # incremental: prefill s-1 then one decode step with the last token
    _, caches = lm.prefill(pax.params, {"tokens": toks[:, : s - 1]},
                           cache_len=s)
    inc_logits, _ = lm.decode_step(pax.params, caches, toks[:, s - 1:])

    np.testing.assert_allclose(
        np.asarray(inc_logits, np.float32),
        np.asarray(full_logits, np.float32), rtol=0.15, atol=0.15)
    # argmax agreement is the functional requirement
    assert int(jnp.argmax(inc_logits)) == int(jnp.argmax(full_logits))


@pytest.mark.parametrize("arch", ARCHS)
def test_shape_support_matrix(arch):
    """The 40-cell support matrix matches DESIGN.md §Shapes."""
    cfg = get_config(arch)
    for shape in SHAPES.values():
        ok, why = supports_shape(cfg, shape)
        if shape.name == "long_500k":
            sub_quadratic = cfg.name in ("recurrentgemma-2b", "xlstm-350m")
            assert ok == sub_quadratic, (arch, shape.name, why)
        else:
            assert ok, (arch, shape.name, why)


def test_full_param_counts_match_names():
    """eval_shape param totals land near the advertised sizes."""
    expected = {
        "deepseek-v2-236b": (220e9, 250e9),
        "qwen3-32b": (30e9, 35e9),
        "mistral-nemo-12b": (11e9, 13.5e9),
        "gemma-2b": (2.0e9, 3.0e9),
        "stablelm-1.6b": (1.4e9, 1.9e9),
        "olmoe-1b-7b": (6.0e9, 7.5e9),
        "recurrentgemma-2b": (2.4e9, 3.2e9),
        "whisper-base": (0.05e9, 0.09e9),
        "internvl2-76b": (65e9, 76e9),   # ViT frontend is stubbed (~6B)
        "xlstm-350m": (0.15e9, 0.45e9),
    }
    for arch, (lo, hi) in expected.items():
        n = LM(get_config(arch)).param_count()
        assert lo <= n <= hi, (arch, n)
