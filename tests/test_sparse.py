"""FixedMatrix / BlockSparse — structure culling and exactness."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.sparse import BlockSparse, FixedMatrix, random_sparse_matrix


class TestBlockSparse:
    @given(st.integers(30, 200), st.integers(30, 200),
           st.sampled_from([16, 32, 64]), st.floats(0.5, 0.99))
    @settings(max_examples=15, deadline=None)
    def test_roundtrip_and_matmul(self, r, c, block, sparsity):
        rng = np.random.default_rng(r * c)
        d = random_sparse_matrix(r, c, sparsity, rng).astype(np.float32)
        bs = BlockSparse.from_dense(d, block=block)
        np.testing.assert_allclose(bs.to_dense(), d, atol=0)
        x = rng.standard_normal((2, r)).astype(np.float32)
        np.testing.assert_allclose(
            np.asarray(bs.matmul_ref(jnp.asarray(x))), x @ d,
            rtol=1e-5, atol=1e-4)

    def test_zero_blocks_culled(self):
        d = np.zeros((128, 128), np.float32)
        d[:32, :32] = 1.0  # single nonzero block at block=32
        bs = BlockSparse.from_dense(d, block=32)
        assert bs.n_blocks_nnz == 1
        assert bs.n_blocks_total == 16
        assert bs.data.shape == (1, 32, 32)

    def test_all_zero_matrix(self):
        bs = BlockSparse.from_dense(np.zeros((64, 64), np.float32), block=32)
        assert bs.n_blocks_nnz == 0
        out = bs.matmul_ref(jnp.ones((3, 64)))
        np.testing.assert_array_equal(np.asarray(out), 0.0)


class TestFixedMatrix:
    def test_int_paths_agree(self):
        rng = np.random.default_rng(5)
        d = random_sparse_matrix(96, 64, 0.9, rng)
        fm = FixedMatrix.compile(d, mode="csd", block=32, rng=rng)
        a = jnp.asarray(rng.integers(-100, 100, size=(4, 96)))
        np.testing.assert_array_equal(
            np.asarray(fm.matvec_int_exact(a)),
            np.asarray(fm.matvec_int_dense_ref(a)))

    @pytest.mark.parametrize("mode", ["pn", "csd"])
    def test_quantization_error_bounded(self, mode):
        rng = np.random.default_rng(6)
        d = random_sparse_matrix(64, 64, 0.8, rng)
        fm = FixedMatrix.compile(d, weight_bits=8, mode=mode, block=32, rng=rng)
        err = np.abs(np.asarray(fm.dense_f32()) - d).max()
        assert err <= fm.scale * 0.5 + 1e-7

    def test_csd_reduces_ones(self):
        rng = np.random.default_rng(7)
        d = random_sparse_matrix(128, 128, 0.7, rng)
        pn = FixedMatrix.compile(d, mode="pn", block=64, rng=rng)
        csd = FixedMatrix.compile(d, mode="csd", block=64,
                                  rng=np.random.default_rng(7))
        assert csd.ones < pn.ones

    def test_cost_report(self):
        rng = np.random.default_rng(8)
        d = random_sparse_matrix(256, 256, 0.95, rng)
        fm = FixedMatrix.compile(d, block=64, rng=rng)
        cost = fm.fpga_cost()
        assert cost.luts == fm.ones
        assert cost.cycles == 8 + 8 + 8 + 2
        assert cost.latency_ns < 120

    def test_element_sparsity_tracked(self):
        rng = np.random.default_rng(9)
        d = random_sparse_matrix(200, 200, 0.9, rng)
        fm = FixedMatrix.compile(d, block=64, rng=rng)
        assert abs(fm.element_sparsity - 0.9) < 0.03
