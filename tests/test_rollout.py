"""Fused reservoir-rollout kernel vs the core ESN step references.

Parity contract:
  * int8 digit-plane mode is BIT-EXACT against the jnp scan reference —
    the recurrent product is exact integer arithmetic and the float
    epilogue compiles to the same fused program.
  * fp32 mode matches to float-accumulation-order tolerance (~1 ulp per
    step), and exactly reproduces the eager ``_step_fp32`` trajectory
    within tight allclose bounds.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.esn import (ESNConfig, _step_fp32, _step_int8, init_esn,
                            run_reservoir)
from repro.core.sparse import FixedMatrix
from repro.kernels.reservoir_rollout.ops import FusedRollout
from repro.kernels.reservoir_rollout.ref import (rollout_fp32_ref,
                                                 rollout_int8_ref)
from repro.kernels.reservoir_step.ops import FusedReservoir


def _step_loop(params, u_seq, step):
    """Reference trajectory: eager per-step function over (T, B, I)."""
    t, b, _ = u_seq.shape
    x = jnp.zeros((b, params.config.reservoir_dim), jnp.float32)
    out = []
    for i in range(t):
        x = step(params, x, u_seq[i])
        out.append(np.asarray(x))
    return np.stack(out)


def _make(dim, mode, leak, seed, block):
    cfg = ESNConfig(reservoir_dim=dim, element_sparsity=0.8, mode=mode,
                    leak=leak, seed=seed, block=block)
    p = init_esn(cfg)
    kmode = "int8" if mode.startswith("int8") else "fp32"
    fr = FusedRollout(p.w, np.asarray(p.w_in), leak=leak, mode=kmode,
                      state_bits=cfg.state_bits)
    return p, fr


class TestFusedRolloutFp32:
    @pytest.mark.parametrize("dim,block,batch", [
        (128, 64, 1),
        (150, 64, 3),      # ragged: padding tile in play
        (128, 128, 4),
    ])
    @pytest.mark.parametrize("leak", [1.0, 0.3])
    def test_parity_vs_step_ref(self, dim, block, batch, leak):
        p, fr = _make(dim, "fp32", leak, seed=dim + batch, block=block)
        rng = np.random.default_rng(0)
        u = jnp.asarray(rng.standard_normal((6, batch, 1)), jnp.float32)
        got = np.asarray(fr(u))
        want = _step_loop(p, u, _step_fp32)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_matches_scan_reference_path(self):
        p, fr = _make(150, "fp32", 0.3, seed=3, block=64)
        rng = np.random.default_rng(1)
        u = jnp.asarray(rng.standard_normal((8, 2, 1)), jnp.float32)
        got = np.asarray(fr(u))
        want = np.asarray(run_reservoir(p, u.transpose(1, 0, 2),
                                        engine="scan")).transpose(1, 0, 2)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_given_x0(self):
        p, fr = _make(96, "fp32", 1.0, seed=5, block=32)
        rng = np.random.default_rng(2)
        u = jnp.asarray(rng.standard_normal((4, 2, 1)), jnp.float32)
        x0 = jnp.asarray(rng.uniform(-0.5, 0.5, (2, 96)), jnp.float32)
        got = np.asarray(fr(u, x0))
        x = x0
        for t in range(4):
            x = _step_fp32(p, x, u[t])
        np.testing.assert_allclose(got[-1], np.asarray(x),
                                   rtol=1e-5, atol=1e-6)

    def test_ref_oracle_consistency(self):
        p, fr = _make(96, "fp32", 0.5, seed=7, block=32)
        rng = np.random.default_rng(3)
        u = jnp.asarray(rng.standard_normal((5, 2, 1)), jnp.float32)
        x0 = jnp.zeros((2, 96), jnp.float32)
        ref = np.asarray(rollout_fp32_ref(u, p.w.dense_f32(), p.w_in, x0,
                                          leak=0.5))
        np.testing.assert_allclose(np.asarray(fr(u)), ref,
                                   rtol=1e-5, atol=1e-5)


class TestFusedRolloutInt8:
    @pytest.mark.parametrize("mode", ["int8-pn", "int8-csd"])
    @pytest.mark.parametrize("leak", [1.0, 0.3])
    def test_bit_exact_vs_scan_reference(self, mode, leak):
        """Acceptance: int8 rollout == jnp scan reference, bit for bit."""
        p, fr = _make(150, mode, leak, seed=3, block=64)
        rng = np.random.default_rng(0)
        u = jnp.asarray(rng.standard_normal((6, 3, 1)), jnp.float32)
        got = np.asarray(fr(u))
        want = np.asarray(run_reservoir(p, u.transpose(1, 0, 2),
                                        engine="scan")).transpose(1, 0, 2)
        np.testing.assert_array_equal(got, want)

    def test_close_to_eager_step_loop(self):
        # Eager per-step execution rounds the epilogue differently (no FMA
        # contraction) — trajectories agree to ~1 ulp per step.
        p, fr = _make(128, "int8-csd", 0.3, seed=4, block=64)
        rng = np.random.default_rng(1)
        u = jnp.asarray(rng.standard_normal((6, 2, 1)), jnp.float32)
        got = np.asarray(fr(u))
        want = _step_loop(p, u, _step_int8)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_ref_oracle_consistency(self):
        p, fr = _make(96, "int8-pn", 1.0, seed=6, block=32)
        rng = np.random.default_rng(2)
        u = jnp.asarray(rng.standard_normal((5, 2, 1)), jnp.float32)
        x0 = jnp.zeros((2, 96), jnp.float32)
        ref = np.asarray(rollout_int8_ref(u, p.w.q, p.w.scale, p.w_in, x0,
                                          leak=1.0, state_bits=8))
        np.testing.assert_allclose(np.asarray(fr(u)), ref,
                                   rtol=1e-5, atol=1e-6)


class TestStaticCulling:
    def _block_structured(self, mode):
        # Only the top-left 2x2 block grid is populated: 12 of 16 blocks
        # (and their plan terms) must be culled at trace time.
        rng = np.random.default_rng(0)
        dense = np.zeros((256, 256), np.float32)
        dense[:128, :128] = rng.integers(-8, 8, (128, 128))
        fm = FixedMatrix.compile(dense, weight_bits=8, mode="csd", block=64,
                                 rng=rng)
        w_in = rng.uniform(-0.5, 0.5, (1, 256)).astype(np.float32)
        return fm, w_in

    @pytest.mark.parametrize("kmode", ["fp32", "int8"])
    def test_zero_blocks_never_enter_plan(self, kmode):
        fm, w_in = self._block_structured(kmode)
        fr = FusedRollout(fm, w_in, mode=kmode)
        assert fm.blocks.n_blocks_nnz == 4       # 2x2 of 64-blocks
        col_terms = fr.plan.col_terms(kmode)
        rows_used = {term[-1] for terms in col_terms for term in terms}
        assert rows_used == {0, 1}
        assert all(not terms for terms in col_terms[2:])

    def test_int8_plane_culling_is_finer_than_blocks(self):
        # One block at full quantized magnitude, one block whose weights
        # quantize to +-1: the small block populates only digit plane 0,
        # so its other plane-blocks must be culled from the plan.
        rng = np.random.default_rng(1)
        dense = np.zeros((128, 128), np.float32)
        dense[:64, :64] = rng.uniform(-1.0, 1.0, (64, 64))
        dense[0, 0] = 1.0                                  # pins amax
        dense[64:, 64:] = rng.choice([-1.0, 0.0, 1.0], (64, 64)) / 127.0
        fm = FixedMatrix.compile(dense, weight_bits=8, mode="pn", block=64,
                                 rng=rng)
        w_in = rng.uniform(-0.5, 0.5, (1, 128)).astype(np.float32)
        fr = FusedRollout(fm, w_in, mode="int8")
        width = fm.planes.pos.shape[0]
        assert fr.n_terms < fm.blocks.n_blocks_nnz * width
        # the +-1 block sits in column block 1 and uses plane 0 only
        small_di = int(np.flatnonzero((fm.blocks.block_rows == 1)
                                      & (fm.blocks.block_cols == 1))[0])
        small_planes = {w for terms in fr.plan.col_terms("int8")
                        for (di, w, _ri) in terms if di == small_di}
        assert small_planes == {0}

    def test_culled_rollout_still_exact(self):
        fm, w_in = self._block_structured("int8")
        fr = FusedRollout(fm, w_in, mode="int8")
        rng = np.random.default_rng(2)
        u = jnp.asarray(rng.standard_normal((4, 2, 1)), jnp.float32)
        got = np.asarray(fr(u))
        ref = np.asarray(rollout_int8_ref(
            u, fm.q, fm.scale, jnp.asarray(w_in),
            jnp.zeros((2, 256), jnp.float32)))
        np.testing.assert_allclose(got, ref, rtol=1e-6, atol=1e-6)


class TestReservoirStepMultiStep:
    """Satellite: reservoir_step driven over multi-step rollouts."""

    @pytest.mark.parametrize("leak", [1.0, 0.3])
    def test_step_scan_matches_step_refs(self, leak):
        rng = np.random.default_rng(0)
        dim, batch, t = 128, 3, 8
        w = (rng.standard_normal((dim, dim)) * 0.05).astype(np.float32)
        w_in = (rng.standard_normal((2, dim)) * 0.3).astype(np.float32)
        fr = FusedReservoir(w, w_in, leak=leak, block=64)
        u = jnp.asarray(rng.standard_normal((t, batch, 2)), jnp.float32)
        got = np.asarray(fr.run(u))
        want = np.asarray(rollout_fp32_ref(
            u, jnp.asarray(w), jnp.asarray(w_in),
            jnp.zeros((batch, dim), jnp.float32), leak=leak))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_step_and_rollout_kernels_agree(self):
        cfg = ESNConfig(reservoir_dim=128, element_sparsity=0.8, seed=9,
                        leak=0.6, block=64)
        p = init_esn(cfg)
        fr_step = FusedReservoir(np.asarray(p.w.dense_f32()),
                                 np.asarray(p.w_in), leak=0.6, block=64)
        fr_roll = FusedRollout(p.w, np.asarray(p.w_in), leak=0.6)
        rng = np.random.default_rng(0)
        u = jnp.asarray(rng.standard_normal((10, 2, 1)), jnp.float32)
        np.testing.assert_allclose(np.asarray(fr_step.run(u)),
                                   np.asarray(fr_roll(u)),
                                   rtol=1e-4, atol=1e-5)
