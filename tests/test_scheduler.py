"""Continuous-batching scheduler: slot pool, admission, chunked parity.

The load-bearing contract is the acceptance criterion: a chunked
scheduler rollout — slot pool, ``chunk_steps`` segments, reservoir state
carried between chunks — must be *bit-identical* to the one-shot engine
rollout of the same inputs, for states and for fused-readout
predictions, on both backends.  Bit-identity holds when the batch shapes
match (the pool rolls a fixed ``(n_slots, chunk_steps, I)`` shape and
rows never mix), so those tests pin ``n_slots`` to the request count.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.esn import (ESNConfig, fit_readout, init_esn, run_reservoir)
from repro.serve import (AsyncReservoirServer, ContinuousBatcher,
                         ReservoirEngine, RolloutRequest, ServeStats)


def _params(mode="fp32", dim=96, leak=0.7, seed=1, block=32, trained=True):
    cfg = ESNConfig(reservoir_dim=dim, element_sparsity=0.8, mode=mode,
                    leak=leak, seed=seed, block=block, output_dim=2)
    p = init_esn(cfg)
    if trained:
        rng = np.random.default_rng(seed)
        u = jnp.asarray(rng.standard_normal((50, 1)), jnp.float32)
        states = run_reservoir(p, u, engine="scan")
        y = jnp.concatenate([u, jnp.roll(u, 1)], axis=-1)
        p = fit_readout(p, states, y, lam=1e-2)
    return p


def _requests(lengths, seed=0, in_dim=1):
    rng = np.random.default_rng(seed)
    return [RolloutRequest(
                uid=i,
                inputs=rng.standard_normal((t, in_dim)).astype(np.float32))
            for i, t in enumerate(lengths)]


def _server(p, backend="xla", **kw):
    eng = ReservoirEngine(p, backend=backend, stats=ServeStats())
    kw.setdefault("chunk_time", 1.0)        # deterministic virtual clock
    return eng, AsyncReservoirServer(eng, **kw)


class TestEngineChunkAPI:
    @pytest.mark.parametrize("backend", ["xla", "pallas"])
    def test_final_state_is_last_state(self, backend):
        p = _params(trained=False)
        rng = np.random.default_rng(0)
        u = jnp.asarray(rng.standard_normal((3, 8, 1)), jnp.float32)
        states, xf = ReservoirEngine(p, backend=backend).rollout(
            u, return_final_state=True)
        np.testing.assert_array_equal(np.asarray(xf),
                                      np.asarray(states)[:, -1])

    @pytest.mark.parametrize("backend", ["xla", "pallas"])
    def test_chunk_resume_bit_identical(self, backend):
        p = _params()
        eng = ReservoirEngine(p, backend=backend)
        rng = np.random.default_rng(1)
        u = jnp.asarray(rng.standard_normal((2, 16, 1)), jnp.float32)
        full = np.asarray(eng.rollout(u))
        s1, xf = eng.rollout(u[:, :8], return_final_state=True)
        s2 = eng.rollout(u[:, 8:], x0=xf)
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(s1), np.asarray(s2)], axis=1), full)
        pfull = np.asarray(eng.predictions(u))
        p1, xf = eng.predictions(u[:, :8], return_final_state=True)
        p2 = eng.predictions(u[:, 8:], x0=xf)
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(p1), np.asarray(p2)], axis=1), pfull)

    def test_single_sequence_final_state_shape(self):
        p = _params(trained=False)
        states, xf = ReservoirEngine(p).rollout(
            jnp.ones((10, 1), jnp.float32), return_final_state=True)
        assert states.shape == (10, 96) and xf.shape == (96,)


class TestChunkedParity:
    """Acceptance: chunked scheduler == one-shot engine, bit for bit."""

    @pytest.mark.parametrize("backend", ["xla", "pallas"])
    @pytest.mark.parametrize("return_states", [True, False])
    def test_scheduler_bit_identical_to_one_shot(self, backend,
                                                 return_states):
        p = _params(mode="fp32")
        eng = ReservoirEngine(p, backend=backend, stats=ServeStats())
        n, t = 4, 24
        reqs = _requests([t] * n, seed=2)
        srv = AsyncReservoirServer(eng, n_slots=n, chunk_steps=8,
                                   return_states=return_states,
                                   chunk_time=1.0)
        for r in reqs:
            srv.submit(r, arrival_time=0.0)
        res = srv.run()
        batch = jnp.asarray(np.stack([r.inputs for r in reqs]))
        one_shot = np.asarray(eng.rollout(batch) if return_states
                              else eng.predictions(batch))
        for i, r in enumerate(reqs):
            np.testing.assert_array_equal(res[r.uid], one_shot[i])

    def test_int8_scheduler_bit_identical(self):
        p = _params(mode="int8-csd")
        eng = ReservoirEngine(p, stats=ServeStats())
        reqs = _requests([16, 16], seed=3)
        srv = AsyncReservoirServer(eng, n_slots=2, chunk_steps=4,
                                   chunk_time=1.0)
        for r in reqs:
            srv.submit(r)
        res = srv.run()
        batch = jnp.asarray(np.stack([r.inputs for r in reqs]))
        one_shot = np.asarray(eng.predictions(batch))
        for i, r in enumerate(reqs):
            np.testing.assert_array_equal(res[r.uid], one_shot[i])

    def test_ragged_lengths_match_per_request_rollout(self):
        """Mixed lengths + mid-chunk retirement: allclose vs the engine's
        own per-request rollout (batch shape differs, so fp accumulation
        may differ by ~1 ulp)."""
        p = _params()
        eng = ReservoirEngine(p, stats=ServeStats())
        reqs = _requests([5, 17, 30, 9, 12, 23], seed=4)
        srv = AsyncReservoirServer(eng, n_slots=3, chunk_steps=8,
                                   chunk_time=1.0)
        for i, r in enumerate(reqs):
            srv.submit(r, arrival_time=0.5 * i)
        res = srv.run()
        for r in reqs:
            want = np.asarray(eng.predictions(jnp.asarray(r.inputs)))
            np.testing.assert_allclose(res[r.uid], want,
                                       rtol=1e-4, atol=1e-6)


class TestAdmission:
    def test_fifo_under_full_pool(self):
        """More arrivals than slots: seats are granted strictly in
        (arrival_time, submission) order as they free up."""
        p = _params()
        eng, srv = _server(p, n_slots=2, chunk_steps=8)
        qreqs = [srv.submit(r, arrival_time=0.0)
                 for r in _requests([8] * 5, seed=5)]
        srv.run()
        admits = [q.admit_time for q in qreqs]
        assert admits == sorted(admits)
        # exactly the pool width is seated at t=0; the rest wait
        assert admits[0] == admits[1] == 0.0
        assert all(a > 0.0 for a in admits[2:])
        finishes = [q.finish_time for q in qreqs]
        assert finishes == sorted(finishes)
        assert eng.stats.admitted == 5 and eng.stats.completed == 5

    def test_late_arrival_not_admitted_early(self):
        p = _params()
        _, srv = _server(p, n_slots=2, chunk_steps=8)
        early = srv.submit(_requests([8], seed=6)[0], arrival_time=0.0)
        late = srv.submit(
            RolloutRequest(uid="late", inputs=np.ones((8, 1), np.float32)),
            arrival_time=10.0)
        srv.run()
        assert early.admit_time == 0.0
        # pool was free the whole time — the clock, not capacity, gated it
        assert late.admit_time >= 10.0

    def test_mid_flight_admit_with_zero_state(self):
        """A request seated while another sequence is mid-rollout starts
        from the zero state and serves correctly."""
        p = _params()
        eng = ReservoirEngine(p, stats=ServeStats())
        srv = AsyncReservoirServer(eng, n_slots=2, chunk_steps=8,
                                   chunk_time=1.0)
        long = srv.submit(RolloutRequest(
            uid="long", inputs=np.ones((40, 1), np.float32)),
            arrival_time=0.0)
        short = srv.submit(RolloutRequest(
            uid="short", inputs=np.ones((8, 1), np.float32)),
            arrival_time=0.0)
        mid = srv.submit(RolloutRequest(
            uid="mid", inputs=np.full((8, 1), 0.5, np.float32)),
            arrival_time=1.5)
        res = srv.run()
        # "mid" was seated after "short" retired, while "long" was live
        assert mid.admit_time > 0.0
        assert mid.admit_time < long.finish_time
        want = np.asarray(eng.predictions(
            jnp.full((8, 1), 0.5, jnp.float32)))
        np.testing.assert_allclose(res["mid"], want, rtol=1e-4, atol=1e-6)

    def test_request_x0_seeds_slot_state(self):
        p = _params()
        eng = ReservoirEngine(p, stats=ServeStats())
        srv = AsyncReservoirServer(eng, n_slots=1, chunk_steps=8,
                                   chunk_time=1.0)
        x0 = np.full((96,), 0.2, np.float32)
        u = np.ones((8, 1), np.float32)
        srv.submit(RolloutRequest(uid=0, inputs=u, x0=x0))
        res = srv.run()
        want = np.asarray(eng.predictions(
            jnp.asarray(u)[None], x0=jnp.asarray(x0)[None]))[0]
        np.testing.assert_array_equal(res[0], want)


class TestQueueStats:
    def test_queue_wait_and_ttfp_accounting(self):
        """Virtual clock with chunk_time=1: waits are exact integers."""
        p = _params()
        eng, srv = _server(p, n_slots=1, chunk_steps=8)
        q0 = srv.submit(_requests([8], seed=7)[0], arrival_time=0.0)
        q1 = srv.submit(
            RolloutRequest(uid=1, inputs=np.ones((8, 1), np.float32)),
            arrival_time=0.0)
        srv.run()
        s = eng.stats
        # q0 seats immediately; q1 waits one full chunk for the slot
        assert (q0.admit_time, q1.admit_time) == (0.0, 1.0)
        assert s.queue_wait_max_s == pytest.approx(1.0)
        assert s.mean_queue_wait_s == pytest.approx(0.5)
        # first predictions land at the end of each request's first chunk
        assert q0.first_output_time == pytest.approx(1.0)
        assert q1.first_output_time == pytest.approx(2.0)
        assert s.mean_ttfp_s == pytest.approx(1.5)
        assert s.ttfp_max_s == pytest.approx(2.0)
        assert s.enqueued == 2 and s.admitted == 2 and s.completed == 2
        assert s.chunks == 2 and s.slot_occupancy == pytest.approx(1.0)

    def test_idle_pool_fast_forwards_clock(self):
        p = _params()
        eng, srv = _server(p, n_slots=2, chunk_steps=8)
        q = srv.submit(_requests([8], seed=8)[0], arrival_time=7.25)
        srv.run()
        # no queue wait: the server jumped to the arrival instead of
        # charging idle time against the request
        assert q.admit_time == pytest.approx(7.25)
        assert eng.stats.queue_wait_max_s == pytest.approx(0.0)
        assert srv.now == pytest.approx(8.25)

    def test_occupancy_reflects_free_slots(self):
        p = _params()
        eng, srv = _server(p, n_slots=4, chunk_steps=8)
        srv.submit(_requests([8], seed=9)[0], arrival_time=0.0)
        srv.run()
        # one live slot of four for the single chunk
        assert eng.stats.slot_occupancy == pytest.approx(0.25)
        assert "occupancy" in eng.stats.render()
        assert "slot_occupancy" in eng.stats.summary()

    def test_occupancy_discounts_retiring_tail(self):
        """A sequence that finishes mid-chunk only counts its real steps —
        the zero-padded tail of its final chunk is not 'live' work."""
        p = _params()
        eng, srv = _server(p, n_slots=1, chunk_steps=16)
        srv.submit(_requests([4], seed=12)[0], arrival_time=0.0)
        srv.run()
        assert eng.stats.slot_occupancy == pytest.approx(4 / 16)

    def test_results_and_drained_flag(self):
        p = _params()
        _, srv = _server(p, n_slots=2, chunk_steps=8)
        assert srv.drained and not srv.step()
        srv.submit(_requests([4], seed=10)[0])
        assert not srv.drained
        res = srv.run()
        assert srv.drained and set(res) == {0}
        assert res[0].shape == (4, 2)


class TestDeadlines:
    def test_expired_queued_request_dropped(self):
        """Pool of one: the second request's deadline passes while it
        queues, so it is dropped — counted, never seated — and the slot
        goes to the third request instead."""
        p = _params()
        eng, srv = _server(p, n_slots=1, chunk_steps=8)
        held = srv.submit(_requests([16], seed=20)[0], arrival_time=0.0)
        doomed = srv.submit(
            RolloutRequest(uid="doomed", inputs=np.ones((8, 1), np.float32)),
            arrival_time=0.0, deadline=0.5)
        patient = srv.submit(
            RolloutRequest(uid="patient", inputs=np.ones((8, 1), np.float32)),
            arrival_time=0.0)
        res = srv.run()
        assert "doomed" not in res
        assert doomed.admit_time is None and doomed.finish_time is None
        assert set(res) == {held.uid, "patient"}
        s = eng.stats
        assert s.timed_out == 1
        assert s.enqueued == 3 and s.admitted == 2 and s.completed == 2
        assert "1 timed out" in s.render()

    def test_deadline_met_is_served(self):
        p = _params()
        _, srv = _server(p, n_slots=1, chunk_steps=8)
        q = srv.submit(_requests([8], seed=21)[0], arrival_time=0.0,
                       deadline=5.0)
        res = srv.run()
        assert q.finish_time is not None and 0 in res

    def test_admitted_request_runs_past_deadline(self):
        """A deadline bounds the queue wait, not the service time: once
        seated, the rollout completes even if it outlives the deadline."""
        p = _params()
        _, srv = _server(p, n_slots=1, chunk_steps=8)
        q = srv.submit(_requests([32], seed=22)[0], arrival_time=0.0,
                       deadline=1.5)            # 4 chunks > deadline
        res = srv.run()
        assert q.finish_time == pytest.approx(4.0)
        assert res[0].shape == (32, 2)

    def test_all_expired_queue_drains(self):
        """A queue holding only expired requests drains without running
        chunks for them (and run() terminates)."""
        p = _params()
        eng, srv = _server(p, n_slots=1, chunk_steps=8)
        srv.submit(_requests([24], seed=23)[0], arrival_time=0.0)
        for i in range(3):
            srv.submit(RolloutRequest(
                uid=f"late{i}", inputs=np.ones((8, 1), np.float32)),
                arrival_time=0.0, deadline=1.0)
        res = srv.run()
        assert set(res) == {0}
        assert eng.stats.timed_out == 3
        # only the first request's chunks ran
        assert eng.stats.chunks == 3


class TestContinuousBatcherUnit:
    def test_slot_reuse_and_retire(self):
        p = _params()
        eng = ReservoirEngine(p, stats=ServeStats())
        cb = ContinuousBatcher(eng, n_slots=2, chunk_steps=4)
        from repro.serve.scheduler import QueuedRequest
        a = QueuedRequest(RolloutRequest(
            uid="a", inputs=np.ones((4, 1), np.float32)))
        b = QueuedRequest(RolloutRequest(
            uid="b", inputs=np.ones((12, 1), np.float32)))
        assert cb.admit(a) == 0 and cb.admit(b) == 1
        assert not cb.has_free_slot() and cb.live == 2
        retired, real = cb.run_chunk()
        assert [q.uid for q, _ in retired] == ["a"]
        assert real == 8                        # both slots fully live
        assert cb.has_free_slot() and cb.live == 1
        c = QueuedRequest(RolloutRequest(
            uid="c", inputs=np.ones((4, 1), np.float32)))
        assert cb.admit(c) == 0                 # freed slot is reused
        retired, real = cb.run_chunk()
        assert [q.uid for q, _ in retired] == ["c"]
        retired, real = cb.run_chunk()
        (qb, out_b), = retired
        assert qb.uid == "b" and out_b.shape == (12, 2)
        assert real == 4                        # b's last 4 of 12 steps
