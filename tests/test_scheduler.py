"""Continuous-batching scheduler: slot pool, admission, chunked parity.

The load-bearing contract is the acceptance criterion: a chunked
scheduler rollout — slot pool, ``chunk_steps`` segments, reservoir state
carried between chunks — must be *bit-identical* to the one-shot engine
rollout of the same inputs, for states and for fused-readout
predictions, on both backends.  Bit-identity holds when the batch shapes
match (the pool rolls a fixed ``(n_slots, chunk_steps, I)`` shape and
rows never mix), so those tests pin ``n_slots`` to the request count.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.esn import (ESNConfig, fit_readout, init_esn, run_reservoir)
from repro.serve import (AsyncReservoirServer, ContinuousBatcher,
                         ReservoirEngine, RolloutRequest, ServeStats,
                         SubmitSpec)


def _params(mode="fp32", dim=96, leak=0.7, seed=1, block=32, trained=True):
    cfg = ESNConfig(reservoir_dim=dim, element_sparsity=0.8, mode=mode,
                    leak=leak, seed=seed, block=block, output_dim=2)
    p = init_esn(cfg)
    if trained:
        rng = np.random.default_rng(seed)
        u = jnp.asarray(rng.standard_normal((50, 1)), jnp.float32)
        states = run_reservoir(p, u, engine="scan")
        y = jnp.concatenate([u, jnp.roll(u, 1)], axis=-1)
        p = fit_readout(p, states, y, lam=1e-2)
    return p


def _requests(lengths, seed=0, in_dim=1):
    rng = np.random.default_rng(seed)
    return [SubmitSpec(rng.standard_normal((t, in_dim)).astype(np.float32),
                       uid=i)
            for i, t in enumerate(lengths)]


def _server(p, backend="xla", **kw):
    eng = ReservoirEngine(p, backend=backend, stats=ServeStats())
    kw.setdefault("chunk_time", 1.0)        # deterministic virtual clock
    return eng, AsyncReservoirServer(eng, **kw)


class TestEngineChunkAPI:
    @pytest.mark.parametrize("backend", ["xla", "pallas"])
    def test_final_state_is_last_state(self, backend):
        p = _params(trained=False)
        rng = np.random.default_rng(0)
        u = jnp.asarray(rng.standard_normal((3, 8, 1)), jnp.float32)
        res = ReservoirEngine(p, backend=backend).submit(
            SubmitSpec(u, want_states=True))
        np.testing.assert_array_equal(np.asarray(res.final_state),
                                      np.asarray(res.states)[:, -1])

    @pytest.mark.parametrize("backend", ["xla", "pallas"])
    def test_chunk_resume_bit_identical(self, backend):
        p = _params()
        eng = ReservoirEngine(p, backend=backend)
        rng = np.random.default_rng(1)
        u = jnp.asarray(rng.standard_normal((2, 16, 1)), jnp.float32)
        z = jnp.zeros((2, 96), jnp.float32)
        full = np.asarray(eng.rollout(u))
        s1, xf = eng.run_segment(u[:, :8], z, want_states=True)
        s2, _ = eng.run_segment(u[:, 8:], xf, want_states=True)
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(s1), np.asarray(s2)], axis=1), full)
        pfull = np.asarray(eng.predictions(u))
        p1, xf = eng.run_segment(u[:, :8], z)
        p2, _ = eng.run_segment(u[:, 8:], xf)
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(p1), np.asarray(p2)], axis=1), pfull)

    def test_single_sequence_final_state_shape(self):
        p = _params(trained=False)
        res = ReservoirEngine(p).submit(
            SubmitSpec(jnp.ones((10, 1), jnp.float32), want_states=True))
        assert res.states.shape == (10, 96)
        assert res.final_state.shape == (96,)
        assert res.output is res.states and res.preds is None


class TestChunkedParity:
    """Acceptance: chunked scheduler == one-shot engine, bit for bit."""

    @pytest.mark.parametrize("backend", ["xla", "pallas"])
    @pytest.mark.parametrize("want_states", [True, False])
    def test_scheduler_bit_identical_to_one_shot(self, backend,
                                                 want_states):
        p = _params(mode="fp32")
        eng = ReservoirEngine(p, backend=backend, stats=ServeStats())
        n, t = 4, 24
        reqs = _requests([t] * n, seed=2)
        srv = AsyncReservoirServer(eng, n_slots=n, chunk_steps=8,
                                   want_states=want_states,
                                   chunk_time=1.0)
        for r in reqs:
            srv.submit(r, arrival_time=0.0)
        res = srv.run()
        batch = jnp.asarray(np.stack([r.inputs for r in reqs]))
        one_shot = np.asarray(eng.rollout(batch) if want_states
                              else eng.predictions(batch))
        for i, r in enumerate(reqs):
            np.testing.assert_array_equal(res[r.uid].output, one_shot[i])

    def test_int8_scheduler_bit_identical(self):
        p = _params(mode="int8-csd")
        eng = ReservoirEngine(p, stats=ServeStats())
        reqs = _requests([16, 16], seed=3)
        srv = AsyncReservoirServer(eng, n_slots=2, chunk_steps=4,
                                   chunk_time=1.0)
        for r in reqs:
            srv.submit(r)
        res = srv.run()
        batch = jnp.asarray(np.stack([r.inputs for r in reqs]))
        one_shot = np.asarray(eng.predictions(batch))
        for i, r in enumerate(reqs):
            np.testing.assert_array_equal(res[r.uid].output, one_shot[i])

    def test_ragged_lengths_match_per_request_rollout(self):
        """Mixed lengths + mid-chunk retirement: allclose vs the engine's
        own per-request rollout (batch shape differs, so fp accumulation
        may differ by ~1 ulp)."""
        p = _params()
        eng = ReservoirEngine(p, stats=ServeStats())
        reqs = _requests([5, 17, 30, 9, 12, 23], seed=4)
        srv = AsyncReservoirServer(eng, n_slots=3, chunk_steps=8,
                                   chunk_time=1.0)
        for i, r in enumerate(reqs):
            srv.submit(r, arrival_time=0.5 * i)
        res = srv.run()
        for r in reqs:
            want = np.asarray(eng.predictions(jnp.asarray(r.inputs)))
            np.testing.assert_allclose(res[r.uid].output, want,
                                       rtol=1e-4, atol=1e-6)


class TestAdmission:
    def test_fifo_under_full_pool(self):
        """More arrivals than slots: seats are granted strictly in
        (arrival_time, submission) order as they free up."""
        p = _params()
        eng, srv = _server(p, n_slots=2, chunk_steps=8)
        qreqs = [srv.submit(r, arrival_time=0.0)
                 for r in _requests([8] * 5, seed=5)]
        srv.run()
        admits = [q.admit_time for q in qreqs]
        assert admits == sorted(admits)
        # exactly the pool width is seated at t=0; the rest wait
        assert admits[0] == admits[1] == 0.0
        assert all(a > 0.0 for a in admits[2:])
        finishes = [q.finish_time for q in qreqs]
        assert finishes == sorted(finishes)
        assert eng.stats.admitted == 5 and eng.stats.completed == 5

    def test_late_arrival_not_admitted_early(self):
        p = _params()
        _, srv = _server(p, n_slots=2, chunk_steps=8)
        early = srv.submit(_requests([8], seed=6)[0], arrival_time=0.0)
        late = srv.submit(
            SubmitSpec(np.ones((8, 1), np.float32), uid="late"),
            arrival_time=10.0)
        srv.run()
        assert early.admit_time == 0.0
        # pool was free the whole time — the clock, not capacity, gated it
        assert late.admit_time >= 10.0

    def test_mid_flight_admit_with_zero_state(self):
        """A request seated while another sequence is mid-rollout starts
        from the zero state and serves correctly."""
        p = _params()
        eng = ReservoirEngine(p, stats=ServeStats())
        srv = AsyncReservoirServer(eng, n_slots=2, chunk_steps=8,
                                   chunk_time=1.0)
        long = srv.submit(SubmitSpec(
            np.ones((40, 1), np.float32), uid="long"), arrival_time=0.0)
        short = srv.submit(SubmitSpec(
            np.ones((8, 1), np.float32), uid="short"), arrival_time=0.0)
        mid = srv.submit(SubmitSpec(
            np.full((8, 1), 0.5, np.float32), uid="mid"), arrival_time=1.5)
        res = srv.run()
        assert short.uid == "short"
        # "mid" was seated after "short" retired, while "long" was live
        assert mid.admit_time > 0.0
        assert mid.admit_time < long.finish_time
        want = np.asarray(eng.predictions(
            jnp.full((8, 1), 0.5, jnp.float32)))
        np.testing.assert_allclose(res["mid"].output, want,
                                   rtol=1e-4, atol=1e-6)

    def test_request_x0_seeds_slot_state(self):
        p = _params()
        eng = ReservoirEngine(p, stats=ServeStats())
        srv = AsyncReservoirServer(eng, n_slots=1, chunk_steps=8,
                                   chunk_time=1.0)
        x0 = np.full((96,), 0.2, np.float32)
        u = np.ones((8, 1), np.float32)
        srv.submit(SubmitSpec(u, uid=0, x0=x0))
        res = srv.run()
        want = np.asarray(eng.predictions(
            jnp.asarray(u)[None], x0=jnp.asarray(x0)[None]))[0]
        np.testing.assert_array_equal(res[0].output, want)


class TestQueueStats:
    def test_queue_wait_and_ttfp_accounting(self):
        """Virtual clock with chunk_time=1: waits are exact integers."""
        p = _params()
        eng, srv = _server(p, n_slots=1, chunk_steps=8)
        q0 = srv.submit(_requests([8], seed=7)[0], arrival_time=0.0)
        q1 = srv.submit(
            SubmitSpec(np.ones((8, 1), np.float32), uid=1),
            arrival_time=0.0)
        srv.run()
        s = eng.stats
        # q0 seats immediately; q1 waits one full chunk for the slot
        assert (q0.admit_time, q1.admit_time) == (0.0, 1.0)
        assert s.queue_wait_max_s == pytest.approx(1.0)
        assert s.mean_queue_wait_s == pytest.approx(0.5)
        # first predictions land at the end of each request's first chunk
        assert q0.first_output_time == pytest.approx(1.0)
        assert q1.first_output_time == pytest.approx(2.0)
        assert s.mean_ttfp_s == pytest.approx(1.5)
        assert s.ttfp_max_s == pytest.approx(2.0)
        assert s.enqueued == 2 and s.admitted == 2 and s.completed == 2
        assert s.chunks == 2 and s.slot_occupancy == pytest.approx(1.0)

    def test_idle_pool_fast_forwards_clock(self):
        p = _params()
        eng, srv = _server(p, n_slots=2, chunk_steps=8)
        q = srv.submit(_requests([8], seed=8)[0], arrival_time=7.25)
        srv.run()
        # no queue wait: the server jumped to the arrival instead of
        # charging idle time against the request
        assert q.admit_time == pytest.approx(7.25)
        assert eng.stats.queue_wait_max_s == pytest.approx(0.0)
        assert srv.now == pytest.approx(8.25)

    def test_occupancy_reflects_free_slots(self):
        p = _params()
        eng, srv = _server(p, n_slots=4, chunk_steps=8)
        srv.submit(_requests([8], seed=9)[0], arrival_time=0.0)
        srv.run()
        # one live slot of four for the single chunk
        assert eng.stats.slot_occupancy == pytest.approx(0.25)
        assert "occupancy" in eng.stats.render()
        assert "slot_occupancy" in eng.stats.summary()

    def test_occupancy_discounts_retiring_tail(self):
        """A sequence that finishes mid-chunk only counts its real steps —
        the zero-padded tail of its final chunk is not 'live' work."""
        p = _params()
        eng, srv = _server(p, n_slots=1, chunk_steps=16)
        srv.submit(_requests([4], seed=12)[0], arrival_time=0.0)
        srv.run()
        assert eng.stats.slot_occupancy == pytest.approx(4 / 16)

    def test_results_and_drained_flag(self):
        p = _params()
        _, srv = _server(p, n_slots=2, chunk_steps=8)
        assert srv.drained and not srv.step()
        srv.submit(_requests([4], seed=10)[0])
        assert not srv.drained
        res = srv.run()
        assert srv.drained and set(res) == {0}
        assert res[0].output.shape == (4, 2)
        assert res[0].timings["latency_s"] > 0.0


class TestDeadlines:
    def test_expired_queued_request_dropped(self):
        """Pool of one: the second request's deadline passes while it
        queues, so it is dropped — counted, never seated — and the slot
        goes to the third request instead."""
        p = _params()
        eng, srv = _server(p, n_slots=1, chunk_steps=8)
        held = srv.submit(_requests([16], seed=20)[0], arrival_time=0.0)
        doomed = srv.submit(
            SubmitSpec(np.ones((8, 1), np.float32), uid="doomed",
                       deadline=0.5),
            arrival_time=0.0)
        patient = srv.submit(
            SubmitSpec(np.ones((8, 1), np.float32), uid="patient"),
            arrival_time=0.0)
        res = srv.run()
        assert "doomed" not in res
        assert doomed.admit_time is None and doomed.finish_time is None
        assert set(res) == {held.uid, "patient"}
        s = eng.stats
        assert s.timed_out == 1
        assert s.enqueued == 3 and s.admitted == 2 and s.completed == 2
        assert "1 timed out" in s.render()

    def test_deadline_met_is_served(self):
        p = _params()
        _, srv = _server(p, n_slots=1, chunk_steps=8)
        q = srv.submit(_requests([8], seed=21)[0], arrival_time=0.0,
                       deadline=5.0)
        res = srv.run()
        assert q.finish_time is not None and 0 in res

    def test_admitted_request_runs_past_deadline(self):
        """A deadline bounds the queue wait, not the service time: once
        seated, the rollout completes even if it outlives the deadline."""
        p = _params()
        _, srv = _server(p, n_slots=1, chunk_steps=8)
        q = srv.submit(_requests([32], seed=22)[0], arrival_time=0.0,
                       deadline=1.5)            # 4 chunks > deadline
        res = srv.run()
        assert q.finish_time == pytest.approx(4.0)
        assert res[0].output.shape == (32, 2)

    def test_all_expired_queue_drains(self):
        """A queue holding only expired requests drains without running
        chunks for them (and run() terminates)."""
        p = _params()
        eng, srv = _server(p, n_slots=1, chunk_steps=8)
        srv.submit(_requests([24], seed=23)[0], arrival_time=0.0)
        for i in range(3):
            srv.submit(SubmitSpec(
                np.ones((8, 1), np.float32), uid=f"late{i}", deadline=1.0),
                arrival_time=0.0)
        res = srv.run()
        assert set(res) == {0}
        assert eng.stats.timed_out == 3
        # only the first request's chunks ran
        assert eng.stats.chunks == 3


class TestContinuousBatcherUnit:
    def test_slot_reuse_and_retire(self):
        p = _params()
        eng = ReservoirEngine(p, stats=ServeStats())
        cb = ContinuousBatcher(eng, n_slots=2, chunk_steps=4)
        from repro.serve.scheduler import QueuedRequest
        a = QueuedRequest(RolloutRequest(
            uid="a", inputs=np.ones((4, 1), np.float32)))
        b = QueuedRequest(RolloutRequest(
            uid="b", inputs=np.ones((12, 1), np.float32)))
        assert cb.admit(a) == 0 and cb.admit(b) == 1
        assert not cb.has_free_slot() and cb.live == 2
        retired, real = cb.run_chunk()
        assert [q.uid for q, _ in retired] == ["a"]
        assert real == 8                        # both slots fully live
        assert cb.has_free_slot() and cb.live == 1
        c = QueuedRequest(RolloutRequest(
            uid="c", inputs=np.ones((4, 1), np.float32)))
        assert cb.admit(c) == 0                 # freed slot is reused
        retired, real = cb.run_chunk()
        assert [q.uid for q, _ in retired] == ["c"]
        retired, real = cb.run_chunk()
        (qb, out_b), = retired
        assert qb.uid == "b" and out_b.shape == (12, 2)
        assert real == 4                        # b's last 4 of 12 steps


class TestRecompilationGuard:
    @pytest.mark.parametrize("backend", ["xla", "pallas"])
    def test_n_chunks_trace_once_per_shape(self, backend):
        """Rolling N chunks through the async server must trace the
        rollout exactly once per (shape, regime) — a cache-key regression
        that recompiles per chunk fails this immediately."""
        p = _params()
        eng, srv = _server(p, backend=backend, n_slots=4, chunk_steps=4)
        for r in _requests([16, 16, 16, 16, 16, 16], seed=5):
            srv.submit(r)
        srv.run()
        assert eng.stats.chunks >= 6            # plenty of chunks ran...
        counts = eng.trace_counts
        assert counts, "trace counter never ticked"
        assert all(n == 1 for n in counts.values()), dict(counts)
        assert len(counts) == 1                 # ...over ONE chunk shape

    def test_trace_count_grows_only_on_new_shape(self):
        p = _params()
        eng = ReservoirEngine(p, stats=ServeStats())
        u1 = jnp.zeros((2, 4, 1), jnp.float32)
        z = jnp.zeros((2, 96), jnp.float32)
        eng.run_segment(u1, z)
        eng.run_segment(u1, z)
        assert sum(eng.trace_counts.values()) == 1
        eng.run_segment(jnp.zeros((2, 8, 1), jnp.float32), z)
        assert sum(eng.trace_counts.values()) == 2


class TestZeroCopyServing:
    def test_host_syncs_only_at_retirement(self):
        """The zero-copy hot loop defers every device->host transfer to
        slot retirement: chunks that retire nothing sync nothing."""
        p = _params()
        eng = ReservoirEngine(p, stats=ServeStats())
        cb = ContinuousBatcher(eng, n_slots=2, chunk_steps=4,
                               zero_copy=True)
        from repro.serve.scheduler import QueuedRequest
        cb.admit(QueuedRequest(RolloutRequest(
            uid="a", inputs=np.ones((12, 1), np.float32))))
        cb.admit(QueuedRequest(RolloutRequest(
            uid="b", inputs=np.ones((8, 1), np.float32))))
        retired, _ = cb.run_chunk()             # nobody finishes...
        assert not retired
        assert cb.host_syncs == 0               # ...so nothing synced
        retired, _ = cb.run_chunk()             # b retires at step 8
        assert [q.uid for q, _ in retired] == ["b"]
        assert cb.host_syncs == 2               # b's two chunk buffers
        retired, _ = cb.run_chunk()             # a retires at step 12
        assert [q.uid for q, _ in retired] == ["a"]
        # a's first two buffers were already synced by b's retirement
        # (shared chunk buffers sync at most once); only chunk 3 is new
        assert cb.host_syncs == 3

    def test_shared_chunk_buffer_syncs_once(self):
        p = _params()
        eng, srv = _server(p, n_slots=2, chunk_steps=4, zero_copy=True)
        for r in _requests([8, 8], seed=6):     # same slots, same chunks
            srv.submit(r)
        res = srv.run()
        assert len(res) == 2
        # 2 chunks ran; both retirements share the same 2 buffers
        assert srv.batcher.host_syncs == 2
        assert srv.batcher.host_syncs <= eng.stats.chunks

    def test_zero_copy_output_matches_legacy_path(self):
        p = _params()
        outs = {}
        for zero_copy in (False, True):
            eng = ReservoirEngine(p, stats=ServeStats())
            batcher = ContinuousBatcher(eng, n_slots=3, chunk_steps=4,
                                        zero_copy=zero_copy)
            srv = AsyncReservoirServer(eng, batcher=batcher, chunk_time=1.0)
            for r in _requests([10, 7, 13], seed=7):
                srv.submit(r)
            outs[zero_copy] = srv.run()
        assert set(outs[True]) == set(outs[False])
        for uid in outs[True]:
            assert (outs[True][uid].output == outs[False][uid].output).all()

    def test_sharded_server_zero_copy_passthrough(self):
        """The sharded server exposes the same zero_copy knob and serves
        identical outputs either way (carried across a shrink rebuild
        via the batcher's resolved flag)."""
        from repro.dist import (DistributedReservoirServer,
                                ShardedReservoirEngine)
        p = _params()
        outs = {}
        for zc in (False, True):
            eng = ShardedReservoirEngine(p, n_shards=1, stats=ServeStats())
            srv = DistributedReservoirServer(
                eng, slots_per_shard=2, chunk_steps=4, chunk_time=1.0,
                zero_copy=zc, stats=ServeStats())
            assert srv.batcher.zero_copy is zc
            for r in _requests([10, 6, 7], seed=9):
                srv.submit(r)
            outs[zc] = srv.run()
        assert set(outs[True]) == set(outs[False])
        for uid in outs[True]:
            assert (outs[True][uid].output == outs[False][uid].output).all()

    def test_shrink_snapshot_survives_host_input_mutation(self):
        """Elastic shrink must carry a sequence's remaining inputs from
        the device-resident lane, not the host buffer — the zero-copy
        contract frees the caller's array the moment admit() uploads it."""
        from repro.dist import (DistributedReservoirServer,
                                ShardedReservoirEngine)
        p = _params()
        rng = np.random.default_rng(11)
        inputs = rng.standard_normal((24, 1)).astype(np.float32)

        def serve(mutate):
            buf = inputs.copy()
            eng = ShardedReservoirEngine(p, n_shards=1, stats=ServeStats())
            srv = DistributedReservoirServer(
                eng, slots_per_shard=1, chunk_steps=4, chunk_time=1.0,
                zero_copy=True, stats=ServeStats())
            srv.submit(SubmitSpec(buf, uid="m"))
            srv.step()                          # one chunk consumed
            if mutate:
                buf[:] = 999.0                  # host buffer is dead
            srv.shrink(0)                       # snapshot + re-admission
            return np.asarray(srv.run()["m"].output)

        clean = serve(mutate=False)
        mutated = serve(mutate=True)
        assert (clean == mutated).all()

    def test_deferred_calls_flagged_in_stats(self):
        p = _params()
        eng, srv = _server(p, n_slots=2, chunk_steps=4, zero_copy=True)
        for r in _requests([8, 8], seed=10):
            srv.submit(r)
        srv.run()
        assert eng.stats.deferred_calls == eng.stats.chunks > 0
        assert "deferred_calls" in eng.stats.summary()
        # legacy path records fully-synced calls, never flags
        eng2, srv2 = _server(p, n_slots=2, chunk_steps=4, zero_copy=False)
        for r in _requests([8, 8], seed=10):
            srv2.submit(r)
        srv2.run()
        assert eng2.stats.deferred_calls == 0
        assert "deferred_calls" not in eng2.stats.summary()

    def test_device_resident_inputs_single_upload(self):
        """Admission moves the request's whole input to the device once;
        run_chunk never touches the host copy again (mutating it after
        admission must not change the output)."""
        p = _params()
        eng = ReservoirEngine(p, stats=ServeStats())
        from repro.serve.scheduler import QueuedRequest
        rng = np.random.default_rng(8)
        inputs = rng.standard_normal((8, 1)).astype(np.float32)
        ref = eng.predictions(jnp.asarray(inputs)[None])[0]
        cb = ContinuousBatcher(eng, n_slots=1, chunk_steps=4,
                               zero_copy=True)
        q = QueuedRequest(RolloutRequest(uid="z", inputs=inputs))
        cb.admit(q)
        inputs[:] = 999.0                       # host buffer is dead now
        retired, _ = cb.run_chunk()
        assert not retired
        (qr, out), = cb.run_chunk()[0]
        assert qr.uid == "z"
        assert np.allclose(out, np.asarray(ref))


class TestServeStatsZeroDivision:
    def test_all_timed_out_summary_and_render(self):
        """Zero requests completed (all expired in the queue): every
        derived metric must come out 0, not raise ZeroDivisionError."""
        s = ServeStats()
        for _ in range(3):
            s.record_enqueue()
            s.record_timeout()
        assert s.admitted == s.completed == s.first_outputs == 0
        assert s.mean_queue_wait_s == 0.0
        assert s.mean_ttfp_s == 0.0
        assert s.steps_per_sec == 0.0
        assert s.goodput_steps_per_sec == 0.0
        assert s.padding_efficiency == 1.0
        assert s.slot_occupancy == 1.0
        summary = s.summary()
        assert summary["timed_out"] == 3 and summary["mean_ttfp_ms"] == 0.0
        assert "3 timed out" in s.render()

    def test_fresh_stats_render(self):
        s = ServeStats()
        assert s.summary()["steps_per_sec"] == 0.0
        assert isinstance(s.render(), str)

    def test_merge_of_empty_and_zero_parts(self):
        merged = ServeStats.merge([])
        assert merged.calls == 0 and merged.latency_ewma_s == 0.0
        assert isinstance(merged.render(), str)
        merged = ServeStats.merge([ServeStats(), ServeStats()])
        assert merged.mean_ttfp_s == 0.0 and merged.mean_queue_wait_s == 0.0
        assert isinstance(merged.summary(), dict)

    def test_all_timed_out_through_real_server(self):
        p = _params()
        eng, srv = _server(p, n_slots=1, chunk_steps=4)
        # one seated request keeps the pool busy while the rest expire
        srv.submit(SubmitSpec(np.ones((24, 1), np.float32), uid=0),
                   arrival_time=0.0)
        for i in range(3):
            srv.submit(SubmitSpec(
                np.ones((8, 1), np.float32), uid=f"late{i}", deadline=0.5),
                arrival_time=0.0)
        res = srv.run()
        assert set(res) == {0}
        st = srv.stats
        assert st.timed_out == 3 and st.completed == 1
        assert st.first_outputs == 1            # honest ttfp denominator
        assert st.mean_ttfp_s >= 0.0
        assert isinstance(st.render(), str)

    def test_ttfp_mean_uses_first_outputs_not_admitted(self):
        s = ServeStats()
        s.record_admission(1.0)
        s.record_admission(1.0)                 # two seated...
        s.record_first_output(4.0)              # ...only one produced output
        assert s.first_outputs == 1
        assert s.mean_ttfp_s == 4.0             # not 2.0
