"""The SubmitSpec migration contract.

Two halves: (a) the deprecated boolean-twin kwargs still work for one
release and warn, answering exactly what they used to; (b) a grep-style
lint pins that no internal caller (src/, examples/, benchmarks/) still
passes one — the shims exist for *external* callers only.
"""

import pathlib
import re
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.esn import ESNConfig, fit_readout, init_esn, run_reservoir
from repro.serve import (AsyncReservoirServer, ReservoirEngine,
                         RolloutRequest, RolloutResult, ServeStats,
                         SubmitSpec)

REPO = pathlib.Path(__file__).resolve().parent.parent
# A kwarg *pass* is `return_xxx=value`: no space before `=` (statement
# assignments in the shim bodies have one — PEP8), value not `...` (the
# shims' own warning strings).  Doc lines carry ``markup`` and are skipped.
DEPRECATED = re.compile(
    r"\breturn_(?:final_state|states|preds|final)=(?!\.\.\.)")


def _params():
    cfg = ESNConfig(reservoir_dim=64, element_sparsity=0.8, mode="fp32",
                    leak=0.7, seed=3, block=32, output_dim=2)
    p = init_esn(cfg)
    rng = np.random.default_rng(3)
    u = jnp.asarray(rng.standard_normal((40, 1)), jnp.float32)
    states = run_reservoir(p, u, engine="scan")
    y = jnp.concatenate([u, jnp.roll(u, 1)], axis=-1)
    return fit_readout(p, states, y, lam=1e-2)


class TestNoInternalDeprecatedCallers:
    """CI lint: internal code must be fully on the SubmitSpec surface."""

    @pytest.mark.parametrize("tree", ["src", "examples", "benchmarks"])
    def test_tree_is_clean(self, tree):
        offenders = []
        for path in sorted((REPO / tree).rglob("*.py")):
            for n, line in enumerate(
                    path.read_text().splitlines(), start=1):
                # def-sites of the shims themselves declare the kwarg
                # with a _UNSET default; only *passing* a value is a
                # migration miss
                if (DEPRECATED.search(line) and "_UNSET" not in line
                        and "``" not in line):
                    offenders.append(f"{path.relative_to(REPO)}:{n}: "
                                     f"{line.strip()}")
        assert not offenders, (
            "deprecated return_* kwargs passed by internal callers:\n"
            + "\n".join(offenders))


class TestDeprecatedShims:
    def test_rollout_final_state_twin_warns_and_matches(self):
        p = _params()
        eng = ReservoirEngine(p)
        u = jnp.ones((2, 8, 1), jnp.float32)
        with pytest.warns(DeprecationWarning, match="run_segment"):
            states, xf = eng.rollout(u, return_final_state=True)
        res = eng.submit(SubmitSpec(u, want_states=True))
        np.testing.assert_array_equal(np.asarray(states),
                                      np.asarray(res.states))
        np.testing.assert_array_equal(np.asarray(xf),
                                      np.asarray(res.final_state))

    def test_predictions_final_state_twin_warns(self):
        p = _params()
        eng = ReservoirEngine(p)
        u = jnp.ones((2, 8, 1), jnp.float32)
        with pytest.warns(DeprecationWarning, match="run_segment"):
            preds, xf = eng.predictions(u, return_final_state=True)
        assert preds.shape == (2, 8, 2) and xf.shape == (2, 64)

    def test_server_rolloutrequest_submit_warns_answers_raw(self):
        p = _params()
        eng = ReservoirEngine(p, stats=ServeStats())
        srv = AsyncReservoirServer(eng, n_slots=1, chunk_steps=8,
                                   chunk_time=1.0)
        with pytest.warns(DeprecationWarning, match="SubmitSpec"):
            srv.submit(RolloutRequest(
                uid="old", inputs=np.ones((8, 1), np.float32)))
        out = srv.run()["old"]
        # legacy submissions keep the bare-array contract
        assert isinstance(out, np.ndarray) and out.shape == (8, 2)

    def test_server_return_states_ctor_warns(self):
        p = _params()
        eng = ReservoirEngine(p, stats=ServeStats())
        with pytest.warns(DeprecationWarning, match="want_states"):
            srv = AsyncReservoirServer(eng, n_slots=1, chunk_steps=8,
                                       chunk_time=1.0, return_states=True)
        assert srv.batcher.want_states is True
        assert srv.batcher.return_states is True    # silent alias

    def test_spec_and_legacy_agree_bitwise(self):
        """Same request through both surfaces: identical bytes out."""
        p = _params()
        rng = np.random.default_rng(5)
        u = rng.standard_normal((16, 1)).astype(np.float32)

        eng1 = ReservoirEngine(p, stats=ServeStats())
        srv1 = AsyncReservoirServer(eng1, n_slots=2, chunk_steps=8,
                                    chunk_time=1.0)
        srv1.submit(SubmitSpec(u, uid="x"))
        new = srv1.run()["x"]
        assert isinstance(new, RolloutResult)

        eng2 = ReservoirEngine(p, stats=ServeStats())
        srv2 = AsyncReservoirServer(eng2, n_slots=2, chunk_steps=8,
                                    chunk_time=1.0)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            srv2.submit(RolloutRequest(uid="x", inputs=u))
        old = srv2.run()["x"]
        np.testing.assert_array_equal(np.asarray(new.output), old)

    def test_want_states_none_needs_readout(self):
        cfg = ESNConfig(reservoir_dim=64, element_sparsity=0.8, mode="fp32",
                        leak=0.7, seed=3, block=32, output_dim=2)
        eng = ReservoirEngine(init_esn(cfg))
        u = jnp.ones((8, 1), jnp.float32)
        # auto mode falls back to states without a readout...
        res = eng.submit(SubmitSpec(u))
        assert res.states is not None and res.preds is None
        # ...but an explicit predictions ask fails loudly
        with pytest.raises(ValueError, match="readout not trained"):
            eng.submit(SubmitSpec(u, want_states=False))
