"""Substrate tests: optimizer, data, checkpoint, compression, runtime."""

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.data import pipeline
from repro.optim import adamw, compression
from repro.runtime import elastic


class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                                weight_decay=0.0, clip_norm=10.0)
        params = {"w": jnp.array([5.0, -3.0])}
        opt = adamw.init_state(params)
        target = jnp.array([1.0, 2.0])

        @jax.jit
        def step(params, opt):
            grads = jax.grad(
                lambda p: jnp.sum((p["w"] - target) ** 2))(params)
            return adamw.apply_updates(params, grads, opt, cfg)

        for _ in range(200):
            params, opt, metrics = step(params, opt)
        np.testing.assert_allclose(np.asarray(params["w"]), target, atol=1e-2)

    def test_clip_bounds_update(self):
        g = {"w": jnp.full((10,), 1e6)}
        clipped, norm = adamw.clip_by_global_norm(g, 1.0)
        assert float(adamw.global_norm(clipped)) <= 1.0 + 1e-5
        assert float(norm) > 1e6

    def test_schedule_warmup_and_decay(self):
        cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
        assert float(adamw.schedule(cfg, jnp.array(0))) == 0.0
        assert float(adamw.schedule(cfg, jnp.array(10))) == pytest.approx(1.0)
        assert float(adamw.schedule(cfg, jnp.array(100))) == pytest.approx(
            cfg.min_lr_ratio, rel=1e-3)


class TestData:
    def test_lm_batch_deterministic_and_sharded(self):
        cfg = pipeline.LMStreamConfig(vocab_size=97, seq_len=32,
                                      global_batch=8, seed=3)
        a = pipeline.lm_batch(cfg, step=5)
        b = pipeline.lm_batch(cfg, step=5)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        s0 = pipeline.lm_batch(cfg, step=5, shard=0, n_shards=2)
        s1 = pipeline.lm_batch(cfg, step=5, shard=1, n_shards=2)
        assert s0["tokens"].shape == (4, 33)
        assert not np.array_equal(s0["tokens"], s1["tokens"])
        assert (a["tokens"] < 97).all() and (a["tokens"] >= 0).all()

    def test_lm_batch_is_learnable(self):
        """Structured stream: next token is predictable from current."""
        cfg = pipeline.LMStreamConfig(vocab_size=50, seq_len=200,
                                      global_batch=4, structure=1.0)
        t = pipeline.lm_batch(cfg, 0)["tokens"]
        mult = 6364136223846793005 % 50
        pred = (t[:, :-1].astype(np.int64) * mult + 12345) % 50
        assert (pred == t[:, 1:]).mean() > 0.99

    def test_mackey_glass_chaotic_band(self):
        x = pipeline.mackey_glass(2000)
        assert x.shape == (2000,)
        assert 0.2 < x.min() and x.max() < 1.6  # canonical MG attractor band
        assert x.std() > 0.1

    def test_narma_and_channel_shapes(self):
        u, y = pipeline.narma10(500)
        assert u.shape == y.shape == (500,)
        assert np.isfinite(y).all()
        u, d = pipeline.channel_equalization(400)
        assert u.shape == d.shape
        assert set(np.unique(d)) <= {-3.0, -1.0, 1.0, 3.0}


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
        store.save(tree, tmp_path, step=7)
        assert store.latest_step(tmp_path) == 7
        out = store.restore(tree, tmp_path, 7)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))
        assert out["b"]["c"].dtype == np.asarray(tree["b"]["c"]).dtype

    def test_corruption_detected(self, tmp_path):
        tree = {"a": jnp.arange(10)}
        d = store.save(tree, tmp_path, step=1)
        # torn write: corrupt a leaf after the manifest was published
        f = next(d.glob("*.npy"))
        f.write_bytes(b"garbage")
        assert not store.verify(d)
        assert store.latest_step(tmp_path) is None  # refuses to resume

    def test_latest_skips_bad_keeps_good(self, tmp_path):
        tree = {"a": jnp.arange(4)}
        store.save(tree, tmp_path, step=1)
        d2 = store.save(tree, tmp_path, step=2)
        next(d2.glob("*.npy")).write_bytes(b"x")
        assert store.latest_step(tmp_path) == 1

    def test_checkpointer_retention(self, tmp_path):
        ck = store.Checkpointer(tmp_path, every=1, keep=2)
        tree = {"a": jnp.zeros(3)}
        for s in range(5):
            ck.maybe_save(tree, s)
        ck.finalize()
        steps = sorted(int(p.name.split("_")[1])
                       for p in Path(tmp_path).glob("step_*"))
        assert len(steps) <= 3  # keep + possibly one in-flight

    def test_shape_mismatch_rejected(self, tmp_path):
        store.save({"a": jnp.zeros((2, 2))}, tmp_path, step=0)
        with pytest.raises(ValueError):
            store.restore({"a": jnp.zeros((3, 3))}, tmp_path, 0)


class TestCompression:
    def test_int8_roundtrip_error_bound(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal(5000), jnp.float32) * 10
        q, scale, pad = compression.quantize_block_int8(x)
        back = compression.dequantize_block_int8(q, scale, pad, x.shape)
        err = np.abs(np.asarray(back - x))
        bound = np.asarray(scale).max() * 0.5 + 1e-6
        assert err.max() <= bound

    def test_error_feedback_converges(self):
        """Compressed-gradient descent with feedback tracks the exact path."""
        rng = np.random.default_rng(1)
        target = jnp.asarray(rng.standard_normal(256), jnp.float32)
        w = jnp.zeros(256)
        res = {"w": jnp.zeros(256)}
        lr = 0.05
        for _ in range(400):
            g = {"w": 2 * (w - target)}
            comp, res = compression.compress_grads_with_feedback(g, res)
            w = w - lr * comp["w"]
        assert float(jnp.abs(w - target).max()) < 1e-2

    def test_feedback_residual_carries_error(self):
        # mixed magnitudes inside one block: the small entries fall below
        # the int8 resolution set by the big one and land in the residual
        g = {"w": jnp.full((4096,), 1e-6).at[0].set(1.0)}
        res = compression.init_residuals(g)
        comp, res = compression.compress_grads_with_feedback(g, res)
        assert float(jnp.abs(np.asarray(res["w"][1:])).max()) > 0


class TestRuntime:
    def test_plan_mesh(self):
        assert elastic.plan_mesh(256, 16) == ((16, 16), ("data", "model"))
        assert elastic.plan_mesh(512, 16, pods=2) == (
            (2, 16, 16), ("pod", "data", "model"))

    def test_replan_after_failure(self):
        plan = elastic.replan_after_failure(256, failed=3, model_parallel=16)
        assert plan["survivors"] == 253
        assert plan["usable_devices"] % 16 == 0
        assert plan["usable_devices"] <= 253
        assert plan["mesh_shape"][1] == 16
        assert any("checkpoint" in a for a in plan["actions"])

    def test_heartbeats(self):
        hb = elastic.Heartbeats(timeout_s=5.0)
        hb.beat("host0", now=0.0)
        hb.beat("host1", now=0.0)
        hb.beat("host0", now=10.0)
        assert hb.failed(now=11.0) == ["host1"]

    def test_straggler_watchdog(self):
        flagged = []
        wd = elastic.StragglerWatchdog(
            threshold=3.0, on_straggler=lambda s, d: flagged.append(s))
        for i in range(20):
            wd.record(i, 1.0)
        wd.record(20, 10.0)  # straggler
        wd.record(21, 1.0)
        assert flagged == [20]
        assert wd.median == pytest.approx(1.0)
