"""Observability layer: metrics, tracing, events, serve integration.

Load-bearing properties:

* **Merge exactness** — fixed-bucket histogram counts are additive, so
  merging per-shard histograms yields *identical* percentiles to one
  histogram fed the union of the samples (property-tested).  This is what
  makes the distributed server's merged p50/p99/p999 export honest rather
  than an approximation-of-approximations.
* **One timings schema** — the one-shot engine path and the queued
  scheduler path answer ``RolloutResult.timings`` with the same
  documented key set (:func:`repro.serve.api.lifecycle_timings`).
* **Zero steady-state retraces** — rolling many chunks of one shape
  emits compile events once and ``retrace`` events never.
* **Off by default** — without ``obs.configure()`` every instrumented
  site is a no-op and results carry no trace ids.
"""

import json

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import obs
from repro.core.esn import ESNConfig, fit_readout, init_esn, run_reservoir
from repro.obs.metrics import (DEFAULT_LATENCY_BUCKETS, HistogramData,
                               MetricsRegistry)
from repro.serve import (AsyncReservoirServer, ReservoirEngine, ServeStats,
                         SubmitSpec)

import jax.numpy as jnp


@pytest.fixture(autouse=True)
def _obs_clean():
    """Every test starts and ends with instrumentation off."""
    obs.disable()
    yield
    obs.disable()


def _params(dim=96, seed=1, block=32):
    cfg = ESNConfig(reservoir_dim=dim, element_sparsity=0.8, leak=0.7,
                    seed=seed, block=block, output_dim=2)
    p = init_esn(cfg)
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.standard_normal((50, 1)), jnp.float32)
    states = run_reservoir(p, u, engine="scan")
    y = jnp.concatenate([u, jnp.roll(u, 1)], axis=-1)
    return fit_readout(p, states, y, lam=1e-2)


def _serve(n=6, **server_kw):
    eng = ReservoirEngine(_params(), backend="xla", stats=ServeStats())
    server_kw.setdefault("chunk_time", 1.0)
    srv = AsyncReservoirServer(eng, n_slots=4, chunk_steps=8, **server_kw)
    rng = np.random.default_rng(0)
    for i in range(n):
        srv.submit(SubmitSpec(
            rng.standard_normal((10 + 3 * i, 1)).astype(np.float32), uid=i),
            arrival_time=0.1 * i)
    return eng, srv, srv.run()


# -- histograms --------------------------------------------------------------
class TestHistogramMerge:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 200), st.integers(2, 5), st.integers(0, 10_000))
    def test_merged_percentiles_equal_union(self, n, shards, seed):
        """THE merge property: per-shard histograms merged == one
        histogram fed the union, for every percentile — exactly."""
        rng = np.random.default_rng(seed)
        samples = rng.lognormal(mean=-6.0, sigma=3.0, size=n)
        parts = [HistogramData(buckets=DEFAULT_LATENCY_BUCKETS)
                 for _ in range(shards)]
        union = HistogramData(buckets=DEFAULT_LATENCY_BUCKETS)
        for i, v in enumerate(samples):
            parts[i % shards].observe(float(v))
            union.observe(float(v))
        merged = HistogramData.merge(parts)
        assert merged.total == union.total == n
        assert merged.counts == union.counts
        assert merged.sum == pytest.approx(union.sum)
        for p in (0.0, 25.0, 50.0, 90.0, 99.0, 99.9, 100.0):
            assert merged.percentile(p) == union.percentile(p)

    def test_percentile_is_bucket_upper_bound(self):
        h = HistogramData(buckets=(1.0, 2.0, 4.0))
        for v in (0.5, 1.5, 1.7, 3.0):
            h.observe(v)
        assert h.percentile(50) == 2.0            # rank 2 lands in (1, 2]
        assert h.percentile(100) == 4.0
        h.observe(100.0)                          # overflow bucket
        assert h.percentile(100) == 100.0         # vmax, not +inf
        assert HistogramData(buckets=(1.0,)).percentile(99) == 0.0

    def test_merge_rejects_mismatched_buckets(self):
        a = HistogramData(buckets=(1.0, 2.0))
        b = HistogramData(buckets=(1.0, 3.0))
        with pytest.raises(AssertionError):
            HistogramData.merge([a, b])

    def test_stats_and_metrics_agree_on_counts(self):
        """ServeStats.merge and a merged metrics histogram count the same
        events when fed the same completions."""
        waits = [[0.01, 0.2, 0.5], [0.003, 0.9]]
        stats_parts, hist_parts = [], []
        for shard in waits:
            s = ServeStats()
            h = HistogramData(buckets=DEFAULT_LATENCY_BUCKETS)
            for w in shard:
                s.record_enqueue()
                s.record_admission(w)
                h.observe(w)
            stats_parts.append(s)
            hist_parts.append(h)
        merged_stats = ServeStats.merge(stats_parts)
        merged_hist = HistogramData.merge(hist_parts)
        assert merged_stats.admitted == merged_hist.total == 5
        assert merged_stats.queue_wait_s == pytest.approx(merged_hist.sum)


# -- registry export ---------------------------------------------------------
class TestMetricsRegistry:
    def _populated(self):
        m = MetricsRegistry(namespace="repro")
        m.inc("requests_total", 3, model="a")
        m.inc("requests_total", 1, model="b")
        m.set("n_shards", 4)
        rng = np.random.default_rng(0)
        for v in rng.lognormal(-5, 2, size=50):
            m.observe("queue_wait_seconds", float(v))
        return m

    def test_prometheus_text_shape(self):
        text = self._populated().prometheus_text()
        assert '# TYPE repro_requests_total counter' in text
        assert 'repro_requests_total{model="a"} 3' in text
        assert '# TYPE repro_n_shards gauge' in text
        assert '# TYPE repro_queue_wait_seconds histogram' in text
        assert 'le="+Inf"' in text
        assert 'repro_queue_wait_seconds_count 50' in text
        # cumulative buckets end at the total count
        lines = [l for l in text.splitlines() if "_bucket" in l]
        assert lines[-1].endswith(" 50")

    def test_json_roundtrip_preserves_percentiles(self):
        m = self._populated()
        m2 = MetricsRegistry.from_json(json.loads(json.dumps(m.to_json())))
        h, h2 = m.histogram("queue_wait_seconds"), \
            m2.histogram("queue_wait_seconds")
        for p in (50, 99, 99.9):
            assert h.percentile(p) == h2.percentile(p)
        assert m2.counter("requests_total").value(model="a") == 3
        assert m2.prometheus_text() == m.prometheus_text()


# -- serve integration -------------------------------------------------------
class TestServeObservability:
    def test_percentiles_exported_from_async_server(self):
        obs.configure()
        _eng, _srv, results = _serve()
        m = obs.metrics()
        qw = m.histogram("queue_wait_seconds")
        ttfp = m.histogram("ttfp_seconds")
        lat = m.histogram("request_latency_seconds")
        assert qw.count() == 6 and ttfp.count() == 6 and lat.count() == 6
        for h in (qw, ttfp, lat):
            for p in (50, 99, 99.9):
                assert h.percentile(p) > 0.0
        text = m.prometheus_text()
        assert "repro_queue_wait_seconds_bucket" in text
        assert "repro_ttfp_seconds_count 6" in text

    def test_one_timings_schema_on_both_paths(self):
        """Engine one-shot and scheduler paths answer the same documented
        key set — including first_output/ttfp on multi-chunk requests."""
        obs.configure()
        eng, _srv, results = _serve()
        rng = np.random.default_rng(1)
        one = eng.submit(SubmitSpec(
            rng.standard_normal((12, 1)).astype(np.float32)))
        base = {"arrival_time", "admit_time", "first_output_time",
                "finish_time", "queue_wait_s", "ttfp_s", "latency_s",
                "seconds"}
        assert base | {"trace_id"} == set(one.timings)
        for res in results.values():
            assert base | {"trace_id"} == set(res.timings)
            t = res.timings
            assert t["queue_wait_s"] == pytest.approx(
                t["admit_time"] - t["arrival_time"])
            assert t["ttfp_s"] == pytest.approx(
                t["first_output_time"] - t["arrival_time"])
            assert t["latency_s"] == pytest.approx(
                t["finish_time"] - t["arrival_time"])
            assert (t["arrival_time"] <= t["admit_time"]
                    <= t["first_output_time"] <= t["finish_time"])

    def test_first_output_precedes_finish_on_long_requests(self):
        """Regression: a request whose first output landed chunks before
        retirement reports that mark, not its finish time."""
        obs.configure()
        eng = ReservoirEngine(_params(), backend="xla", stats=ServeStats())
        srv = AsyncReservoirServer(eng, n_slots=2, chunk_steps=4,
                                   chunk_time=1.0)
        rng = np.random.default_rng(2)
        srv.submit(SubmitSpec(
            rng.standard_normal((20, 1)).astype(np.float32), uid="long"))
        res = srv.run()["long"]
        t = res.timings
        assert t["first_output_time"] < t["finish_time"]
        assert t["ttfp_s"] < t["latency_s"]

    def test_trace_id_threads_through_lifecycle(self):
        obs.configure()
        _eng, _srv, results = _serve(n=3)
        tr = obs.tracer()
        for res in results.values():
            tid = res.timings["trace_id"]
            names = [s.name for s in tr.spans(trace_id=tid)]
            assert "request.enqueue" in names
            assert "request.queued" in names
            assert "request.serve" in names
            assert all(s.clock == "server"
                       for s in tr.spans(trace_id=tid))

    def test_explicit_trace_id_wins(self):
        obs.configure()
        eng = ReservoirEngine(_params(), backend="xla", stats=ServeStats())
        res = eng.submit(SubmitSpec(
            np.zeros((4, 1), np.float32), trace_id="mine"))
        assert res.timings["trace_id"] == "mine"
        assert obs.tracer().spans(trace_id="mine")

    def test_flight_recorder_jsonl_export(self, tmp_path):
        obs.configure()
        _serve(n=3)
        path = tmp_path / "trace.jsonl"
        n = obs.tracer().export_jsonl(path)
        lines = path.read_text().splitlines()
        assert len(lines) == n > 0
        rec = json.loads(lines[0])
        assert {"name", "start", "end", "duration_s", "clock"} <= set(rec)

    def test_zero_steady_state_retraces(self):
        """Compile events fire once per program; rolling many chunks of
        one pool shape must never emit a retrace."""
        obs.configure()
        _serve(n=8)
        ev = obs.events()
        assert ev.count("retrace") == 0
        assert ev.count("xla_trace") >= 1
        # warmed steady-state window: drain, serve more, still zero
        ev.drain()
        _serve(n=4)
        assert not [e for e in ev.events() if e.kind == "retrace"]

    def test_disabled_is_noop(self):
        assert not obs.enabled()
        eng, _srv, results = _serve(n=2)
        for res in results.values():
            assert "trace_id" not in res.timings
            assert "seconds" in res.timings
        assert obs.metrics() is None and obs.tracer() is None


# -- stats render ------------------------------------------------------------
class TestStatsRender:
    def test_render_surfaces_timed_out_and_quota_held(self):
        s = ServeStats()
        s.record_enqueue()
        s.record_admission(0.1)
        s.record_chunk(live_steps=4, total_steps=8)
        s.record_completion(0.5)
        s.record_timeout()
        s.record_quota_hold()
        s.record_quota_hold()
        line = s.render()
        assert "1 timed out" in line
        assert "2 quota held" in line

    def test_render_shows_zeros_not_silence(self):
        s = ServeStats()
        s.record_enqueue()
        s.record_admission(0.0)
        s.record_chunk(live_steps=1, total_steps=1)
        line = s.render()
        assert "0 timed out" in line
        assert "0 quota held" in line


# -- dist: merged shard export ----------------------------------------------
class TestDistObservability:
    def test_sharded_server_merged_percentiles(self):
        """Queue-wait/ttfp percentiles export from the distributed server
        with per-shard labels merging into one exact histogram."""
        from repro.dist import (DistributedReservoirServer,
                                ShardedReservoirEngine)
        obs.configure()
        eng = ShardedReservoirEngine(_params(), n_shards=1, backend="xla",
                                     stats=ServeStats())
        srv = DistributedReservoirServer(eng, slots_per_shard=3,
                                         chunk_steps=8, chunk_time=1.0)
        rng = np.random.default_rng(3)
        for i in range(5):
            srv.submit(SubmitSpec(
                rng.standard_normal((10, 1)).astype(np.float32), uid=i),
                arrival_time=0.1 * i)
        srv.run()
        m = obs.metrics()
        qw = m.histogram("queue_wait_seconds")
        assert qw.count() == 5
        # per-shard series carry a shard label; the unlabeled view is the
        # exact merge of every shard's series
        shard_total = 0
        for key, data in qw.series.items():
            assert any(k == "shard" for k, _v in key)
            shard_total += data.total
        assert shard_total == 5
        for p in (50, 99, 99.9):
            assert qw.percentile(p) > 0.0
        assert m.histogram("ttfp_seconds").count() == 5
