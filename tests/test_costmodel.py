"""FPGA cost model + baseline models vs the paper's stated anchors."""

import numpy as np
import pytest

from repro.core import baselines, costmodel
from repro.core.bitplanes import decompose
from repro.core.sparse import random_sparse_matrix


class TestAreaModel:
    def test_luts_track_ones_linearly(self):
        """Fig 5/10: hardware cost is linear in the number of set bits."""
        rng = np.random.default_rng(0)
        pts = []
        for sparsity in (0.4, 0.7, 0.9, 0.98):
            m = random_sparse_matrix(64, 64, sparsity, rng, weight_bits=8)
            dp = decompose(m.astype(np.int64), 8, mode="pn")
            pts.append((dp.ones, costmodel.luts_for_ones(dp.ones)))
        for ones, luts in pts:
            assert luts == pytest.approx(ones)

    def test_ffs_twice_luts(self):
        assert costmodel.ffs_for_ones(1000) == 2000

    def test_expected_ones_matches_sampled(self):
        rng = np.random.default_rng(1)
        m = random_sparse_matrix(256, 256, 0.9, rng, weight_bits=8)
        dp = decompose(m.astype(np.int64), 8, mode="pn")
        est = costmodel.expected_ones(256, 256, 0.9, 8, "pn")
        assert abs(dp.ones - est) / est < 0.10

    def test_csd_estimate_lower(self):
        pn = costmodel.expected_ones(512, 512, 0.9, 8, "pn")
        csd = costmodel.expected_ones(512, 512, 0.9, 8, "csd")
        assert csd == pytest.approx(0.83 * pn)


class TestFrequencyModel:
    def test_bands(self):
        """Fig 11: one-SLR designs are fastest; >2 SLR flattens at 225-250."""
        assert 445e6 <= costmodel.fmax_hz(100_000) <= 597e6
        assert 296e6 <= costmodel.fmax_hz(600_000) <= 400e6
        assert 225e6 <= costmodel.fmax_hz(1_200_000) <= 250e6

    def test_capacity_limit(self):
        with pytest.raises(ValueError):
            costmodel.fmax_hz(2_000_000)

    def test_monotone_decreasing_within_band(self):
        assert costmodel.fmax_hz(50_000) > costmodel.fmax_hz(300_000)


class TestLatencyAndPower:
    def test_eq5(self):
        assert costmodel.latency_cycles(8, 8, 1024) == 28

    def test_sub_120ns_claim(self):
        """'in all cases, our FPGA latency is less than 120ns' (98% sparse).

        Our banded Fmax model reproduces the claim exactly through 2048; at
        4096 (a >2-SLR design) it lands within 4% of the paper's 120 ns
        (the paper's own Fig 11 shows 225-250 MHz noise in that regime).
        """
        for dim in (64, 128, 256, 512, 1024, 2048):
            dp = costmodel.design_point(dim, dim, 0.98)
            assert dp.latency_ns < 120, (dim, dp.latency_ns)
        dp = costmodel.design_point(4096, 4096, 0.98, mode="csd")
        assert dp.latency_ns < 125, dp.latency_ns

    def test_thermal_limit_region(self):
        """Fig 12: high dimension + low sparsity approaches ~150 W.

        The conclusion pins the capacity anchor: 'up to 1.5 million ones, as
        large as 1024x1024 eight-bit matrix at a sparsity of 60%'.
        """
        dp = costmodel.design_point(1024, 1024, 0.60, mode="pn")
        assert 1.4e6 <= dp.ones <= 1.55e6
        assert 130 <= dp.power_w <= 155

    def test_1p5m_ones_capacity_claim(self):
        """'Bit serial implementations allow ... up to 1.5 million ones'."""
        dp = costmodel.design_point(1024, 1024, 0.60, mode="pn")
        assert dp.ones <= 1.5e6 and dp.fits

    def test_batching_pipelined(self):
        dp = costmodel.design_point(1024, 1024, 0.95)
        l1 = dp.batch_latency_s(1)
        l64 = dp.batch_latency_s(64)
        # pipelined streaming: 64 vectors cost far less than 64 x latency
        assert l64 < 64 * l1
        assert l64 == pytest.approx(l1 + 63 * dp.input_bits / dp.fmax_hz)


class TestBaselineModels:
    def test_gpu_never_breaks_1us(self):
        """'the GPU cannot break the 1us barrier'."""
        for dim in (64, 256, 1024, 4096):
            for lib in ("cusparse", "sputnik"):
                assert baselines.gpu_latency_s(dim, 0.98, lib) > 1e-6

    def test_dim_sweep_speedup_band(self):
        """Fig 14: 50x-86x vs cuSPARSE across the dim sweep at 98% sparsity
        (the paper's headline band; the optimized kernel sits lower)."""
        for dim in (64, 128, 256, 512, 1024, 2048, 4096):
            fpga = costmodel.design_point(dim, dim, 0.98)
            speedup = baselines.gpu_latency_s(dim, 0.98, "cusparse") / fpga.latency_s
            assert 35 <= speedup <= 95, (dim, speedup)
            sput = baselines.gpu_latency_s(dim, 0.98, "sputnik") / fpga.latency_s
            assert sput >= 20, (dim, sput)

    def test_average_speedup_50x_up_to_86x(self):
        """Abstract: 'reduce latency by 50x up to 86x versus GPU libraries'."""
        sweeps = []
        for dim in (64, 128, 256, 512, 1024, 2048, 4096):
            fpga = costmodel.design_point(dim, dim, 0.98)
            sweeps.append(baselines.gpu_latency_s(dim, 0.98, "cusparse")
                          / fpga.latency_s)
        assert max(sweeps) >= 80
        assert np.mean(sweeps) >= 45

    def test_sigma_crossover_at_grid_capacity(self):
        """Figs 19-20: SIGMA is ns-scale while nnz fits the 128x128 grid,
        then tiles and loses by 4.1x..25x+."""
        small = baselines.sigma_latency_s(128, 0.98)   # nnz ~ 328 fits
        assert small < 100e-9
        fpga = costmodel.design_point(1024, 1024, 0.98)
        s1024 = baselines.sigma_latency_s(1024, 0.98) / fpga.latency_s
        assert 3.0 <= s1024 <= 6.0  # paper: 4.1x worst case
        fpga4k = costmodel.design_point(4096, 4096, 0.98)
        s4096 = baselines.sigma_latency_s(4096, 0.98) / fpga4k.latency_s
        assert s4096 >= 20  # 'quickly gain a 25x advantage'

    def test_sigma_sparsity_max_47x(self):
        """Fig 22: up to ~47x at low sparsity (1024x1024)."""
        speedups = []
        for es in (0.70, 0.75, 0.80, 0.85, 0.90, 0.95, 0.98):
            fpga = costmodel.design_point(1024, 1024, es, mode="csd")
            speedups.append(
                baselines.sigma_latency_s(1024, es) / fpga.latency_s)
        assert 35 <= max(speedups) <= 60
        # '90% sparsity and below ... back into the microsecond regime'
        assert baselines.sigma_latency_s(1024, 0.90) > 1e-6

    def test_sigma_batch_saturates(self):
        """Fig 23: batching speedup saturates ~5.4x (1024, 95%)."""
        fpga = costmodel.design_point(1024, 1024, 0.95)
        sp = []
        for b in (4, 8, 16, 32, 64):
            sig = baselines.sigma_latency_s(1024, 0.95, batch=b)
            sp.append(sig / fpga.batch_latency_s(b))
        assert 3.0 <= sp[-1] <= 8.0
        # saturation: last two batch points within 30%
        assert abs(sp[-1] - sp[-2]) / sp[-2] < 0.3
