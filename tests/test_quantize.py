"""int8 frozen-weight serving (the paper's technique on LM decode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.quantize import (dequant_tree, is_quantized_leaf,
                                   quant_struct_like, quantize_tree)
from repro.models.transformer import LM


class TestQuantize:
    def test_roundtrip_error_bound(self):
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.standard_normal((64, 512, 256)), jnp.float32)
        q = quantize_tree({"w": w})
        assert is_quantized_leaf(q["w"])
        assert q["w"]["q"].dtype == jnp.int8
        assert q["w"]["scale"].shape == (64, 256)  # (layers, out)
        back = dequant_tree(q, jnp.float32)["w"]
        err = jnp.abs(back - w)
        bound = jnp.abs(w).max() / 127 + 1e-6
        assert float(err.max()) <= float(bound) * 1.01

    def test_small_leaves_untouched(self):
        tree = {"norm": jnp.ones((64, 512)), "bias": jnp.ones((128,))}
        q = quantize_tree(tree)
        assert not is_quantized_leaf(q["norm"])  # stacked norm vector
        assert not is_quantized_leaf(q["bias"])

    def test_struct_like_matches_quantize(self):
        rng = np.random.default_rng(1)
        tree = {"w": jnp.asarray(rng.standard_normal((8, 256, 128)),
                                 jnp.bfloat16),
                "n": jnp.ones((256,))}
        structs = jax.eval_shape(lambda: tree)
        qs = quant_struct_like(structs)
        qt = quantize_tree(tree)
        assert qs["w"]["q"].shape == qt["w"]["q"].shape
        assert qs["w"]["scale"].shape == qt["w"]["scale"].shape
        assert qs["n"].shape == qt["n"].shape

    def test_int8_decode_close_to_bf16(self):
        """Quantized-serving decode stays close to the bf16 path."""
        cfg = reduced(get_config("qwen3-32b"))
        lm = LM(cfg)
        params = lm.init(jax.random.PRNGKey(0)).params
        toks = jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 8)))
        logits, caches = lm.prefill(params, {"tokens": toks[:, :7]},
                                    cache_len=8)
        ref, _ = lm.decode_step(params, caches, toks[:, 7:])

        qparams = quantize_tree(params)
        logits_q, caches_q = lm.prefill(qparams, {"tokens": toks[:, :7]},
                                        cache_len=8)
        got, _ = lm.decode_step(qparams, caches_q, toks[:, 7:])
        a = np.asarray(got, np.float32).ravel()
        b = np.asarray(ref, np.float32).ravel()
        # int8 weights perturb logits but preserve ranking at smoke scale
        assert np.corrcoef(a, b)[0, 1] > 0.98
