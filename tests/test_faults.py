"""Deterministic fault injection: transient retry bit-identity, straggler
windows, publish aborts, and plan determinism.

The load-bearing contract: every fault the plan injects is *recovered
from* with outputs bit-identical to an undisturbed run.  Transient
engine-call failures are raised before the launch, so the retry replays
the exact same (inputs, pre-chunk state) pair; straggler windows only
inflate the virtual clock; a publish abort leaves the active version
untouched and the staged version ready for a no-recompile retry.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.esn import ESNConfig, fit_readout, init_esn, run_reservoir
from repro.runtime.faults import (FaultEvent, FaultPlan, PublishAborted,
                                  TransientFault)
from repro.runtime import faults
from repro.serve import (AsyncReservoirServer, ModelRegistry,
                         ReservoirEngine, ServeStats, SubmitSpec)


def _params(mode="fp32", dim=96, leak=0.7, seed=1, block=32):
    cfg = ESNConfig(reservoir_dim=dim, element_sparsity=0.8, mode=mode,
                    leak=leak, seed=seed, block=block, output_dim=2)
    p = init_esn(cfg)
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.standard_normal((50, 1)), jnp.float32)
    states = run_reservoir(p, u, engine="scan")
    y = jnp.concatenate([u, jnp.roll(u, 1)], axis=-1)
    return fit_readout(p, states, y, lam=1e-2)


def _requests(lengths, seed=0, in_dim=1):
    rng = np.random.default_rng(seed)
    return [SubmitSpec(rng.standard_normal((t, in_dim)).astype(np.float32),
                       uid=i)
            for i, t in enumerate(lengths)]


def _server(p, **kw):
    eng = ReservoirEngine(p, backend="xla", stats=ServeStats())
    kw.setdefault("chunk_time", 1.0)
    return AsyncReservoirServer(eng, stats=ServeStats(), **kw)


@pytest.fixture(autouse=True)
def _clear_installed_plan():
    yield
    faults.install(None)


class TestFaultPlanUnit:
    def test_seeded_is_deterministic(self):
        kw = dict(horizon=50.0, n_shards=4, transient_rate=0.2,
                  slow_rate=0.1, shard_loss_times=[10.0])
        a = FaultPlan.seeded(7, **kw)
        b = FaultPlan.seeded(7, **kw)
        c = FaultPlan.seeded(8, **kw)
        assert [(e.kind, e.at) for e in a.events] \
            == [(e.kind, e.at) for e in b.events]
        assert [(e.kind, e.at) for e in a.events] \
            != [(e.kind, e.at) for e in c.events]
        assert any(e.kind == "shard_loss" and e.at == 10.0 for e in a.events)

    def test_begin_chunk_activates_in_time_order(self):
        plan = FaultPlan([FaultEvent("transient", at=2.0, count=2),
                          FaultEvent("shard_loss", at=5.0, shard=1)])
        plan.begin_chunk(1.0)
        assert plan.injected == {} and plan.take_dead_shards() == []
        plan.begin_chunk(2.0)          # at <= now activates
        assert plan.injected == {"transient": 1}
        with pytest.raises(TransientFault):
            plan.check_call()
        with pytest.raises(TransientFault):
            plan.check_call()
        plan.check_call()              # count=2 exhausted: clean
        plan.begin_chunk(6.0)
        assert plan.take_dead_shards() == [1]
        assert plan.take_dead_shards() == []       # drained once
        assert plan.fault_times["shard_loss"] == [6.0]

    def test_backoff_is_capped_exponential(self):
        plan = FaultPlan(backoff_base_s=0.001, backoff_cap_s=0.05)
        delays = [plan.backoff_s(i) for i in range(10)]
        assert delays[:3] == [0.001, 0.002, 0.004]
        assert max(delays) == 0.05 and delays == sorted(delays)

    def test_slow_window_expires(self):
        plan = FaultPlan([FaultEvent("slow_shard", at=0.0, factor=3.0,
                                     duration=2.0)])
        plan.begin_chunk(0.0)
        assert plan.slow_factor() == 3.0
        plan.begin_chunk(1.9)
        assert plan.slow_factor() == 3.0
        plan.begin_chunk(2.0)          # window [0, 2) closed
        assert plan.slow_factor() == 1.0

    def test_publish_abort_arm_and_consume(self):
        plan = FaultPlan()
        assert plan.take_publish_abort() is False
        plan.arm_publish_abort()
        assert plan.take_publish_abort() is True
        assert plan.take_publish_abort() is False
        assert plan.injected["publish_abort"] == 1

    def test_install_active_round_trip(self):
        assert faults.active() is None
        plan = FaultPlan()
        faults.install(plan)
        assert faults.active() is plan
        faults.install(None)
        assert faults.active() is None


class TestTransientRetry:
    def test_retry_replays_bit_identical(self):
        p = _params()
        specs = _requests([8, 8, 8, 8], seed=5)
        ref_srv = _server(p, n_slots=2, chunk_steps=4)
        for s in specs:
            ref_srv.submit(s, arrival_time=0.0)
        ref = ref_srv.run()

        plan = FaultPlan([FaultEvent("transient", at=0.0, count=3)])
        srv = _server(p, n_slots=2, chunk_steps=4, fault_plan=plan)
        for s in specs:
            srv.submit(s, arrival_time=0.0)
        res = srv.run()
        assert plan.injected["transient"] == 1
        assert srv.stats.retries == 3          # count=3 -> 3 retried calls
        assert srv.stats.completed == 4 and len(res) == 4
        for uid in ref:
            np.testing.assert_array_equal(np.asarray(res[uid].output),
                                          np.asarray(ref[uid].output))

    def test_backoff_charged_to_virtual_clock(self):
        p = _params()
        plan = FaultPlan([FaultEvent("transient", at=0.0, count=3)],
                         backoff_base_s=0.001)
        srv = _server(p, n_slots=2, chunk_steps=4, fault_plan=plan)
        for s in _requests([8, 8], seed=6):
            srv.submit(s, arrival_time=0.0)
        srv.run()
        # 2 chunks of 1.0 plus 0.001 + 0.002 + 0.004 of backoff on the
        # first chunk's three retries
        assert srv.now == pytest.approx(2.007)

    def test_exhausted_attempts_propagate(self):
        p = _params()
        plan = FaultPlan([FaultEvent("transient", at=0.0, count=5)],
                         max_attempts=2)
        srv = _server(p, n_slots=1, chunk_steps=4, fault_plan=plan)
        srv.submit(_requests([4], seed=7)[0], arrival_time=0.0)
        with pytest.raises(TransientFault):
            srv.run()


class TestSlowWindow:
    def test_straggler_inflates_clock_not_outputs(self):
        p = _params()
        specs = _requests([8, 8], seed=8)
        ref_srv = _server(p, n_slots=2, chunk_steps=4)
        for s in specs:
            ref_srv.submit(s, arrival_time=0.0)
        ref = ref_srv.run()
        assert ref_srv.now == pytest.approx(2.0)

        plan = FaultPlan([FaultEvent("slow_shard", at=0.0, factor=3.0,
                                     duration=2.0)])
        srv = _server(p, n_slots=2, chunk_steps=4, fault_plan=plan)
        for s in specs:
            srv.submit(s, arrival_time=0.0)
        res = srv.run()
        # chunk 1 inside the window costs 3.0; chunk 2 (t=3.0) is past it
        assert srv.now == pytest.approx(4.0)
        for uid in ref:
            np.testing.assert_array_equal(np.asarray(res[uid].output),
                                          np.asarray(ref[uid].output))


class TestPublishAbort:
    def test_abort_leaves_active_version_then_retry_succeeds(self):
        reg = ModelRegistry(backend="xla")
        reg.register("m", _params(seed=1))
        assert reg.active_version("m") == 1
        plan = FaultPlan()
        plan.arm_publish_abort()
        faults.install(plan)
        with pytest.raises(PublishAborted, match="stays"):
            reg.publish("m", _params(seed=2))
        # the worst-moment abort: prewarm spent, cutover never happened
        assert reg.active_version("m") == 1
        assert reg.versions("m") == [1, 2]     # staged version survives
        # retry (same installed plan, abort consumed) activates v2
        out = reg.publish("m", version=2)
        assert reg.active_version("m") == 2
        assert out["version"] == 2 and out["previous_version"] == 1

    def test_serving_unaffected_across_abort(self):
        reg = ModelRegistry(backend="xla")
        reg.register("m", _params(seed=1))
        eng = reg.engine("m")
        eng.stats = ServeStats()
        srv = AsyncReservoirServer(eng, n_slots=2, chunk_steps=4,
                                   chunk_time=1.0, registry=reg,
                                   stats=ServeStats())
        # pool-shaped reference: the undisturbed pooled serve of the
        # same request (one-shot engine bits differ at a different
        # batch shape, so pooled compares against pooled)
        srv.submit(SubmitSpec(np.ones((8, 1), np.float32), model="m",
                              uid="ref"), arrival_time=0.0)
        before = srv.run()["ref"]
        plan = FaultPlan()
        plan.arm_publish_abort()
        faults.install(plan)
        with pytest.raises(PublishAborted):
            reg.publish("m", _params(seed=2))
        srv.submit(SubmitSpec(np.ones((8, 1), np.float32), model="m",
                              uid="r0"), arrival_time=0.0)
        res = srv.run()
        # post-abort admissions still serve v1 bits
        np.testing.assert_array_equal(np.asarray(res["r0"].output),
                                      np.asarray(before.output))
