"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Each kernel sweeps shapes/dtypes and asserts allclose (exact for integer
paths) against its ref.py oracle, per the kernel-layout convention.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.bitplanes import decompose
from repro.core.sparse import BlockSparse, FixedMatrix, random_sparse_matrix
from repro.kernels.bitplane_gemv.bitplane_gemv import bitplane_gemv
from repro.kernels.bitplane_gemv.ops import BitplaneGemv, digits_from_fixed
from repro.kernels.bitplane_gemv.ref import bitplane_gemv_ref, dense_gemv_ref
from repro.kernels.bcsr_matmul.ops import BcsrMatmul
from repro.kernels.bcsr_matmul.ref import bcsr_matmul_ref
from repro.kernels.reservoir_step.ops import FusedReservoir
from repro.kernels.reservoir_step.ref import reservoir_step_ref


class TestBitplaneGemv:
    @pytest.mark.parametrize("r,c,br,bc", [
        (128, 128, 128, 128),
        (256, 128, 128, 128),
        (128, 256, 64, 128),
        (256, 256, 64, 64),
    ])
    @pytest.mark.parametrize("mode", ["pn", "csd"])
    def test_exact_vs_dense(self, r, c, br, bc, mode):
        rng = np.random.default_rng(r + c)
        v = rng.integers(-128, 128, size=(r, c))
        v[rng.random(v.shape) < 0.9] = 0
        dp = decompose(v, 8, mode=mode, rng=rng)
        digits = jnp.asarray(
            dp.pos.astype(np.int8) - dp.neg.astype(np.int8))
        x = jnp.asarray(rng.integers(-128, 128, size=(4, r)), jnp.int32)
        got = bitplane_gemv(x, digits, block_r=br, block_c=bc)
        want = dense_gemv_ref(x, jnp.asarray(v))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        ref = bitplane_gemv_ref(x, digits)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    @pytest.mark.parametrize("x_dtype", [jnp.int8, jnp.int32])
    def test_input_dtypes(self, x_dtype):
        rng = np.random.default_rng(0)
        v = rng.integers(-8, 8, size=(128, 128))
        dp = decompose(v, 4, mode="pn")
        digits = jnp.asarray(dp.pos.astype(np.int8) - dp.neg.astype(np.int8))
        x = jnp.asarray(rng.integers(-100, 100, size=(2, 128)), x_dtype)
        got = bitplane_gemv(x, digits)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(dense_gemv_ref(x, jnp.asarray(v))))

    def test_plane_mask_culls_safely(self):
        """Culling empty planes (trace-time constant prop) changes nothing."""
        rng = np.random.default_rng(1)
        v = rng.integers(0, 4, size=(128, 128))  # only low planes populated
        dp = decompose(v, 8, mode="pn")
        digits = np.asarray(dp.pos.astype(np.int8) - dp.neg.astype(np.int8))
        x = jnp.asarray(rng.integers(-128, 128, size=(2, 128)), jnp.int32)
        mask = tuple(bool(np.any(digits[w])) for w in range(digits.shape[0]))
        assert not all(mask)  # some planes really are empty
        got = bitplane_gemv(x, jnp.asarray(digits), plane_mask=mask)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(dense_gemv_ref(x, jnp.asarray(v))))

    def test_ops_wrapper_unaligned(self):
        """Wrapper pads ragged shapes to MXU-aligned blocks."""
        rng = np.random.default_rng(2)
        d = random_sparse_matrix(200, 150, 0.9, rng)
        fm = FixedMatrix.compile(d, mode="csd", block=64, rng=rng)
        op = BitplaneGemv(fm, block_r=128, block_c=128)
        x = jnp.asarray(rng.integers(-128, 128, size=(3, 200)), jnp.int32)
        got = op(x)
        want = fm.matvec_int_dense_ref(x)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @given(st.integers(1, 6), st.sampled_from(["pn", "csd"]))
    @settings(max_examples=10, deadline=None)
    def test_property_random_bits(self, weight_bits, mode):
        rng = np.random.default_rng(weight_bits * 17)
        lo, hi = -(1 << (weight_bits - 1)), (1 << (weight_bits - 1))
        v = rng.integers(lo, hi, size=(128, 128))
        dp = decompose(v, weight_bits, mode=mode, rng=rng)
        digits = jnp.asarray(dp.pos.astype(np.int8) - dp.neg.astype(np.int8))
        x = jnp.asarray(rng.integers(-64, 64, size=(2, 128)), jnp.int32)
        got = bitplane_gemv(x, digits)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(dense_gemv_ref(x, jnp.asarray(v))))


class TestBcsrMatmul:
    @pytest.mark.parametrize("r,c,block,sparsity", [
        (256, 256, 128, 0.95),
        (512, 256, 128, 0.99),
        (256, 512, 128, 0.999),   # many empty column blocks
        (384, 384, 128, 0.98),
    ])
    def test_vs_dense(self, r, c, block, sparsity):
        rng = np.random.default_rng(r * 7 + c)
        d = random_sparse_matrix(r, c, sparsity, rng).astype(np.float32)
        bs = BlockSparse.from_dense(d, block=block)
        op = BcsrMatmul(bs)
        x = jnp.asarray(rng.standard_normal((4, r)), jnp.float32)
        got = op(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x) @ d,
                                   rtol=1e-5, atol=1e-4)

    def test_vs_ref_oracle(self):
        rng = np.random.default_rng(5)
        d = random_sparse_matrix(256, 256, 0.97, rng).astype(np.float32)
        bs = BlockSparse.from_dense(d, block=128)
        op = BcsrMatmul(bs)
        x = jnp.asarray(rng.standard_normal((2, 256)), jnp.float32)
        want = bcsr_matmul_ref(x, op.data, np.asarray(op.cols),
                               np.asarray(op.rows), op.cols_pad, block=128)
        # rtol-only is too strict for near-zero sums whose accumulation
        # order differs between the kernel and the oracle.
        np.testing.assert_allclose(np.asarray(op(x)), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)

    def test_bf16_inputs(self):
        rng = np.random.default_rng(6)
        d = random_sparse_matrix(256, 256, 0.95, rng).astype(np.float32)
        bs = BlockSparse.from_dense(d, block=128)
        op = BcsrMatmul(bs)
        x = jnp.asarray(rng.standard_normal((2, 256)), jnp.bfloat16)
        got = np.asarray(op(x), np.float32)
        want = np.asarray(x, np.float32) @ d
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)

    def test_all_zero(self):
        bs = BlockSparse.from_dense(np.zeros((256, 256), np.float32), 128)
        op = BcsrMatmul(bs)
        out = op(jnp.ones((2, 256)))
        np.testing.assert_array_equal(np.asarray(out), 0.0)

    def test_culling_reduces_tiles(self):
        d = np.zeros((512, 512), np.float32)
        d[:128, :128] = 1.0
        bs = BlockSparse.from_dense(d, block=128)
        op = BcsrMatmul(bs)
        # 1 data tile + 3 zero-padding tiles for empty output columns
        assert op.n_tiles == 4
        assert bs.n_blocks_nnz == 1


class TestReservoirStep:
    @pytest.mark.parametrize("dim,batch,block", [
        (128, 4, 128),
        (256, 2, 128),
        (256, 8, 64),
        (384, 1, 128),
    ])
    @pytest.mark.parametrize("leak", [1.0, 0.3])
    def test_vs_ref(self, dim, batch, block, leak):
        rng = np.random.default_rng(dim + batch)
        w = (rng.standard_normal((dim, dim)) * 0.05).astype(np.float32)
        w_in = rng.standard_normal((8, dim)).astype(np.float32) * 0.3
        fr = FusedReservoir(w, w_in, leak=leak, block=block)
        x = jnp.asarray(rng.standard_normal((batch, dim)), jnp.float32)
        u = jnp.asarray(rng.standard_normal((batch, 8)), jnp.float32)
        got = fr.step(x, u)
        want = reservoir_step_ref(x, jnp.asarray(w), u, jnp.asarray(w_in),
                                  leak=leak)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_sequence_scan_matches_esn(self):
        """Fused kernel trajectory == core ESN reference trajectory."""
        from repro.core.esn import ESNConfig, init_esn, run_reservoir
        cfg = ESNConfig(reservoir_dim=128, element_sparsity=0.8, seed=9,
                        block=64)
        p = init_esn(cfg)
        w = np.asarray(p.w.dense_f32())
        fr = FusedReservoir(w, np.asarray(p.w_in), leak=cfg.leak, block=128)
        t, b = 20, 2
        rng = np.random.default_rng(0)
        u = jnp.asarray(rng.standard_normal((t, b, 1)), jnp.float32)
        states_kernel = fr.run(u)
        states_ref = run_reservoir(p, u.transpose(1, 0, 2))
        np.testing.assert_allclose(np.asarray(states_kernel),
                                   np.asarray(states_ref).transpose(1, 0, 2),
                                   rtol=1e-4, atol=1e-4)
