"""Echo State Network behaviour — the paper's motivating workload."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.esn import (ESNConfig, fit_readout, init_esn, nrmse, predict,
                            run_reservoir)
from repro.core.ridge import ridge_fit


def _sine_task(n=800):
    t = np.arange(n) * 0.1
    sig = np.sin(t) + 0.5 * np.sin(0.37 * t)
    u = sig[:-1, None].astype(np.float32)
    y = sig[1:, None].astype(np.float32)
    return u, y


class TestRidge:
    def test_recovers_linear_map(self):
        rng = np.random.default_rng(0)
        x = rng.standard_normal((500, 20)).astype(np.float32)
        w_true = rng.standard_normal((20, 3)).astype(np.float32)
        y = x @ w_true
        w = ridge_fit(jnp.asarray(x), jnp.asarray(y), lam=1e-8)
        np.testing.assert_allclose(np.asarray(w), w_true, atol=1e-3)

    def test_regularization_shrinks(self):
        rng = np.random.default_rng(1)
        x = rng.standard_normal((100, 10)).astype(np.float32)
        y = rng.standard_normal((100, 1)).astype(np.float32)
        w_small = ridge_fit(jnp.asarray(x), jnp.asarray(y), lam=1e-6)
        w_big = ridge_fit(jnp.asarray(x), jnp.asarray(y), lam=1e3)
        assert np.linalg.norm(w_big) < np.linalg.norm(w_small)


class TestESN:
    def test_echo_state_property(self):
        """Spectral radius < 1 => state stays bounded, forgets initial state."""
        cfg = ESNConfig(reservoir_dim=80, spectral_radius=0.8, seed=0, block=32)
        p = init_esn(cfg)
        u = jnp.asarray(np.random.default_rng(0).standard_normal((300, 1)),
                        jnp.float32)
        s_zero = run_reservoir(p, u)
        s_ones = run_reservoir(p, u, x0=jnp.ones(80))
        assert np.abs(np.asarray(s_zero)).max() <= 1.0  # tanh bound
        # initial-condition difference decays (echo state property)
        d0 = np.abs(np.asarray(s_zero[0] - s_ones[0])).max()
        dT = np.abs(np.asarray(s_zero[-1] - s_ones[-1])).max()
        assert dT < d0 * 0.05

    def test_learns_sine_prediction(self):
        cfg = ESNConfig(reservoir_dim=200, element_sparsity=0.8, seed=2,
                        block=64)
        p = init_esn(cfg)
        u, y = _sine_task()
        states = run_reservoir(p, jnp.asarray(u))
        p = fit_readout(p, states[100:], jnp.asarray(y[100:]))
        err = float(nrmse(predict(p, states[100:]), jnp.asarray(y[100:])))
        assert err < 0.05, err

    def test_int8_mode_close_to_fp32(self):
        """[16]: quantized reservoirs lose little accuracy."""
        u, y = _sine_task()
        errs = {}
        for mode in ("fp32", "int8-csd"):
            cfg = ESNConfig(reservoir_dim=150, element_sparsity=0.8,
                            mode=mode, seed=3, block=64)
            p = init_esn(cfg)
            states = run_reservoir(p, jnp.asarray(u))
            p = fit_readout(p, states[100:], jnp.asarray(y[100:]))
            errs[mode] = float(nrmse(predict(p, states[100:]),
                                     jnp.asarray(y[100:])))
        assert errs["int8-csd"] < max(3 * errs["fp32"], 0.1)

    def test_batched_inputs(self):
        cfg = ESNConfig(reservoir_dim=50, seed=4, block=32)
        p = init_esn(cfg)
        u = jnp.ones((3, 20, 1))
        s = run_reservoir(p, u)
        assert s.shape == (3, 20, 50)
        assert np.isfinite(np.asarray(s)).all()

    def test_reservoir_sparsity_honored(self):
        cfg = ESNConfig(reservoir_dim=100, element_sparsity=0.9, seed=5,
                        block=32)
        p = init_esn(cfg)
        assert abs(p.w.element_sparsity - 0.9) < 0.03


class TestBatchedWashout:
    """Regression: washout must trim each sequence's transient, not just
    the head of the flattened (B*T, R) array."""

    def _batched(self, b=3, t=40, seed=6):
        cfg = ESNConfig(reservoir_dim=64, element_sparsity=0.8, seed=seed,
                        block=32, output_dim=2)
        p = init_esn(cfg)
        rng = np.random.default_rng(seed)
        u = jnp.asarray(rng.standard_normal((b, t, 1)), jnp.float32)
        states = run_reservoir(p, u, engine="scan")
        y = jnp.asarray(rng.standard_normal((b, t, 2)), jnp.float32)
        return p, states, y

    def test_batched_washout_matches_per_sequence_fit(self):
        p, states, y = self._batched()
        washed = fit_readout(p, states, y, lam=1e-3, washout=10)
        # reference: trim every sequence by hand, then fit with washout=0
        manual = fit_readout(p, states[:, 10:], y[:, 10:], lam=1e-3)
        np.testing.assert_allclose(np.asarray(washed.w_out),
                                   np.asarray(manual.w_out),
                                   rtol=1e-5, atol=1e-6)
        # and differs from the old buggy flattened-head trim
        b, t, r = states.shape
        flat_s = states.reshape(-1, r)[10:]
        flat_y = y.reshape(-1, y.shape[-1])[10:]
        buggy = fit_readout(p, flat_s, flat_y, lam=1e-3)
        assert np.abs(np.asarray(washed.w_out)
                      - np.asarray(buggy.w_out)).max() > 1e-6

    def test_unbatched_washout_semantics_unchanged(self):
        p, states, y = self._batched(b=1)
        single = fit_readout(p, states[0], y[0], lam=1e-3, washout=10)
        manual = fit_readout(p, states[0, 10:], y[0, 10:], lam=1e-3)
        np.testing.assert_allclose(np.asarray(single.w_out),
                                   np.asarray(manual.w_out),
                                   rtol=1e-6, atol=1e-7)
