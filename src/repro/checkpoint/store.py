"""Sharded checkpointing with manifest integrity and auto-resume.

Layout per step:
  <dir>/step_<n>/
    manifest.json       # tree structure, shapes, dtypes, per-file sha256
    <leaf-path>.npy     # one file per pytree leaf (gathered to host)

Saves run on a background thread (training continues), and ``latest_step``
skips manifests that fail integrity (a torn write from a crash mid-save is
detected, not resumed into) — the restart path a real cluster needs.
"""

from __future__ import annotations

import hashlib
import json
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "Checkpointer"]


def _leaf_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        yield name.replace("/", "__"), leaf
    return


def _sha(path: Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for blk in iter(lambda: f.read(1 << 20), b""):
            h.update(blk)
    return h.hexdigest()


def save(tree: Any, directory: str | Path, step: int) -> Path:
    d = Path(directory) / f"step_{step:08d}"
    tmp = d.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    manifest = {"step": step, "leaves": {}}
    for name, leaf in _leaf_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        f = tmp / f"{name}.npy"
        logical = str(arr.dtype)
        if arr.dtype.kind not in "biufc":  # bf16 etc: npy can't round-trip
            arr = arr.view(np.uint8).reshape(arr.shape + (arr.dtype.itemsize,))
        np.save(f, arr)
        manifest["leaves"][name] = {
            "shape": list(arr.shape), "dtype": logical,
            "sha256": _sha(f),
        }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if d.exists():
        shutil.rmtree(d)
    tmp.rename(d)  # atomic-ish publish
    return d


def save_async(tree: Any, directory: str | Path, step: int) -> threading.Thread:
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    t = threading.Thread(target=save, args=(host_tree, directory, step),
                         daemon=True)
    t.start()
    return t


def verify(d: Path) -> bool:
    mf = d / "manifest.json"
    if not mf.exists():
        return False
    try:
        manifest = json.loads(mf.read_text())
        for name, info in manifest["leaves"].items():
            f = d / f"{name}.npy"
            if not f.exists() or _sha(f) != info["sha256"]:
                return False
        return True
    except Exception:
        return False


def latest_step(directory: str | Path) -> int | None:
    root = Path(directory)
    if not root.exists():
        return None
    steps = sorted((int(p.name.split("_")[1]) for p in root.glob("step_*")
                    if p.is_dir() and p.name.split("_")[1].isdigit()),
                   reverse=True)
    for s in steps:
        if verify(root / f"step_{s:08d}"):
            return s
    return None


def restore(tree_like: Any, directory: str | Path, step: int,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``tree_like`` (shapes validated).

    ``shardings``: optional matching pytree of NamedSharding — leaves are
    device_put with their sharding, which is how a *re-planned* (elastic)
    mesh reloads a checkpoint written under a different topology.
    """
    d = Path(directory) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    names = dict(_leaf_paths(tree_like))
    flat_sh = (dict(_leaf_paths(shardings)) if shardings is not None else {})
    out = {}
    for name, leaf in names.items():
        arr = np.load(d / f"{name}.npy")
        logical = manifest["leaves"][name]["dtype"]
        if str(arr.dtype) != logical:  # exotic dtype stored as uint8 bytes
            import ml_dtypes
            dt = np.dtype(getattr(ml_dtypes, logical, logical))
            arr = arr.reshape(arr.shape[:-1] + (-1,)).view(dt)[..., 0] \
                if arr.shape[-1:] == (dt.itemsize,) else arr.view(dt)
        want = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"{name}: checkpoint {arr.shape} != model {want}")
        sh = flat_sh.get(name)
        out[name] = jax.device_put(arr, sh) if sh is not None else arr

    leaves_order = [name for name, _ in _leaf_paths(tree_like)]
    flat, treedef = jax.tree_util.tree_flatten(tree_like)
    return jax.tree_util.tree_unflatten(
        treedef, [out[n] for n in leaves_order])


class Checkpointer:
    """Every-N-steps async checkpointing with bounded retention."""

    def __init__(self, directory: str | Path, every: int = 100, keep: int = 3):
        self.dir = Path(directory)
        self.every = every
        self.keep = keep
        self._thread: threading.Thread | None = None

    def maybe_save(self, tree: Any, step: int):
        if step % self.every:
            return
        if self._thread is not None:
            self._thread.join()  # one in flight at a time
        self._thread = save_async(tree, self.dir, step)
        self._gc()

    def _gc(self):
        steps = sorted((int(p.name.split("_")[1])
                        for p in self.dir.glob("step_*")
                        if p.name.split("_")[1].isdigit()), reverse=True)
        for s in steps[self.keep:]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def finalize(self):
        if self._thread is not None:
            self._thread.join()
