"""Checkpointing."""
