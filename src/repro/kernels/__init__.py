"""Pallas TPU kernels for the paper's compute hot-spots.

- ``bitplane_gemv``: digit-plane fixed-matrix gemv (bit-serial analogue)
- ``bcsr_matmul``: static block-culled sparse matmul (constant propagation)
- ``reservoir_step``: fused ESN state update (the recurrent latency path)
- ``reservoir_rollout``: T fused steps for a whole batch — state resident
  in VMEM across the scan, static BCSR + digit-plane culling, fp32 and
  exact-int8 modes (serving hot path behind ``repro.serve``)

All kernels are written for TPU (pl.pallas_call + BlockSpec VMEM tiling)
and validated with interpret=True on CPU against pure-jnp oracles.
EXAMPLE.md documents the per-kernel layout convention.
"""
