"""Pallas TPU kernels for the paper's compute hot-spots.

- ``bitplane_gemv``: digit-plane fixed-matrix gemv (bit-serial analogue)
- ``bcsr_matmul``: static block-culled sparse matmul (constant propagation)
- ``reservoir_step``: fused ESN state update (the recurrent latency path)

All kernels are written for TPU (pl.pallas_call + BlockSpec VMEM tiling)
and validated with interpret=True on CPU against pure-jnp oracles.
EXAMPLE.md documents the per-kernel layout convention.
"""
