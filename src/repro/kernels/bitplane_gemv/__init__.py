"""bitplane_gemv kernel package."""
