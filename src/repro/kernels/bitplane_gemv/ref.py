"""Pure-jnp oracle for the digit-plane gemv kernel."""

from __future__ import annotations

import jax.numpy as jnp


def bitplane_gemv_ref(x: jnp.ndarray, digits: jnp.ndarray) -> jnp.ndarray:
    """Exact integer ``x @ (sum_w 2^w digits[w])`` in int32.

    x: (B, R) integer; digits: (W, R, C) in {-1, 0, 1}.
    """
    w = digits.shape[0]
    xi = x.astype(jnp.int32)
    out = jnp.zeros((x.shape[0], digits.shape[2]), jnp.int32)
    for b in range(w):
        out = out + ((xi @ digits[b].astype(jnp.int32)) << b)
    return out


def dense_gemv_ref(x: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Direct dense integer product (ground truth for both paths)."""
    return x.astype(jnp.int32) @ v.astype(jnp.int32)
