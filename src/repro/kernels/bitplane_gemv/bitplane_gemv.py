"""Pallas TPU kernel: digit-plane gemv for fixed integer matrices.

TPU-native form of the paper's bit-serial multiplier (Sec. III): the fixed
matrix is decomposed offline into signed digit planes ``d_w in {-1,0,1}``
(PN or CSD, see ``repro.core.bitplanes``), and

    y = x @ V  =  sum_w  (x @ d_w) << w

Each plane product is an int8 x int8 -> int32 matmul that maps directly onto
the MXU; the plane loop is a *static* Python loop, so planes whose block is
all-zero can be culled at trace time — the MXU-granular analogue of the
paper's constant propagation ("we can cull the AND gate ... and replace the
adder with a single flip-flop").

Grid: ``(C/bc, R/br)`` with the reduction dimension innermost; the output
block is revisited across the reduction steps and accumulated in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_R = 128
DEFAULT_BLOCK_C = 128


def _kernel(x_ref, dig_ref, o_ref, *, width: int, plane_mask: tuple):
    """One (batch, bc) output tile; accumulates over the R grid dimension.

    plane_mask[w] is a trace-time constant: False planes (all-zero in this
    whole matrix) are culled from the unrolled loop entirely.
    """
    r_step = pl.program_id(1)

    @pl.when(r_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.int32)  # (B, br)
    acc = jnp.zeros(o_ref.shape, jnp.int32)
    for w in range(width):
        if not plane_mask[w]:
            continue  # trace-time constant propagation
        d = dig_ref[w].astype(jnp.int32)  # (br, bc)
        acc = acc + ((x @ d) << w)
    o_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("block_r", "block_c", "plane_mask",
                                             "interpret"))
def bitplane_gemv(
    x: jnp.ndarray,
    digits: jnp.ndarray,
    *,
    block_r: int = DEFAULT_BLOCK_R,
    block_c: int = DEFAULT_BLOCK_C,
    plane_mask: tuple | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """``y[b, c] = sum_r x[b, r] * V[r, c]`` via digit planes.

    Args:
        x: (B, R) int8/int32 activations (R divisible by block_r).
        digits: (W, R, C) int8 planes in {-1, 0, 1} with V = sum 2^w digits[w].
        plane_mask: per-plane keep flags (None keeps all planes).
        interpret: run the Pallas interpreter (CPU container); on real TPU
            pass False.

    Returns:
        (B, C) int32 exact integer product.
    """
    b, r = x.shape
    w, r2, c = digits.shape
    assert r == r2, (x.shape, digits.shape)
    assert r % block_r == 0 and c % block_c == 0, "pad R/C to block multiples"
    if plane_mask is None:
        plane_mask = tuple([True] * w)

    grid = (c // block_c, r // block_r)
    return pl.pallas_call(
        functools.partial(_kernel, width=w, plane_mask=tuple(plane_mask)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, block_r), lambda ci, ri: (0, ri)),
            pl.BlockSpec((w, block_r, block_c), lambda ci, ri: (0, ri, ci)),
        ],
        out_specs=pl.BlockSpec((b, block_c), lambda ci, ri: (0, ci)),
        interpret=interpret,
    )(x, digits)
