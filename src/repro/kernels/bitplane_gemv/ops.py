"""ExecutionPlan -> padded digit planes -> Pallas gemv.

The digit decomposition, MXU padding and whole-plane cull mask all come
from the shared :mod:`repro.plan` lowering; this wrapper only pads the
activations and dispatches.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.sparse import FixedMatrix
from repro.kernels.bitplane_gemv.bitplane_gemv import bitplane_gemv
from repro.plan import ExecutionPlan, plan_for


def digits_from_fixed(fm: FixedMatrix) -> np.ndarray:
    """Signed digit planes (W, R, C) int8 via the shared ExecutionPlan."""
    return plan_for(fm).digits


class BitplaneGemv:
    """Precompiled digit-plane multiplier for one fixed matrix.

    Offline (init): pull the MXU-padded planes and the per-plane cull mask
    from the ExecutionPlan.  Online (``__call__``): one Pallas call, exact
    int32 result.
    """

    def __init__(self, source: FixedMatrix | ExecutionPlan,
                 block_r: int = 128, block_c: int = 128,
                 interpret: bool = True):
        plan = source if isinstance(source, ExecutionPlan) else plan_for(source)
        self.plan = plan
        self.digits = plan.padded_digits(block_r, block_c)
        self.rows, self.cols = plan.shape
        self.block_r, self.block_c = block_r, block_c
        self.interpret = interpret
        # Whole-plane culling: CSD often leaves high planes empty.
        self.plane_mask = plan.plane_mask

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        """x: (B, rows) integer -> (B, cols) int32 exact."""
        b, r = x.shape
        assert r == self.rows, (x.shape, self.rows)
        pad = (-r) % self.block_r
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad)))
        y = bitplane_gemv(x, self.digits,
                          block_r=self.block_r, block_c=self.block_c,
                          plane_mask=self.plane_mask,
                          interpret=self.interpret)
        return y[:, : self.cols]
