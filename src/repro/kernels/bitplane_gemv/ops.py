"""Jitted wrapper: FixedMatrix -> padded digit planes -> Pallas gemv."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.sparse import FixedMatrix
from repro.kernels.bitplane_gemv.bitplane_gemv import bitplane_gemv


def digits_from_fixed(fm: FixedMatrix) -> np.ndarray:
    """Signed digit planes (W, R, C) int8 from a compiled FixedMatrix."""
    return (fm.planes.pos.astype(np.int8) - fm.planes.neg.astype(np.int8))


def _pad_to(x: np.ndarray, axis: int, mult: int) -> np.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths)


class BitplaneGemv:
    """Precompiled digit-plane multiplier for one fixed matrix.

    Offline (init): pad planes to MXU-aligned blocks, compute the per-plane
    cull mask.  Online (``__call__``): one Pallas call, exact int32 result.
    """

    def __init__(self, fm: FixedMatrix, block_r: int = 128, block_c: int = 128,
                 interpret: bool = True):
        dig = digits_from_fixed(fm)                     # (W, R, C)
        dig = _pad_to(_pad_to(dig, 1, block_r), 2, block_c)
        self.digits = jnp.asarray(dig)
        self.rows, self.cols = fm.shape
        self.block_r, self.block_c = block_r, block_c
        self.interpret = interpret
        # Whole-plane culling: CSD often leaves high planes empty.
        self.plane_mask = tuple(bool(np.any(dig[w])) for w in range(dig.shape[0]))

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        """x: (B, rows) integer -> (B, cols) int32 exact."""
        b, r = x.shape
        assert r == self.rows, (x.shape, self.rows)
        pad = (-r) % self.block_r
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad)))
        y = bitplane_gemv(x, self.digits,
                          block_r=self.block_r, block_c=self.block_c,
                          plane_mask=self.plane_mask,
                          interpret=self.interpret)
        return y[:, : self.cols]
