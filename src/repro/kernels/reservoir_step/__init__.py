"""reservoir_step kernel package."""
