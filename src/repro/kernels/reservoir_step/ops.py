"""Jitted wrapper for the fused reservoir step (padding + scan driver)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.reservoir_step.reservoir_step import reservoir_step


def _pad_dim(a: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


class FusedReservoir:
    """Run a whole input sequence through the fused Pallas step via scan."""

    def __init__(self, w: np.ndarray, w_in: np.ndarray, leak: float = 1.0,
                 block: int = 128, interpret: bool = True):
        self.dim = w.shape[0]
        self.block = block
        self.leak = float(leak)
        self.interpret = interpret
        wp = _pad_dim(_pad_dim(jnp.asarray(w, jnp.float32), 0, block), 1, block)
        self.w = wp
        self.w_in = _pad_dim(jnp.asarray(w_in, jnp.float32), 1, block)

    def step(self, x: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
        """x: (B, dim), u: (B, I) -> (B, dim)."""
        xp = _pad_dim(x, 1, self.block)
        nxt = reservoir_step(xp, self.w, u, self.w_in, leak=self.leak,
                             block_r=self.block, block_c=self.block,
                             interpret=self.interpret)
        return nxt[:, : self.dim]

    def run(self, inputs: jnp.ndarray, x0: jnp.ndarray | None = None
            ) -> jnp.ndarray:
        """inputs: (T, B, I) -> states (T, B, dim)."""
        t, b, _ = inputs.shape
        if x0 is None:
            x0 = jnp.zeros((b, self.dim), jnp.float32)

        def body(x, u):
            nxt = self.step(x, u)
            return nxt, nxt

        _, states = jax.lax.scan(body, x0, inputs)
        return states
