"""Pure-jnp oracle for the fused reservoir step."""

from __future__ import annotations

import jax.numpy as jnp


def reservoir_step_ref(x, w, u, w_in, leak: float = 1.0):
    pre = u.astype(jnp.float32) @ w_in.astype(jnp.float32) \
        + x.astype(jnp.float32) @ w.astype(jnp.float32)
    return (1.0 - leak) * x.astype(jnp.float32) + leak * jnp.tanh(pre)
