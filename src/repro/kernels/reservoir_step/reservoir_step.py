"""Pallas TPU kernel: fused reservoir state update.

One step of paper Eq. 1 for a batch of reservoir states:

    x' = (1 - leak) * x + leak * tanh(u @ W_in + x @ W)

Fusing the two matmuls with the activation keeps the (B, block_c) output
tile resident in VMEM across the whole reduction — the recurrent latency
path the paper optimizes is exactly this loop, so on TPU we avoid three
HBM round-trips per step (pre-activation, activation, blend).

Grid: ``(C/bc, R/br)``, reduction innermost.  The input projection
``u @ W_in`` joins the accumulation on the first reduction step; the
leak/tanh epilogue fires on the last.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, u_ref, win_ref, xold_ref, o_ref, *, leak: float,
            n_r: int):
    r_step = pl.program_id(1)

    @pl.when(r_step == 0)
    def _init():
        o_ref[...] = (u_ref[...] @ win_ref[...]).astype(o_ref.dtype)

    o_ref[...] += x_ref[...] @ w_ref[...]

    @pl.when(r_step == n_r - 1)
    def _epilogue():
        o_ref[...] = ((1.0 - leak) * xold_ref[...]
                      + leak * jnp.tanh(o_ref[...]))


@functools.partial(jax.jit, static_argnames=("block_r", "block_c", "leak",
                                             "interpret"))
def reservoir_step(
    x: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,
    w_in: jnp.ndarray,
    *,
    leak: float = 1.0,
    block_r: int = 128,
    block_c: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused ESN update for a state batch.

    Args:
        x: (B, R) current states.
        w: (R, R) fixed reservoir matrix (R divisible by both blocks).
        u: (B, I) inputs.
        w_in: (I, R) input weights.

    Returns:
        (B, R) next states, float32.
    """
    b, r = x.shape
    i = u.shape[1]
    assert w.shape == (r, r) and w_in.shape == (i, r)
    assert r % block_r == 0 and r % block_c == 0
    n_r = r // block_r
    grid = (r // block_c, n_r)
    return pl.pallas_call(
        functools.partial(_kernel, leak=leak, n_r=n_r),
        out_shape=jax.ShapeDtypeStruct((b, r), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, block_r), lambda ci, ri: (0, ri)),       # x
            pl.BlockSpec((block_r, block_c), lambda ci, ri: (ri, ci)),  # w
            pl.BlockSpec((b, i), lambda ci, ri: (0, 0)),              # u
            pl.BlockSpec((i, block_c), lambda ci, ri: (0, ci)),       # w_in
            pl.BlockSpec((b, block_c), lambda ci, ri: (0, ci)),       # x (old)
        ],
        out_specs=pl.BlockSpec((b, block_c), lambda ci, ri: (0, ci)),
        interpret=interpret,
    )(x, w, u, w_in, x)
