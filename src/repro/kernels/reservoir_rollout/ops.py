"""Offline "compile" step: FixedMatrix -> static rollout plan -> Pallas call.

Mirrors the paper's flow: the reservoir matrix is frozen, so the reduction
structure (which blocks exist, which digit planes are populated) is decided
once here, offline, and baked into the kernel as trace-time constants.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.sparse import FixedMatrix
from repro.kernels.reservoir_rollout.reservoir_rollout import reservoir_rollout


def _pad_axis(a: np.ndarray, axis: int, size: int) -> np.ndarray:
    pad = size - a.shape[axis]
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return np.pad(a, widths)


class FusedRollout:
    """Precompiled fused multi-step rollout for one frozen reservoir.

    Offline (init): gather the nonzero tiles (fp32) or the per-plane digit
    tiles (int8) of the FixedMatrix, and build the static per-column
    reduction plan the kernel unrolls.  Online (``__call__``): one Pallas
    launch rolls the whole (T, B) workload, state resident in VMEM.
    """

    def __init__(self, fm: FixedMatrix, w_in, *, leak: float = 1.0,
                 mode: str = "fp32", state_bits: int = 8,
                 interpret: bool = True):
        assert fm.shape[0] == fm.shape[1], "reservoir matrix must be square"
        assert mode in ("fp32", "int8"), mode
        bk = fm.blocks.block
        nbr, nbc = fm.blocks.mask.shape
        assert nbr == nbc
        self.dim = fm.shape[0]
        self.block = bk
        self.rpad = nbc * bk
        self.leak = float(leak)
        self.mode = mode
        self.interpret = interpret
        self.smax = (1 << (state_bits - 1)) - 1
        self.recur_scale = fm.scale / self.smax

        cols = fm.blocks.block_cols
        rows = fm.blocks.block_rows
        if mode == "fp32":
            data = np.asarray(fm.blocks.data, np.float32)
            # Per output column, terms in ascending row order — the same
            # accumulation order as BlockSparse.matmul_ref, so the fused
            # kernel is bit-compatible with the reference path.
            plan = tuple(
                tuple((int(di), int(rows[di]))
                      for di in np.flatnonzero(cols == ci))
                for ci in range(nbc))
            if data.shape[0] == 0:  # all-zero reservoir: ship one dummy tile
                data = np.zeros((1, bk, bk), np.float32)
        else:
            dig = (fm.planes.pos.astype(np.int8)
                   - fm.planes.neg.astype(np.int8))          # (W, R, C)
            width = dig.shape[0]
            dig = _pad_axis(_pad_axis(dig, 1, nbr * bk), 2, nbc * bk)
            tiles = dig.reshape(width, nbr, bk, nbc, bk).transpose(0, 1, 3, 2, 4)
            data = tiles[:, rows, cols]                      # (W, n_nnz, bk, bk)
            # Plane-level culling on top of block-level culling: a plan term
            # exists only where that plane of that block has any set digit.
            plan = tuple(
                tuple((w, int(di), int(rows[di]))
                      for di in np.flatnonzero(cols == ci)
                      for w in range(width)
                      if np.any(data[w, di]))
                for ci in range(nbc))
            if data.shape[1] == 0:
                data = np.zeros((width, 1, bk, bk), np.int8)
        self.w_data = jnp.asarray(data)
        self.col_plan = plan
        self.n_terms = sum(len(p) for p in plan)
        self.w_in = jnp.asarray(
            _pad_axis(np.asarray(w_in, np.float32), 1, self.rpad))

    def __call__(self, u_seq: jnp.ndarray,
                 x0: jnp.ndarray | None = None) -> jnp.ndarray:
        """u_seq: (T, B, I) -> states (T, B, dim)."""
        t, b, _ = u_seq.shape
        if x0 is None:
            x0 = jnp.zeros((b, self.rpad), jnp.float32)
        else:
            x0 = jnp.asarray(x0, jnp.float32)
            x0 = jnp.pad(x0, ((0, 0), (0, self.rpad - x0.shape[1])))
        states = reservoir_rollout(
            u_seq.astype(jnp.float32), self.w_data, self.w_in, x0,
            col_plan=self.col_plan, leak=self.leak, block=self.block,
            mode=self.mode, smax=self.smax, recur_scale=self.recur_scale,
            interpret=self.interpret)
        return states[:, :, : self.dim]
