"""ExecutionPlan -> Pallas rollout launch.

The offline lowering lives in :mod:`repro.plan`: the reservoir matrix is
frozen, so the reduction structure (which blocks exist, which digit
plane-blocks are populated, how the columns band into VMEM) is compiled
once there and consumed here as trace-time constants.  This wrapper only
pads the per-instance operands (w_in, w_out, x0) and dispatches.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.sparse import FixedMatrix
from repro.kernels.reservoir_rollout.reservoir_rollout import reservoir_rollout
from repro.plan import DEFAULT_VMEM_BUDGET, ExecutionPlan, plan_for
from repro.plan.plan import pad_axis


class FusedRollout:
    """Precompiled fused multi-step rollout for one frozen reservoir.

    Offline (init): take the shared :class:`~repro.plan.ExecutionPlan`
    (building it if handed a raw FixedMatrix) and pick the banded rollout
    layout for the requested mode and VMEM budget.  Online (``__call__``):
    one Pallas launch rolls the whole (T, B) workload, state resident in
    VMEM, streaming one band of weight tiles per grid step.

    With ``w_out`` attached, the readout is fused into the launch epilogue
    and ``__call__`` can return predictions instead of (or alongside) the
    state trajectory.
    """

    def __init__(self, source: FixedMatrix | ExecutionPlan, w_in, *,
                 leak: float = 1.0, mode: str = "fp32", state_bits: int = 8,
                 interpret: bool = True, w_out=None,
                 vmem_budget: int | None = DEFAULT_VMEM_BUDGET,
                 readout_every: int = 1):
        plan = source if isinstance(source, ExecutionPlan) else plan_for(source)
        assert plan.shape[0] == plan.shape[1], "reservoir matrix must be square"
        assert mode in ("fp32", "int8"), mode
        assert plan.nbr == plan.nbc
        self.plan = plan
        self.layout = plan.rollout_layout(mode, vmem_budget=vmem_budget)
        self.dim = plan.shape[0]
        self.block = plan.block
        self.rpad = plan.cols_pad
        self.leak = float(leak)
        self.mode = mode
        self.interpret = interpret
        self.readout_every = int(readout_every)
        self.smax = (1 << (state_bits - 1)) - 1
        self.recur_scale = plan.scale / self.smax
        self.n_terms = self.layout.n_terms
        self.w_in = jnp.asarray(
            pad_axis(np.asarray(w_in, np.float32), 1, self.rpad))
        self.w_out = None
        self.out_dim = 0
        if w_out is not None:
            wo = np.asarray(w_out, np.float32)
            assert wo.shape[0] == self.dim, wo.shape
            self.out_dim = wo.shape[1]
            opad = -(-self.out_dim // 128) * 128
            self.w_out = jnp.asarray(
                pad_axis(pad_axis(wo, 0, self.rpad), 1, opad))

    @property
    def n_bands(self) -> int:
        return self.layout.n_bands

    def __call__(self, u_seq: jnp.ndarray, x0: jnp.ndarray | None = None, *,
                 want_states: bool = True, want_preds: bool = False,
                 want_final: bool = False):
        """u_seq: (T, B, I) -> the requested outputs, in order: states
        (T, B, dim), preds (T // readout_every, B, out_dim), final state
        (B, dim).  A bare array when exactly one is requested, else a
        tuple.  ``want_final`` hands back x(T) so a later chunk can
        resume the rollout bit-identically (continuous batching)."""
        assert want_states or want_preds or want_final
        assert not want_preds or self.w_out is not None, \
            "fused readout requested but no w_out attached"
        t, b, _ = u_seq.shape
        if x0 is None:
            x0 = jnp.zeros((b, self.rpad), jnp.float32)
        else:
            x0 = jnp.asarray(x0, jnp.float32)
            x0 = jnp.pad(x0, ((0, 0), (0, self.rpad - x0.shape[1])))
        out = reservoir_rollout(
            u_seq.astype(jnp.float32), self.layout.data, self.w_in, x0,
            self.w_out if want_preds else None,
            band_plans=self.layout.band_plans(), leak=self.leak,
            block=self.block, mode=self.mode, smax=self.smax,
            recur_scale=self.recur_scale, readout_every=self.readout_every,
            want_states=want_states, want_preds=want_preds,
            want_final=want_final, interpret=self.interpret)
        parts = list(out) if isinstance(out, tuple) else [out]
        trimmed = []
        if want_states:
            trimmed.append(parts.pop(0)[:, :, : self.dim])
        if want_preds:
            trimmed.append(parts.pop(0)[:, :, : self.out_dim])
        if want_final:
            trimmed.append(parts.pop(0)[:, : self.dim])
        return trimmed[0] if len(trimmed) == 1 else tuple(trimmed)
