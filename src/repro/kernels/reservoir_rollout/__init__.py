"""reservoir_rollout kernel package: fused T-step batched ESN rollout."""
