"""Pallas TPU kernel: fused batched reservoir rollout.

T steps of paper Eq. 1 for a whole state batch in ONE kernel launch:

    x(n) = (1 - leak) * x(n-1) + leak * f(u(n) @ W_in + x(n-1) @ W)

The grid is ``(T,)`` — TPU grids execute sequentially, so a VMEM scratch
buffer carries the state batch across steps without ever round-tripping to
HBM.  This extends ``reservoir_step.py`` (which fuses the two matmuls and
the leak/tanh epilogue of a *single* step) to the full recurrent loop the
paper specializes: the input projection joins each step's accumulation and
the epilogue fires per output column tile.

The recurrent reduction is driven by a *static* per-column plan derived
from :class:`repro.core.sparse.FixedMatrix`'s BCSR mask: the Python loop
over nonzero blocks unrolls at trace time, so zero blocks cost nothing —
the MXU analogue of the paper's synthesis-time adder culling.  Two modes:

* ``fp32``  — dequantized block data, bit-compatible with
  ``BlockSparse.matmul_ref`` accumulation order.
* ``int8``  — exact digit-plane arithmetic (paper [16]): the state batch is
  requantized every step, the recurrent product runs as shifted int32
  plane-block dots (plan entries carry the plane index, so empty
  plane-blocks are culled too), then is rescaled for the activation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rollout_fp32_kernel(u_ref, w_ref, win_ref, x0_ref, o_ref, x_ref, *,
                         col_plan, leak: float, block: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _load_initial_state():
        x_ref[...] = x0_ref[...]

    x = x_ref[...]
    u = u_ref[0]
    for ci, terms in enumerate(col_plan):
        sl = slice(ci * block, (ci + 1) * block)
        acc = None
        for di, ri in terms:
            xs = x[:, ri * block:(ri + 1) * block]
            contrib = xs @ w_ref[di]
            acc = contrib if acc is None else acc + contrib
        pre = u @ win_ref[:, sl]
        if acc is not None:
            pre = pre + acc
        o_ref[0, :, sl] = (1.0 - leak) * x[:, sl] + leak * jnp.tanh(pre)
    x_ref[...] = o_ref[0]


def _rollout_int8_kernel(u_ref, dig_ref, win_ref, x0_ref, o_ref, x_ref, *,
                         col_plan, leak: float, block: int, smax: int,
                         recur_scale: float):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _load_initial_state():
        x_ref[...] = x0_ref[...]

    x = x_ref[...]
    # Per-step state requantization, exactly as esn._step_int8 does it.
    xq = jnp.clip(jnp.round(x * smax), -smax - 1, smax).astype(jnp.int32)
    u = u_ref[0]
    b = x.shape[0]
    for ci, terms in enumerate(col_plan):
        sl = slice(ci * block, (ci + 1) * block)
        acc = jnp.zeros((b, block), jnp.int32)
        for w, di, ri in terms:
            xs = xq[:, ri * block:(ri + 1) * block]
            acc = acc + ((xs @ dig_ref[w, di].astype(jnp.int32)) << w)
        recur = acc.astype(jnp.float32) * recur_scale
        pre = u @ win_ref[:, sl] + recur
        o_ref[0, :, sl] = (1.0 - leak) * x[:, sl] + leak * jnp.tanh(pre)
    x_ref[...] = o_ref[0]


@functools.partial(jax.jit, static_argnames=(
    "col_plan", "leak", "block", "mode", "smax", "recur_scale", "interpret"))
def reservoir_rollout(
    u_seq: jnp.ndarray,
    w_data: jnp.ndarray,
    w_in: jnp.ndarray,
    x0: jnp.ndarray,
    *,
    col_plan: tuple,
    leak: float = 1.0,
    block: int = 128,
    mode: str = "fp32",
    smax: int = 127,
    recur_scale: float = 1.0,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused T-step rollout for a state batch.

    Args:
        u_seq: (T, B, I) inputs, float32.
        w_data: fp32 mode — (n_nnz, block, block) float32 nonzero tiles of
            the reservoir matrix; int8 mode — (width, n_nnz, block, block)
            int8 signed digit planes gathered over the same tile list.
        w_in: (I, R) input weights, R padded to a multiple of ``block``.
        x0: (B, R) initial states.
        col_plan: static nested tuple; entry ``ci`` lists the reduction
            terms for output column block ``ci`` — fp32: ``(data_idx,
            row_block)`` pairs; int8: ``(plane, data_idx, row_block)``
            triples.  Zero blocks (and empty plane-blocks) simply never
            appear, so they are culled at trace time.
        leak: leak rate of Eq. 1.
        mode: "fp32" or "int8".
        smax / recur_scale: int8-mode state quantization range and the
            ``scale / smax`` factor restoring float pre-activations.

    Returns:
        (T, B, R) state trajectory, float32.
    """
    t, b, i = u_seq.shape
    r = x0.shape[1]
    assert r % block == 0 and w_in.shape == (i, r), (u_seq.shape, w_in.shape)
    assert len(col_plan) == r // block
    if mode == "int8":
        kernel = functools.partial(
            _rollout_int8_kernel, col_plan=col_plan, leak=leak, block=block,
            smax=smax, recur_scale=recur_scale)
    else:
        kernel = functools.partial(
            _rollout_fp32_kernel, col_plan=col_plan, leak=leak, block=block)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((t, b, r), jnp.float32),
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, b, i), lambda ti: (ti, 0, 0)),          # u(t)
            pl.BlockSpec(w_data.shape,
                         lambda ti, _n=w_data.ndim: (0,) * _n),      # tiles
            pl.BlockSpec((i, r), lambda ti: (0, 0)),                 # w_in
            pl.BlockSpec((b, r), lambda ti: (0, 0)),                 # x0
        ],
        out_specs=pl.BlockSpec((1, b, r), lambda ti: (ti, 0, 0)),
        scratch_shapes=[pltpu.VMEM((b, r), jnp.float32)],            # state
        interpret=interpret,
    )(u_seq, w_data, w_in, x0)
