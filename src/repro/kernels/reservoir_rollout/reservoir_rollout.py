"""Pallas TPU kernel: fused batched reservoir rollout (banded, fused readout).

T steps of paper Eq. 1 for a whole state batch in ONE kernel launch:

    x(n) = (1 - leak) * x(n-1) + leak * f(u(n) @ W_in + x(n-1) @ W)
    y(n) = x(n) @ W_out                                       (optional, Eq. 2)

The grid is ``(T, n_bands)`` — TPU grids execute sequentially, so VMEM
scratch carries the state batch across steps while each inner grid step
streams exactly ONE band's weight tiles into VMEM.  Bands come from the
:class:`repro.plan.ExecutionPlan` lowering: output column blocks are packed
into bands whose tiles fit the VMEM budget, which is what lets dim-2048
fp32 rollouts compile instead of overflowing scratch.  With one band this
degenerates to the original whole-matrix-resident kernel.

The reduction is driven by the plan's *static* per-band term lists — the
Python loops unroll at trace time, so culled blocks (and, in int8 mode,
culled digit plane-blocks) cost nothing: the MXU analogue of the paper's
synthesis-time adder culling.  Two modes share one kernel body:

* ``fp32``  — dequantized tiles, bit-compatible with
  ``BlockSparse.matmul_ref`` accumulation order (shift is 0 and unused).
* ``int8``  — exact digit-plane arithmetic: the state batch is requantized
  every step and each term is a shifted int32 plane-tile dot.

The optional fused readout applies ``W_out`` to the new state inside the
launch (at every step, or every ``readout_every`` steps), so serving can
return predictions without ever materializing the state trajectory in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rollout_kernel(*refs, band_plans, leak, block, mode, smax, recur_scale,
                    n_bands, n_steps, readout_every, want_states, want_preds,
                    want_final):
    if want_preds:
        u_ref, w_ref, win_ref, wout_ref, x0_ref, *rest = refs
    else:
        u_ref, w_ref, win_ref, x0_ref, *rest = refs
        wout_ref = None
    o_ref = rest.pop(0) if want_states else None
    y_ref = rest.pop(0) if want_preds else None
    f_ref = rest.pop(0) if want_final else None
    x_ref, nx_ref = rest

    t = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when((t == 0) & (k == 0))
    def _load_initial_state():
        x_ref[...] = x0_ref[...]

    x = x_ref[...]
    u = u_ref[0]
    if mode == "int8":
        # Per-step state requantization, exactly as esn._step_int8 does it.
        xq = jnp.clip(jnp.round(x * smax), -smax - 1, smax).astype(jnp.int32)
    b = x.shape[0]

    for bi, cols in enumerate(band_plans):
        @pl.when(k == bi)
        def _run_band(cols=cols):
            # w_ref holds band bi's tiles exactly when k == bi (BlockSpec).
            for ci, terms in cols:
                sl = slice(ci * block, (ci + 1) * block)
                if mode == "fp32":
                    acc = None
                    for slot, _shift, ri in terms:
                        xs = x[:, ri * block:(ri + 1) * block]
                        contrib = xs @ w_ref[0, slot]
                        acc = contrib if acc is None else acc + contrib
                    pre = u @ win_ref[:, sl]
                    if acc is not None:
                        pre = pre + acc
                else:
                    acc = jnp.zeros((b, block), jnp.int32)
                    for slot, shift, ri in terms:
                        xs = xq[:, ri * block:(ri + 1) * block]
                        acc = acc + (
                            (xs @ w_ref[0, slot].astype(jnp.int32)) << shift)
                    recur = acc.astype(jnp.float32) * recur_scale
                    pre = u @ win_ref[:, sl] + recur
                nx_ref[:, sl] = (1.0 - leak) * x[:, sl] + leak * jnp.tanh(pre)

    @pl.when(k == n_bands - 1)
    def _commit_step():
        nx = nx_ref[...]
        x_ref[...] = nx
        if want_states:
            o_ref[0] = nx
        if want_final:
            # The chunked-serving carry: x(T) leaves the launch as its own
            # (B, R) output so a later chunk can resume bit-identically.
            @pl.when(t == n_steps - 1)
            def _emit_final_state():
                f_ref[...] = nx
        if want_preds:
            if readout_every == 1:
                y_ref[0] = nx @ wout_ref[...]
            else:
                @pl.when((t + 1) % readout_every == 0)
                def _emit_readout():
                    y_ref[0] = nx @ wout_ref[...]


@functools.partial(jax.jit, static_argnames=(
    "band_plans", "leak", "block", "mode", "smax", "recur_scale",
    "readout_every", "want_states", "want_preds", "want_final", "interpret"))
def reservoir_rollout(
    u_seq: jnp.ndarray,
    w_data: jnp.ndarray,
    w_in: jnp.ndarray,
    x0: jnp.ndarray,
    w_out: jnp.ndarray | None = None,
    *,
    band_plans: tuple,
    leak: float = 1.0,
    block: int = 128,
    mode: str = "fp32",
    smax: int = 127,
    recur_scale: float = 1.0,
    readout_every: int = 1,
    want_states: bool = True,
    want_preds: bool = False,
    want_final: bool = False,
    interpret: bool = True,
):
    """Fused T-step rollout for a state batch, optionally banded + readout.

    Args:
        u_seq: (T, B, I) inputs, float32.
        w_data: (n_bands, max_terms, block, block) banded weight tiles from
            ``ExecutionPlan.rollout_layout`` — float32 dequantized tiles
            (fp32 mode) or int8 digit-plane tiles (int8 mode).
        w_in: (I, R) input weights, R padded to a multiple of ``block``.
        x0: (B, R) initial states.
        w_out: (R, O) readout weights (required iff ``want_preds``), O
            padded to a lane multiple.
        band_plans: static nested tuple, one entry per band; each entry
            lists ``(ci, ((slot, shift, row_block), ...))`` per output
            column block.  Culled blocks/plane-blocks never appear.
        leak: leak rate of Eq. 1.
        mode: "fp32" or "int8".
        smax / recur_scale: int8-mode state quantization range and the
            ``scale / smax`` factor restoring float pre-activations.
        readout_every: emit predictions every k steps (k must divide T).
        want_states / want_preds: which outputs to materialize; dropping
            states keeps the trajectory entirely in VMEM.
        want_final: additionally emit x(T), the post-rollout state batch
            (B, R) — the carry the chunked scheduler resumes from.

    Returns:
        The requested outputs in the order states (T, B, R),
        preds (T // readout_every, B, O), final state (B, R) — a bare
        array when exactly one of ``want_states`` / ``want_preds`` /
        ``want_final`` is set, else a tuple.
    """
    t, b, i = u_seq.shape
    r = x0.shape[1]
    n_bands, max_terms = w_data.shape[:2]
    assert r % block == 0 and w_in.shape == (i, r), (u_seq.shape, w_in.shape)
    assert len(band_plans) == n_bands
    assert want_states or want_preds or want_final
    if want_preds:
        assert w_out is not None and w_out.shape[0] == r, w_out
        assert t % readout_every == 0, (t, readout_every)
        o = w_out.shape[1]

    kernel = functools.partial(
        _rollout_kernel, band_plans=band_plans, leak=leak, block=block,
        mode=mode, smax=smax, recur_scale=recur_scale, n_bands=n_bands,
        n_steps=t, readout_every=readout_every, want_states=want_states,
        want_preds=want_preds, want_final=want_final)

    in_specs = [
        pl.BlockSpec((1, b, i), lambda ti, ki: (ti, 0, 0)),        # u(t)
        pl.BlockSpec((1, max_terms, block, block),
                     lambda ti, ki: (ki, 0, 0, 0)),                # band tiles
        pl.BlockSpec((i, r), lambda ti, ki: (0, 0)),               # w_in
    ]
    operands = [u_seq, w_data, w_in]
    if want_preds:
        in_specs.append(pl.BlockSpec((r, o), lambda ti, ki: (0, 0)))
        operands.append(w_out)
    in_specs.append(pl.BlockSpec((b, r), lambda ti, ki: (0, 0)))   # x0
    operands.append(x0)

    out_shapes, out_specs = [], []
    if want_states:
        out_shapes.append(jax.ShapeDtypeStruct((t, b, r), jnp.float32))
        out_specs.append(pl.BlockSpec((1, b, r), lambda ti, ki: (ti, 0, 0)))
    if want_preds:
        out_shapes.append(jax.ShapeDtypeStruct(
            (t // readout_every, b, o), jnp.float32))
        out_specs.append(pl.BlockSpec(
            (1, b, o),
            lambda ti, ki, _k=readout_every: (ti // _k, 0, 0)))
    if want_final:
        out_shapes.append(jax.ShapeDtypeStruct((b, r), jnp.float32))
        out_specs.append(pl.BlockSpec((b, r), lambda ti, ki: (0, 0)))

    single = len(out_shapes) == 1
    out = pl.pallas_call(
        kernel,
        out_shape=out_shapes[0] if single else tuple(out_shapes),
        grid=(t, n_bands),
        in_specs=in_specs,
        out_specs=out_specs[0] if single else tuple(out_specs),
        scratch_shapes=[pltpu.VMEM((b, r), jnp.float32),           # state
                        pltpu.VMEM((b, r), jnp.float32)],          # next state
        interpret=interpret,
    )(*operands)
    return out
