"""Pure-jnp oracles for the fused reservoir rollout (fp32 + int8)."""

from __future__ import annotations

import jax.numpy as jnp


def rollout_fp32_ref(u_seq, w, w_in, x0, *, leak: float = 1.0):
    """(T, B, I) inputs through a dense reservoir matrix, python-loop scan."""
    x = x0.astype(jnp.float32)
    states = []
    for t in range(u_seq.shape[0]):
        pre = u_seq[t].astype(jnp.float32) @ w_in + x @ w
        x = (1.0 - leak) * x + leak * jnp.tanh(pre)
        states.append(x)
    return jnp.stack(states)


def rollout_int8_ref(u_seq, q, scale, w_in, x0, *, leak: float = 1.0,
                     state_bits: int = 8):
    """Exact integer-reservoir rollout: per-step state requantization.

    ``q`` is the int8 quantized reservoir matrix; the recurrent product is
    exact int32, rescaled by ``scale / smax`` — the same semantics as
    ``repro.core.esn._step_int8``.
    """
    smax = (1 << (state_bits - 1)) - 1
    x = x0.astype(jnp.float32)
    states = []
    for t in range(u_seq.shape[0]):
        xq = jnp.clip(jnp.round(x * smax), -smax - 1, smax).astype(jnp.int32)
        recur = (xq @ q.astype(jnp.int32)).astype(jnp.float32)
        recur = recur * (scale / smax)
        pre = u_seq[t].astype(jnp.float32) @ w_in + recur
        x = (1.0 - leak) * x + leak * jnp.tanh(pre)
        states.append(x)
    return jnp.stack(states)
