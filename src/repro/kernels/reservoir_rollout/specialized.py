"""Plan-specialized Pallas rollout: resident weights, band pipeline, tiles.

The generic banded kernel (:mod:`.reservoir_rollout`) streams every band of
weight tiles from HBM on every one of the T grid steps — the roofline's
"weights re-read every token".  This kernel consumes a
:class:`repro.plan.RolloutProgram` instead and executes whichever regime
the plan selected:

* **resident** — all kept (folded) tiles fit the VMEM budget, so the
  weight operand uses a *constant* index map: Pallas fetches the block
  once and every later grid step reuses the on-chip copy — zero per-step
  weight traffic, the software analogue of the paper's spatially-resident
  matrix.  Grid: ``(T, B_tiles)``.
* **pipelined** — tiles exceed the budget; output columns are packed into
  bands of at most *half* the budget and the band axis streams.
  Pallas's pipeline emitter double-buffers the streamed operand: band
  ``k+1``'s DMA is issued while band ``k`` reduces.
  Grid: ``(T, n_bands, B_tiles)`` — the band axis sits OUTSIDE the batch
  tiles, so each band's tiles are fetched once per step and stay
  resident across the whole batch-tile sweep (band-inside-tiles would
  re-stream every band once per tile, multiplying exactly the HBM
  traffic this regime exists to bound).

Both regimes tile the batch axis: each grid step works on one
``b_tile``-row slice of the state, so a batch-64 rollout no longer runs
its compute as one monolithic VMEM block (the state carry itself is a
(B, R) scratch either way; in the resident regime the next-state scratch
shrinks to one tile).

The schedule's terms are the program's constant-propagated lowering:
``MM`` terms multiply a *folded* tile (int8 planes collapsed into the
quantized block — one int32 MXU pass instead of ``width`` shifted plane
passes) and ``SA`` terms unroll a sparse plane's few set digits as static
shift-adds.  int8 terms accumulate in exact int32, so any schedule is
bit-identical to the generic kernel; fp32 terms keep its ascending-row
order for the same guarantee.
"""

from __future__ import annotations

import collections
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import obs
from repro.core.sparse import FixedMatrix
from repro.plan import (DEFAULT_BATCH_TILE, DEFAULT_VMEM_BUDGET,
                        ExecutionPlan, plan_for, specialize_rollout)
from repro.plan.plan import pad_axis
from repro.plan.specialize import MM


def _specialized_kernel(*refs, schedules, leak, block, mode, smax,
                        recur_scale, n_bands, b_tile, n_steps, readout_every,
                        want_states, want_preds, want_final):
    if want_preds:
        u_ref, w_ref, win_ref, wout_ref, x0_ref, *rest = refs
    else:
        u_ref, w_ref, win_ref, x0_ref, *rest = refs
        wout_ref = None
    o_ref = rest.pop(0) if want_states else None
    y_ref = rest.pop(0) if want_preds else None
    f_ref = rest.pop(0) if want_final else None
    x_ref, nx_ref = rest

    t = pl.program_id(0)
    if n_bands > 1:
        # pipelined grid (T, n_bands, B_tiles): the band axis is OUTSIDE
        # the batch tiles so each band's weights stream once per step
        k, bt = pl.program_id(1), pl.program_id(2)
    else:
        # resident grid (T, B_tiles): the weight block index never
        # changes, so the tiles were fetched exactly once
        k, bt = None, pl.program_id(1)
    bsl = pl.ds(bt * b_tile, b_tile)
    # next-state scratch: one batch tile suffices in the resident regime
    # (reduce + commit happen in the same grid step); the pipelined
    # regime interleaves batch tiles between a tile's bands, so every
    # tile's partials must stay live
    nsl = bsl if k is not None else pl.ds(0, b_tile)

    first_visit = (t == 0) if k is None else ((t == 0) & (k == 0))

    @pl.when(first_visit)
    def _seed_state():
        # each batch tile seeds its own state slice on its first visit
        x_ref[bsl, :] = x0_ref[...]

    x = x_ref[bsl, :]
    u = u_ref[0]
    if mode == "int8":
        # per-step state requantization, exactly as the generic kernel
        xq = jnp.clip(jnp.round(x * smax), -smax - 1, smax).astype(jnp.int32)

    def run_band(cols):
        for ci, terms in cols:
            sl = slice(ci * block, (ci + 1) * block)
            if mode == "fp32":
                # ascending-row matmul order matches the generic kernel
                acc = None
                for _tag, slot, _shift, ri in terms:
                    xs = x[:, ri * block:(ri + 1) * block]
                    contrib = xs @ w_ref[0, slot]
                    acc = contrib if acc is None else acc + contrib
                pre = u @ win_ref[:, sl]
                if acc is not None:
                    pre = pre + acc
            else:
                # exact int32 accumulation: folded tiles + shift-add digits
                acc = jnp.zeros((b_tile, block), jnp.int32)
                for term in terms:
                    if term[0] == MM:
                        _tag, slot, shift, ri = term
                        xs = xq[:, ri * block:(ri + 1) * block]
                        acc = acc + (
                            (xs @ w_ref[0, slot].astype(jnp.int32)) << shift)
                    else:
                        _tag, ri, digits = term
                        for i, j, s, w in digits:
                            col = xq[:, ri * block + i] << w
                            acc = acc.at[:, j].add(col if s > 0 else -col)
                recur = acc.astype(jnp.float32) * recur_scale
                pre = u @ win_ref[:, sl] + recur
            nx_ref[nsl, sl] = (1.0 - leak) * x[:, sl] + leak * jnp.tanh(pre)

    def commit():
        nx = nx_ref[nsl, :]
        x_ref[bsl, :] = nx
        if want_states:
            o_ref[0] = nx
        if want_final:
            @pl.when(t == n_steps - 1)
            def _emit_final_state():
                f_ref[...] = nx
        if want_preds:
            if readout_every == 1:
                y_ref[0] = nx @ wout_ref[...]
            else:
                @pl.when((t + 1) % readout_every == 0)
                def _emit_readout():
                    y_ref[0] = nx @ wout_ref[...]

    if k is None:
        run_band(schedules[0])
        commit()
    else:
        for bi_, cols in enumerate(schedules):
            @pl.when(k == bi_)
            def _run_band(cols=cols):
                run_band(cols)

        @pl.when(k == n_bands - 1)
        def _commit_step():
            commit()


def specialized_rollout(
    u_seq: jnp.ndarray,
    w_data: jnp.ndarray,
    w_in: jnp.ndarray,
    x0: jnp.ndarray,
    w_out: jnp.ndarray | None = None,
    *,
    schedules: tuple,
    leak: float = 1.0,
    block: int = 128,
    mode: str = "fp32",
    smax: int = 127,
    recur_scale: float = 1.0,
    b_tile: int | None = None,
    readout_every: int = 1,
    want_states: bool = True,
    want_preds: bool = False,
    want_final: bool = False,
    interpret: bool = True,
):
    """Launch one program-specialized rollout (see module docstring).

    ``u_seq`` is (T, B_pad, I) with ``B_pad`` already padded to a multiple
    of ``b_tile`` (the :class:`SpecializedRollout` wrapper handles this).
    Outputs mirror :func:`..reservoir_rollout.reservoir_rollout`: states /
    preds / final state in that order, bare when only one is requested.
    """
    t, b_pad, i = u_seq.shape
    r = x0.shape[1]
    n_bands, max_terms = w_data.shape[:2]
    b_tile = b_pad if b_tile is None else b_tile
    assert b_pad % b_tile == 0, (b_pad, b_tile)
    n_btiles = b_pad // b_tile
    assert r % block == 0 and w_in.shape == (i, r), (u_seq.shape, w_in.shape)
    assert len(schedules) == n_bands
    assert want_states or want_preds or want_final
    if want_preds:
        assert w_out is not None and w_out.shape[0] == r, w_out
        assert t % readout_every == 0, (t, readout_every)
        o = w_out.shape[1]

    kernel = functools.partial(
        _specialized_kernel, schedules=schedules, leak=leak, block=block,
        mode=mode, smax=smax, recur_scale=recur_scale, n_bands=n_bands,
        b_tile=b_tile, n_steps=t, readout_every=readout_every,
        want_states=want_states, want_preds=want_preds,
        want_final=want_final)

    # pipelined: bands OUTSIDE batch tiles (see kernel docstring)
    grid = (t, n_btiles) if n_bands == 1 else (t, n_bands, n_btiles)

    def im(f):
        """Arity-matched index map over the logical (ti, bi, ki) ids."""
        if n_bands == 1:
            return lambda ti, bi: f(ti, bi, 0)
        return lambda ti, ki, bi: f(ti, bi, ki)

    in_specs = [
        pl.BlockSpec((1, b_tile, i),
                     im(lambda ti, bi, ki: (ti, bi, 0))),       # u(t) tile
        # resident: ki is constant 0 -> the tiles are fetched exactly once;
        # pipelined: the band axis streams (and double-buffers) the tiles
        pl.BlockSpec((1, max_terms, block, block),
                     im(lambda ti, bi, ki: (ki, 0, 0, 0))),
        pl.BlockSpec((i, r), im(lambda ti, bi, ki: (0, 0))),    # w_in
    ]
    operands = [u_seq, w_data, w_in]
    if want_preds:
        in_specs.append(pl.BlockSpec((r, o), im(lambda ti, bi, ki: (0, 0))))
        operands.append(w_out)
    in_specs.append(pl.BlockSpec((b_tile, r),
                                 im(lambda ti, bi, ki: (bi, 0))))  # x0 tile
    operands.append(x0)

    out_shapes, out_specs = [], []
    if want_states:
        out_shapes.append(jax.ShapeDtypeStruct((t, b_pad, r), jnp.float32))
        out_specs.append(pl.BlockSpec(
            (1, b_tile, r), im(lambda ti, bi, ki: (ti, bi, 0))))
    if want_preds:
        out_shapes.append(jax.ShapeDtypeStruct(
            (t // readout_every, b_pad, o), jnp.float32))
        out_specs.append(pl.BlockSpec(
            (1, b_tile, o),
            im(lambda ti, bi, ki, _k=readout_every: (ti // _k, bi, 0))))
    if want_final:
        out_shapes.append(jax.ShapeDtypeStruct((b_pad, r), jnp.float32))
        out_specs.append(pl.BlockSpec(
            (b_tile, r), im(lambda ti, bi, ki: (bi, 0))))

    single = len(out_shapes) == 1
    return pl.pallas_call(
        kernel,
        out_shape=out_shapes[0] if single else tuple(out_shapes),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs[0] if single else tuple(out_specs),
        scratch_shapes=[pltpu.VMEM((b_pad, r), jnp.float32),     # state
                        # next state: one tile suffices when reduce and
                        # commit share a grid step (resident regime)
                        pltpu.VMEM((b_tile if n_bands == 1 else b_pad, r),
                                   jnp.float32)],
        interpret=interpret,
    )(*operands)


class SpecializedRollout:
    """Program-driven fused rollout for one frozen reservoir.

    Drop-in for :class:`..ops.FusedRollout` with the plan-specialized
    lowering behind it: the regime (resident / pipelined), batch tiling
    and the folded/shift-add schedule all come from
    :func:`repro.plan.specialize_rollout`.  Each instance jits its own
    launch so it can offer a state-donating variant for the zero-copy
    chunk API and count its traces (``trace_counts``) for recompilation
    guards.
    """

    def __init__(self, source: FixedMatrix | ExecutionPlan, w_in, *,
                 leak: float = 1.0, mode: str = "fp32", state_bits: int = 8,
                 interpret: bool = True, w_out=None,
                 vmem_budget: int | None = DEFAULT_VMEM_BUDGET,
                 readout_every: int = 1,
                 batch_tile_max: int = DEFAULT_BATCH_TILE,
                 crossover: int | None = None):
        plan = source if isinstance(source, ExecutionPlan) else plan_for(source)
        assert plan.shape[0] == plan.shape[1], "reservoir matrix must be square"
        assert mode in ("fp32", "int8"), mode
        assert plan.nbr == plan.nbc
        self.plan = plan
        self.program = specialize_rollout(
            plan, mode, vmem_budget=vmem_budget, crossover=crossover,
            batch_tile_max=batch_tile_max)
        self.dim = plan.shape[0]
        self.block = plan.block
        self.rpad = plan.cols_pad
        self.leak = float(leak)
        self.mode = mode
        self.interpret = interpret
        self.readout_every = int(readout_every)
        self.smax = (1 << (state_bits - 1)) - 1
        self.recur_scale = plan.scale / self.smax
        self.w_in = jnp.asarray(
            pad_axis(np.asarray(w_in, np.float32), 1, self.rpad))
        self.w_out = None
        self.out_dim = 0
        if w_out is not None:
            wo = np.asarray(w_out, np.float32)
            assert wo.shape[0] == self.dim, wo.shape
            self.out_dim = wo.shape[1]
            opad = -(-self.out_dim // 128) * 128
            self.w_out = jnp.asarray(
                pad_axis(pad_axis(wo, 0, self.rpad), 1, opad))
        self.trace_counts: collections.Counter = collections.Counter()
        self._fns: dict = {}

    @property
    def regime(self) -> str:
        return self.program.regime

    @property
    def n_bands(self) -> int:
        return self.program.n_bands

    def _fn(self, donate: bool):
        fn = self._fns.get(donate)
        if fn is None:
            program, me = self.program, self

            def launch(u_seq, x0, *, want_states, want_preds,
                       want_final, b_tile):
                # trace-time side effect: one tick per compiled program
                # (donate is part of the key — a donated variant is a
                # distinct program, not a recompile)
                tkey = (u_seq.shape, want_states, want_preds,
                        want_final, donate, program.regime)
                me.trace_counts[tkey] += 1
                n = me.trace_counts[tkey]
                obs.event("pallas_trace" if n == 1 else "retrace",
                          backend="pallas", shape=str(u_seq.shape),
                          regime=program.regime, count=n)
                obs.inc("retrace_total" if n > 1
                        else "compile_traces_total", backend="pallas")
                # batch/lane padding AND output trimming live inside the
                # jit: the caller's (B, dim) carried-state buffer is the
                # donated argument itself, and the trimmed (B, dim) final
                # state can reuse it — pre-padding outside would donate a
                # throwaway temporary instead.
                t, b, _i = u_seq.shape
                b_pad = b_tile * (-(-b // b_tile))
                x0 = jnp.pad(x0.astype(jnp.float32),
                             ((0, b_pad - x0.shape[0]),
                              (0, me.rpad - x0.shape[1])))
                if b_pad != b:
                    u_seq = jnp.pad(u_seq, ((0, 0), (0, b_pad - b), (0, 0)))
                out = specialized_rollout(
                    u_seq.astype(jnp.float32), program.data, me.w_in, x0,
                    me.w_out if want_preds else None,
                    schedules=program.schedules, leak=me.leak,
                    block=me.block, mode=me.mode, smax=me.smax,
                    recur_scale=me.recur_scale, b_tile=b_tile,
                    readout_every=me.readout_every,
                    want_states=want_states, want_preds=want_preds,
                    want_final=want_final, interpret=me.interpret)
                parts = list(out) if isinstance(out, tuple) else [out]
                trimmed = []
                if want_states:
                    trimmed.append(parts.pop(0)[:, :b, : me.dim])
                if want_preds:
                    trimmed.append(parts.pop(0)[:, :b, : me.out_dim])
                if want_final:
                    trimmed.append(parts.pop(0)[:b, : me.dim])
                return trimmed[0] if len(trimmed) == 1 else tuple(trimmed)

            fn = jax.jit(
                launch,
                static_argnames=("want_states", "want_preds",
                                 "want_final", "b_tile"),
                donate_argnums=(1,) if donate else ())
            self._fns[donate] = fn
        return fn

    def __call__(self, u_seq: jnp.ndarray, x0: jnp.ndarray | None = None, *,
                 want_states: bool = True, want_preds: bool = False,
                 want_final: bool = False, donate_state: bool = False):
        """u_seq: (T, B, I) -> the requested outputs (states, preds, final
        state), exactly as :class:`..ops.FusedRollout`.  ``donate_state``
        donates ``x0`` to the launch so the emitted final state can reuse
        its buffer (the chunked scheduler's carried slot states)."""
        assert want_states or want_preds or want_final
        assert not want_preds or self.w_out is not None, \
            "fused readout requested but no w_out attached"
        _t, b, _ = u_seq.shape
        b_tile, _n_tiles, _b_pad = self.program.batch_tiling(b)
        if x0 is None:
            x0 = jnp.zeros((b, self.dim), jnp.float32)
        return self._fn(donate_state)(
            u_seq, jnp.asarray(x0), want_states=want_states,
            want_preds=want_preds, want_final=want_final,
            b_tile=b_tile)
