"""Jitted wrapper: BlockSparse -> sorted/padded tile list -> Pallas BCSR."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.sparse import BlockSparse
from repro.kernels.bcsr_matmul.bcsr_matmul import bcsr_matmul


class BcsrMatmul:
    """Precompiled block-sparse multiplier for one fixed BlockSparse matrix.

    Offline: sort tiles by (col, row) so output tiles accumulate on
    consecutive grid steps, and pad a zero tile into every empty output
    column so initialization covers the whole output.
    """

    def __init__(self, bs: BlockSparse, interpret: bool = True):
        self.block = bs.block
        nbr, nbc = bs.mask.shape
        self.rows_pad = nbr * bs.block
        self.cols_pad = nbc * bs.block
        self.shape = bs.shape
        self.interpret = interpret

        data = np.asarray(bs.data)
        cols = bs.block_cols.astype(np.int32)
        rows = bs.block_rows.astype(np.int32)
        # pad empty output columns with a zero tile
        missing = sorted(set(range(nbc)) - set(cols.tolist()))
        if missing:
            zero = np.zeros((len(missing), bs.block, bs.block), data.dtype)
            data = np.concatenate([data, zero], axis=0) if data.size else zero
            cols = np.concatenate([cols, np.asarray(missing, np.int32)])
            rows = np.concatenate([rows, np.zeros(len(missing), np.int32)])
        order = np.lexsort((rows, cols))  # sort by col, then row
        self.data = jnp.asarray(data[order])
        self.cols = jnp.asarray(cols[order])
        self.rows = jnp.asarray(rows[order])
        self.n_tiles = int(self.data.shape[0])

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        b, r = x.shape
        assert r == self.shape[0], (x.shape, self.shape)
        if r != self.rows_pad:
            x = jnp.pad(x, ((0, 0), (0, self.rows_pad - r)))
        y = bcsr_matmul(x, self.data, self.cols, self.rows, self.cols_pad,
                        block=self.block, interpret=self.interpret)
        return y[:, : self.shape[1]]
