"""ExecutionPlan -> sorted/padded BCSR tile list -> Pallas kernel.

The tile sort, empty-column padding and gather all live in
:class:`repro.plan.BcsrLayout`; this wrapper only pads activations and
dispatches.  It accepts a FixedMatrix / ExecutionPlan (the shared compile
path) or a bare BlockSparse (standalone block-sparse matmuls).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.sparse import BlockSparse, FixedMatrix
from repro.kernels.bcsr_matmul.bcsr_matmul import bcsr_matmul
from repro.plan import BcsrLayout, ExecutionPlan, plan_for


class BcsrMatmul:
    """Precompiled block-sparse multiplier over one static tile layout."""

    def __init__(self,
                 source: FixedMatrix | ExecutionPlan | BlockSparse | BcsrLayout,
                 interpret: bool = True):
        if isinstance(source, BcsrLayout):
            layout = source
        elif isinstance(source, ExecutionPlan):
            layout = source.bcsr
        elif isinstance(source, FixedMatrix):
            layout = plan_for(source).bcsr
        else:
            layout = BcsrLayout.from_blocks(source)
        self.layout = layout
        self.interpret = interpret

    # Everything static lives on the layout; expose the public surface
    # as read-only views instead of mirrored copies.
    block = property(lambda self: self.layout.block)
    shape = property(lambda self: self.layout.shape)
    rows_pad = property(lambda self: self.layout.rows_pad)
    cols_pad = property(lambda self: self.layout.cols_pad)
    data = property(lambda self: self.layout.data)
    cols = property(lambda self: self.layout.cols)
    rows = property(lambda self: self.layout.rows)
    n_tiles = property(lambda self: self.layout.n_tiles)

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        b, r = x.shape
        assert r == self.shape[0], (x.shape, self.shape)
        if r != self.rows_pad:
            x = jnp.pad(x, ((0, 0), (0, self.rows_pad - r)))
        y = bcsr_matmul(x, self.data, self.cols, self.rows, self.cols_pad,
                        block=self.block, interpret=self.interpret)
        return y[:, : self.shape[1]]
