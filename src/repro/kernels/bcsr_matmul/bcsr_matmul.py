"""Pallas TPU kernel: block-sparse (BCSR) matmul with static culling.

``y = x @ M`` where M's nonzero-block structure is *fixed* (the paper's
setting: the reservoir matrix never changes).  The grid iterates only the
nonzero blocks — zero blocks are culled before the kernel is even launched,
exactly as the paper's synthesis flow culls adders for zero weights.  Block
coordinates arrive via scalar prefetch so the BlockSpec index maps can
gather the right x / output tiles per step.

The block list must be sorted by (col, row): the output tile for a column
is then revisited on consecutive grid steps and accumulates in VMEM.
Columns with no nonzero blocks are padded with one zero block so every
output tile gets initialized.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(cols_ref, rows_ref, x_ref, blk_ref, o_ref):
    i = pl.program_id(0)
    is_first = i == 0
    prev = cols_ref[jnp.maximum(i - 1, 0)]
    new_col = jnp.logical_or(is_first, cols_ref[i] != prev)

    @pl.when(new_col)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    blk = blk_ref[0]
    o_ref[...] += jax.lax.dot(x, blk.astype(x.dtype),
                              preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("out_cols", "block", "interpret"))
def bcsr_matmul(
    x: jnp.ndarray,
    blocks: jnp.ndarray,
    block_cols: jnp.ndarray,
    block_rows: jnp.ndarray,
    out_cols: int,
    *,
    block: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Block-sparse product over a static structure.

    Args:
        x: (B, R) activations, R divisible by ``block``.
        blocks: (n_blk, block, block) nonzero tiles, sorted by (col, row),
            padded so every output column block appears at least once.
        block_cols / block_rows: (n_blk,) int32 tile coordinates.
        out_cols: C (divisible by ``block``).

    Returns:
        (B, C) in x.dtype's accumulation type (f32 for f32/bf16 in).
    """
    b, r = x.shape
    n_blk = blocks.shape[0]
    assert r % block == 0 and out_cols % block == 0
    out_dtype = jnp.float32 if x.dtype in (jnp.float32, jnp.bfloat16) else jnp.int32

    # Scalar-prefetch grid spec (TPU): coordinates available to index maps.
    from jax.experimental.pallas import tpu as pltpu
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_blk,),
        in_specs=[
            pl.BlockSpec((b, block), lambda i, cols, rows: (0, rows[i])),
            pl.BlockSpec((1, block, block), lambda i, cols, rows: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((b, block), lambda i, cols, rows: (0, cols[i])),
    )
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((b, out_cols), out_dtype),
        grid_spec=grid_spec,
        interpret=interpret,
    )(block_cols, block_rows, x, blocks)
