"""bcsr_matmul kernel package."""
