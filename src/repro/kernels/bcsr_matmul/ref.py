"""Pure-jnp oracle for the BCSR matmul kernel."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def bcsr_matmul_ref(x, blocks, block_cols, block_rows, out_cols, block=128):
    """Dense reconstruction reference: scatter blocks, then one matmul."""
    r = x.shape[-1]
    dense = np.zeros((r, out_cols), dtype=np.asarray(blocks).dtype)
    blks = np.asarray(blocks)
    for i in range(blks.shape[0]):
        br, bc = int(block_rows[i]), int(block_cols[i])
        dense[br * block:(br + 1) * block, bc * block:(bc + 1) * block] += blks[i]
    acc = jnp.float32 if x.dtype in (jnp.float32, jnp.bfloat16) else jnp.int32
    return (x.astype(acc) @ jnp.asarray(dense).astype(acc))
