"""Runtime: fault tolerance, elasticity, stragglers."""
