"""Elastic re-planning + straggler watchdog (fault-tolerance runtime).

On a real cluster the runtime detects failed hosts (missed heartbeats),
shrinks the mesh to the surviving device count, recomputes shardings, and
restores the latest checkpoint into the new topology.  All the policy logic
is here and unit-tested; the detection transport (heartbeats) is a thin
interface a deployment fills in.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

__all__ = ["plan_mesh", "replan_after_failure", "shrink_serve_plan",
           "grow_serve_plan", "swap_serve_plan", "AutoscalePolicy",
           "StragglerWatchdog", "Heartbeats"]


def plan_mesh(n_devices: int, model_parallel: int,
              pods: int = 1) -> tuple[tuple, tuple]:
    """Choose (shape, axis_names) for a device count.

    Keeps model-parallel width fixed (weights must still fit) and gives the
    rest to data parallelism; degrades MP only when unavoidable.
    """
    mp = model_parallel
    while mp > 1 and (n_devices % (mp * pods)) != 0:
        mp //= 2
    dp = n_devices // (mp * pods)
    if pods > 1:
        return (pods, dp, mp), ("pod", "data", "model")
    return (dp, mp), ("data", "model")


def replan_after_failure(prev_devices: int, failed: int, model_parallel: int,
                         pods: int = 1) -> dict:
    """Failure response plan: new mesh + what must happen to state.

    Returns a dict describing the recovery actions in order; the train loop
    executes them (see examples/train_lm.py --simulate-failure).
    """
    survivors = prev_devices - failed
    # shrink to the largest usable device count (keep mesh factorable)
    usable = survivors
    mp = model_parallel
    while usable > 0 and usable % (mp * pods) != 0:
        usable -= 1
    shape, axes = plan_mesh(max(usable, mp * pods), model_parallel, pods)
    return {
        "survivors": survivors,
        "usable_devices": max(usable, mp * pods),
        "mesh_shape": shape,
        "mesh_axes": axes,
        "actions": [
            "barrier: drain in-flight steps",
            "restore latest verified checkpoint (checkpoint.store.restore "
            "with new shardings)",
            f"rescale global batch or keep per-device batch "
            f"(dp {prev_devices // model_parallel} -> "
            f"{max(usable, mp * pods) // (model_parallel * pods)})",
            "resume from restored step counter (data stream is stateless)",
        ],
    }


def shrink_serve_plan(n_shards: int, failed: int) -> dict:
    """Failure response for a data-parallel *serving* pool.

    Serving shards carry no model parallelism (the reservoir is replicated),
    so every survivor count is usable — ``replan_after_failure`` with
    ``model_parallel=1`` gives the new width — but the state that must
    survive is different from training: there is no checkpoint to restore,
    the in-flight reservoir states ARE the recovery payload.  The action
    list reflects that; ``DistributedReservoirServer.shrink`` executes it.
    """
    base = replan_after_failure(n_shards, failed, model_parallel=1)
    base["actions"] = [
        "freeze admission; no new chunk is launched",
        "snapshot per-slot reservoir state x(t) and consumed step counts",
        "rebuild the sharded engine on the surviving mesh (ExecutionPlan "
        "is cached per matrix — no re-lowering)",
        "re-admit in-flight sequences with x0 = snapshot via the global "
        "FIFO (least-loaded shard admission)",
        "resume: queued requests were never lost, they stay in the FIFO",
    ]
    return base


def grow_serve_plan(n_shards: int, added: int,
                    max_shards: int | None = None) -> dict:
    """Scale-up response for a data-parallel serving pool — the inverse
    of :func:`shrink_serve_plan`.

    New shards join under live traffic: the engine is rebuilt on the
    wider mesh (the :class:`ExecutionPlan` is cached per matrix, so this
    is jit setup only, and the per-shard compiled program is unchanged —
    the local sub-pool shape ``(slots_per_shard, chunk_steps, I)`` does
    not depend on the shard count, which is what keeps resumed
    trajectories bit-identical across the rebuild), and the in-flight
    snapshot re-admits through the global FIFO whose least-loaded
    admission rebalances the sub-pools over the wider pool automatically.
    Completed work is never dropped or re-run: produced chunks are
    stitched as prefixes, states resume from the snapshot carry.
    ``DistributedReservoirServer.grow`` executes the plan.
    """
    assert added >= 0
    new_n = n_shards + added
    if max_shards is not None:
        new_n = min(new_n, max_shards)
    shape, axes = plan_mesh(max(new_n, 1), model_parallel=1)
    return {
        "n_shards_before": n_shards,
        "n_shards_after": new_n,
        "added": new_n - n_shards,
        "mesh_shape": shape,
        "mesh_axes": axes,
        "actions": [
            "freeze admission; no new chunk is launched",
            "snapshot per-slot reservoir state x(t), consumed step "
            "counts, and produced chunks",
            "rebuild the sharded engine on the widened mesh "
            "(ExecutionPlan is cached per matrix — no re-lowering; the "
            "per-shard program shape is unchanged)",
            "re-admit in-flight sequences with x0 = snapshot via the "
            "global FIFO — least-loaded shard admission rebalances the "
            "sub-pools across the new width",
            "resume: queued requests were never lost, they stay in the "
            "FIFO and now drain over more shards",
        ],
    }


@dataclasses.dataclass
class AutoscalePolicy:
    """Queue-depth / occupancy driven elastic scaling decisions.

    Consulted by ``DistributedReservoirServer`` once per scheduler step:
    ``decide()`` answers +1 (grow a shard), -1 (retire a shard) or 0.
    Growth triggers when the backlog exceeds ``grow_queue_per_slot``
    queued requests per pool slot — the queue is outrunning the pool;
    scale-down triggers only when the queue is EMPTY and pool occupancy
    sits below ``shrink_occupancy`` — capacity is provably idle.
    ``cooldown_steps`` scheduler steps must pass between decisions so a
    rebuild's re-admission transient never triggers the next decision
    (flap damping).
    """

    min_shards: int = 1
    max_shards: int = 8
    grow_queue_per_slot: float = 1.0
    shrink_occupancy: float = 0.25
    cooldown_steps: int = 4

    def decide(self, *, pending: int, live: int, n_slots: int,
               n_shards: int) -> int:
        if (n_shards < self.max_shards
                and pending > self.grow_queue_per_slot * n_slots):
            return 1
        if (n_shards > self.min_shards and pending == 0
                and live <= self.shrink_occupancy * n_slots):
            return -1
        return 0


def swap_serve_plan(name: str, old_version: int | None,
                    new_version: int) -> dict:
    """Live-swap response for a multi-tenant serving pool.

    Publishing a new version of a served model is the zero-downtime
    analogue of :func:`shrink_serve_plan`: nothing about the mesh changes,
    but the engine behind a tenant's admissions does, and the state that
    must survive is again the in-flight work.  The action list is the
    contract ``ModelRegistry.publish`` executes — compile *before*
    cutover, pin in-flight slots to the engine they started on, and make
    the cutover a single atomic active-version write so no request ever
    observes a half-swapped model.
    """
    return {
        "model": name,
        "previous_version": old_version,
        "version": new_version,
        "actions": [
            "build the new version's engine off-path (plan -> specialize "
            "-> compile; ExecutionPlan cached per registry identity)",
            "prewarm it against every attached server's pool shapes "
            "(chunk program compiled before any request routes to it)",
            "atomic cutover: flip the registry's active version — new "
            "admissions pin the new engine",
            "in-flight slots keep their admission-pinned engine and run "
            "to completion (zero drops, bit-exact both sides)",
            "demote the retired version in the engine LRU so it is first "
            "out once its last pinned slot retires",
        ],
    }


@dataclasses.dataclass
class Heartbeats:
    """Liveness tracking: hosts report; stale hosts are failures."""

    timeout_s: float = 30.0
    _last: dict = dataclasses.field(default_factory=dict)

    def beat(self, host: str, now: Optional[float] = None):
        self._last[host] = now if now is not None else time.monotonic()

    def failed(self, now: Optional[float] = None) -> list:
        now = now if now is not None else time.monotonic()
        return sorted(h for h, t in self._last.items()
                      if now - t > self.timeout_s)


class StragglerWatchdog:
    """Flags steps whose duration exceeds median * threshold.

    At cluster scale the mitigation hook triggers (a) XLA collective
    timeouts tuning, (b) hot-spare promotion; here the policy and detection
    are real and tested, the mitigation is a callback.
    """

    def __init__(self, window: int = 50, threshold: float = 3.0,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.window = window
        self.threshold = threshold
        self.on_straggler = on_straggler
        self.durations: list = []
        self.flagged: list = []

    def record(self, step: int, duration_s: float):
        hist = self.durations[-self.window:]
        if len(hist) >= 5:
            med = float(np.median(hist))
            if duration_s > self.threshold * med:
                self.flagged.append((step, duration_s))
                if self.on_straggler:
                    self.on_straggler(step, duration_s)
        self.durations.append(duration_s)

    @property
    def median(self) -> float:
        return float(np.median(self.durations)) if self.durations else 0.0
