"""Deterministic fault injection for the serving stack.

A :class:`FaultPlan` is a seeded, pre-materialized schedule of failures
on the server's virtual clock — shard deaths, slow-shard (straggler)
chunks, transient engine-call failures, and publish-mid-swap aborts.
Determinism is the point: the sustained-load harness replays the same
trace with and without the plan and asserts every completed request is
bit-identical, and a CI failure under chaos reproduces exactly.

Injection sites (all consult the plan, none depend on wall time):

* ``AsyncReservoirServer.step()`` calls :meth:`FaultPlan.begin_chunk`
  with the server clock, activating any events whose time has come;
* ``ContinuousBatcher.run_chunk()`` calls :meth:`FaultPlan.check_call`
  before each fused engine call — an armed transient fault raises
  :class:`TransientFault` and the batcher retries with capped
  exponential backoff from the slot's last carried state (the inputs
  and the pre-chunk state are untouched, so the replay is bit-identical
  by construction);
* ``DistributedReservoirServer.step()`` drains
  :meth:`FaultPlan.take_dead_shards` and converts them into the
  existing elastic ``shrink()`` path — unplanned shard death becomes a
  planned rebuild with zero request loss;
* straggler windows inflate the chunk's charge on the virtual clock via
  :meth:`FaultPlan.slow_factor` (under ``shard_map`` one straggling
  shard stalls the whole synchronized chunk, so a single pool-wide
  factor is the honest model);
* ``ModelRegistry.publish()`` consults the installed plan via
  :func:`active` and aborts *after* prewarm but *before* the atomic
  cutover when :meth:`FaultPlan.take_publish_abort` fires — the worst
  moment — leaving the old version serving untouched.
"""

from __future__ import annotations

import dataclasses

from repro import obs


class TransientFault(RuntimeError):
    """An injected transient engine-call failure (retryable)."""


class PublishAborted(RuntimeError):
    """An injected abort between prewarm and cutover of a live swap.

    The registry guarantees the active version is unchanged when this
    propagates; the prewarmed version stays registered (inactive) so a
    retry can activate it without recompiling.
    """


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.

    ``kind`` is ``"shard_loss"`` / ``"slow_shard"`` / ``"transient"`` /
    ``"publish_abort"``; ``at`` is the activation time on the server's
    clock.  ``shard`` names the victim for shard faults, ``duration`` /
    ``factor`` shape a straggler window, ``count`` is how many
    consecutive engine calls a transient event fails.
    """

    kind: str
    at: float
    shard: int | None = None
    duration: float = 0.0
    factor: float = 1.0
    count: int = 1


class FaultPlan:
    """A deterministic schedule of :class:`FaultEvent`\\ s plus the
    retry/backoff parameters recovery uses.

    Build one explicitly from events, or :meth:`seeded` for a
    reproducible random schedule over a trace horizon.  The plan is
    consumed as the server clock passes each event's ``at``; ``injected``
    counts what actually fired, keyed by kind.
    """

    def __init__(self, events: list[FaultEvent] | None = None, *,
                 backoff_base_s: float = 0.001, backoff_cap_s: float = 0.05,
                 max_attempts: int = 64):
        self.events = sorted(events or [], key=lambda e: e.at)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.max_attempts = int(max_attempts)
        self.injected: dict[str, int] = {}
        self.now = 0.0
        self._cursor = 0               # next not-yet-activated event
        self._pending_transient = 0    # armed engine-call failures
        self._slow_until = 0.0
        self._slow_factor = 1.0
        self._dead: list[int] = []     # activated, not yet taken
        self._publish_aborts = 0
        self.fault_times: dict[str, list[float]] = {}

    @classmethod
    def seeded(cls, seed: int, *, horizon: float, n_shards: int = 0,
               transient_rate: float = 0.0, slow_rate: float = 0.0,
               shard_loss_times: list[float] | None = None,
               slow_factor: float = 4.0, slow_duration: float = 2.0,
               **kw) -> "FaultPlan":
        """A reproducible random schedule: Poisson-ish transient and
        straggler events over ``[0, horizon)`` from ``seed``, plus
        explicit shard losses (chaos traces pin those so recovery time
        is measured against a known instant)."""
        import numpy as np
        rng = np.random.default_rng(seed)
        events: list[FaultEvent] = []
        for rate, kind in ((transient_rate, "transient"),
                           (slow_rate, "slow_shard")):
            if rate <= 0:
                continue
            t = 0.0
            while True:
                t += float(rng.exponential(1.0 / rate))
                if t >= horizon:
                    break
                if kind == "transient":
                    events.append(FaultEvent("transient", at=t,
                                             count=1 + int(rng.integers(2))))
                else:
                    shard = (int(rng.integers(n_shards)) if n_shards
                             else None)
                    events.append(FaultEvent(
                        "slow_shard", at=t, shard=shard,
                        factor=slow_factor, duration=slow_duration))
        for t in (shard_loss_times or []):
            events.append(FaultEvent("shard_loss", at=float(t), shard=0))
        return cls(events, **kw)

    # -- activation ----------------------------------------------------------
    def begin_chunk(self, now: float) -> None:
        """Advance the plan to the server clock: activate every event
        whose time has come.  Called once per scheduler step."""
        self.now = float(now)
        while (self._cursor < len(self.events)
               and self.events[self._cursor].at <= self.now):
            ev = self.events[self._cursor]
            self._cursor += 1
            self._record(ev.kind)
            if ev.kind == "transient":
                self._pending_transient += ev.count
            elif ev.kind == "slow_shard":
                self._slow_until = max(self._slow_until,
                                       self.now + ev.duration)
                self._slow_factor = max(self._slow_factor, ev.factor)
            elif ev.kind == "shard_loss":
                self._dead.append(0 if ev.shard is None else ev.shard)
            elif ev.kind == "publish_abort":
                self._publish_aborts += ev.count

    def _record(self, kind: str) -> None:
        self.injected[kind] = self.injected.get(kind, 0) + 1
        self.fault_times.setdefault(kind, []).append(self.now)
        obs.inc("faults_injected_total", kind=kind)

    # -- consumption ---------------------------------------------------------
    def check_call(self) -> None:
        """Raise :class:`TransientFault` while transient failures are
        armed (each raise consumes one).  The batcher's retry loop calls
        this before every fused engine launch."""
        if self._pending_transient > 0:
            self._pending_transient -= 1
            raise TransientFault(
                f"injected transient engine-call failure at t={self.now:.3f}")

    def backoff_s(self, attempt: int) -> float:
        """Capped exponential backoff delay for retry ``attempt``
        (0-based)."""
        return min(self.backoff_cap_s,
                   self.backoff_base_s * (2.0 ** attempt))

    def slow_factor(self) -> float:
        """Multiplier on the current chunk's virtual-clock charge — 1.0
        outside straggler windows."""
        if self.now < self._slow_until:
            return self._slow_factor
        self._slow_factor = 1.0
        return 1.0

    def take_dead_shards(self) -> list[int]:
        """Drain shard deaths activated since the last call.  The
        distributed server converts each batch into one ``shrink()``."""
        dead, self._dead = self._dead, []
        return dead

    def arm_publish_abort(self, count: int = 1) -> None:
        """Arm the next ``count`` publishes to abort mid-swap (clock-free
        arming for tests; scheduled ``publish_abort`` events arm the same
        counter)."""
        self._publish_aborts += count
        self._record("publish_abort")

    def take_publish_abort(self) -> bool:
        """Consume one armed publish abort, if any."""
        if self._publish_aborts > 0:
            self._publish_aborts -= 1
            return True
        return False


# -- module-global plan (for sites with no server handle) --------------------
_ACTIVE: FaultPlan | None = None


def install(plan: FaultPlan | None) -> None:
    """Install ``plan`` as the process-global fault plan consulted by
    sites that have no server handle (``ModelRegistry.publish``).
    Servers take their plan explicitly (``fault_plan=``); ``install``
    exists so one plan can cover both.  ``install(None)`` clears."""
    global _ACTIVE
    _ACTIVE = plan


def active() -> FaultPlan | None:
    """The installed process-global plan, or None."""
    return _ACTIVE


__all__ = ["FaultPlan", "FaultEvent", "TransientFault", "PublishAborted",
           "install", "active"]
