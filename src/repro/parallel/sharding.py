"""Logical-axis -> mesh-axis sharding rules (TP / FSDP / EP).

Model code annotates every parameter dim with a logical name (see
``models/common.py``); this module resolves those names against a mesh:

  TP  ('model'):  vocab, heads, kv, ffn, expert, lru
  FSDP('data' [+ 'pod']): embed  — every weight's d_model dim is sharded
      across the data axes, ZeRO-3 style; XLA inserts the per-layer
      all-gathers (params) and reduce-scatters (grads).

A dim is only sharded when its size divides the axis size — e.g. MQA's one
kv head stays replicated on a 16-way model axis rather than failing.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

TP_AXES = ("vocab", "heads", "kv", "ffn", "expert", "lru")


def data_axis_names(mesh: Mesh) -> tuple:
    """The batch/FSDP axes present in this mesh ('pod' composes with 'data')."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_axis_size(mesh: Mesh) -> int:
    """Data-parallel width: product of the batch axes ('pod' x 'data')."""
    return _axis_size(mesh, data_axis_names(mesh))


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def resolve_axes(logical: tuple, shape: tuple, mesh: Mesh,
                 fsdp: bool = True, use_tp: bool = True,
                 expert_fsdp: bool = True) -> P:
    """Logical names + concrete shape -> PartitionSpec (divisibility-safe).

    use_tp=False: the 'model' axis joins the FSDP axes instead of carrying
    tensor parallelism (right for collective-bound models that fit without
    TP).  expert_fsdp=False: weights with an 'expert' dim skip FSDP on
    their other dims (EP-resident experts).
    """
    daxes = data_axis_names(mesh)
    fsdp_axes = daxes if use_tp else daxes + (
        ("model",) if "model" in mesh.axis_names else ())
    is_expert_w = "expert" in logical
    spec: list = []
    used_model = False
    used_data = False
    for name, dim in zip(logical, shape):
        entry = None
        if (name in TP_AXES and use_tp and not used_model
                and "model" in mesh.axis_names):
            if dim % mesh.shape["model"] == 0 and dim > 0:
                entry = "model"
                used_model = True
        elif (name == "embed" and fsdp and not used_data and fsdp_axes
                and not (is_expert_w and not expert_fsdp)):
            n = _axis_size(mesh, fsdp_axes)
            if dim % n == 0 and dim >= n:
                entry = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
                used_data = True
        spec.append(entry)
    return P(*spec)


def param_shardings(axes_tree: Any, shapes_tree: Any, mesh: Mesh,
                    fsdp: bool = True, use_tp: bool = True,
                    expert_fsdp: bool = True) -> Any:
    """Pytree of logical-axes tuples + shapes -> pytree of NamedSharding."""
    is_axes = lambda a: isinstance(a, tuple) and all(
        x is None or isinstance(x, str) for x in a)
    return jax.tree.map(
        lambda ax, sh: NamedSharding(
            mesh, resolve_axes(ax, sh.shape, mesh, fsdp, use_tp,
                               expert_fsdp)),
        axes_tree, shapes_tree, is_leaf=is_axes)


def batch_spec(mesh: Mesh) -> P:
    """Batch dim over all data axes."""
    d = data_axis_names(mesh)
    return P(d if len(d) > 1 else d[0])


def batch_sharding(mesh: Mesh, ndim: int) -> NamedSharding:
    d = data_axis_names(mesh)
    return NamedSharding(mesh, P(*((d if len(d) > 1 else d[0]),)
                                 + (None,) * (ndim - 1)))


def cache_sharding(mesh: Mesh, shape: tuple, n_kv: Optional[int] = None,
                   batch_dim: int = 0, kv_dim: Optional[int] = None,
                   seq_dim: Optional[int] = None) -> NamedSharding:
    """KV-cache policy: batch over data axes; kv-heads over 'model' when
    divisible, else the sequence dim over 'model' (distributed decode)."""
    d = data_axis_names(mesh)
    spec = [None] * len(shape)
    if shape[batch_dim] % _axis_size(mesh, d) == 0 and shape[batch_dim] > 1:
        spec[batch_dim] = d if len(d) > 1 else d[0]
    nm = mesh.shape.get("model", 1)
    if (kv_dim is not None and n_kv and n_kv % nm == 0):
        spec[kv_dim] = "model"
    elif seq_dim is not None and shape[seq_dim] % nm == 0:
        spec[seq_dim] = "model"
    return NamedSharding(mesh, P(*spec))
