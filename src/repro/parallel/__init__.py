"""Distribution: logical-axis sharding rules."""
