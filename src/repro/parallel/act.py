"""Activation sharding constraints via an ambient mesh context.

Model code stays mesh-agnostic: it calls ``shard_batch(x, dim)`` at anchor
points (attention inputs, scan carries, embeddings, logits chunks) and the
launch layer decides what that means by installing a context.  Without a
context every helper is a no-op, so smoke tests and examples run unchanged.

GSPMD generally propagates well through straight-line code but gives up
inside nested while loops with rich carries (flash-attention statistics) —
anchoring the loop inputs/outputs keeps the global batch sharded there.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar = contextvars.ContextVar("act_mesh", default=None)


@contextlib.contextmanager
def activation_mesh(mesh, data_axes: tuple, model_axis: str = "model"):
    tok = _CTX.set({"mesh": mesh, "data": tuple(data_axes),
                    "model": model_axis})
    try:
        yield
    finally:
        _CTX.reset(tok)


def _get():
    return _CTX.get()


def _dp(ctx):
    d = ctx["data"]
    return d if len(d) > 1 else d[0]


def shard_batch(x, dim: int = 0):
    """Constrain dim ``dim`` of x to the data axes (if divisible)."""
    ctx = _get()
    if ctx is None or x.ndim <= dim:
        return x
    import numpy as np
    n = int(np.prod([ctx["mesh"].shape[a] for a in ctx["data"]]))
    if x.shape[dim] % n != 0 or x.shape[dim] < n:
        return x
    # UNCONSTRAINED elsewhere: a hard None would force replication and
    # destroy e.g. the heads sharding GSPMD propagated from the weights.
    spec = [P.UNCONSTRAINED] * x.ndim
    spec[dim] = _dp(ctx)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx["mesh"], P(*spec)))


def shard_spec(x, **dim_axes):
    """Constrain named dims: shard_spec(x, d0='data', d2='model')."""
    ctx = _get()
    if ctx is None:
        return x
    spec = [P.UNCONSTRAINED] * x.ndim
    for key, kind in dim_axes.items():
        dim = int(key[1:])
        if dim >= x.ndim:
            continue
        import numpy as np
        if kind == "data":
            n = int(np.prod([ctx["mesh"].shape[a] for a in ctx["data"]]))
            if x.shape[dim] % n == 0 and x.shape[dim] >= n:
                spec[dim] = _dp(ctx)
        elif kind == "model":
            n = ctx["mesh"].shape[ctx["model"]]
            if x.shape[dim] % n == 0 and x.shape[dim] >= n:
                spec[dim] = ctx["model"]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx["mesh"], P(*spec)))
