"""AdamW with global-norm clipping and schedules — hand-rolled, pytree-native.

Optimizer state (m, v in f32) inherits each parameter's sharding, so ZeRO-
style partitioning falls out of the same FSDP rules the weights use.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_state(params: Any, dtype=jnp.float32) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def apply_updates(params: Any, grads: Any, opt: dict, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_opt, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = opt["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, opt["m"], opt["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
