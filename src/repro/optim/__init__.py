"""Optimizer substrate."""
