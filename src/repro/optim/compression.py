"""Gradient compression for bandwidth-thin links (cross-pod axis).

int8 block-quantized all-reduce with error feedback: each participant
quantizes (gradient + residual) to int8 with a per-block f32 scale, reduces
the int8 payload, and keeps the quantization error as residual for the next
step.  Error feedback makes the compressed SGD/Adam trajectory converge to
the uncompressed one (Karimireddy et al., 2019); ~3.5x fewer bytes on the
pod-to-pod hops, which are the slowest links in a 2-pod mesh.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["quantize_block_int8", "dequantize_block_int8",
           "compressed_psum", "init_residuals", "compress_grads_with_feedback"]

BLOCK = 2048


def quantize_block_int8(x: jnp.ndarray, block: int = BLOCK):
    """x (f32, any shape) -> (int8 payload, f32 per-block scales, pad)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), pad


def dequantize_block_int8(q, scale, pad, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compressed_psum(x: jnp.ndarray, axis_name: str):
    """psum of an int8-quantized payload over ``axis_name`` (inside
    shard_map/pmap).  Returns the dequantized mean contribution sum and the
    local quantization error (for feedback)."""
    q, scale, pad = quantize_block_int8(x)
    local = dequantize_block_int8(q, scale, pad, x.shape)
    err = x - local
    # reduce the dequantized-but-quantization-limited payload; the wire
    # format in a real runtime is (int8, scales) — bytes modeled accordingly.
    total = jax.lax.psum(local, axis_name)
    return total, err


def init_residuals(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_grads_with_feedback(grads: Any, residuals: Any):
    """Quantize (grad + residual) to int8, return (dequantized grads for the
    cross-pod reduce, new residuals).  Pure local transform — composable
    with any reduction the runtime applies afterwards."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, scale, pad = quantize_block_int8(x)
        deq = dequantize_block_int8(q, scale, pad, x.shape)
        return deq, x - deq

    out = jax.tree.map(one, grads, residuals)
    comp = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    return comp, res
