"""Attention: GQA/MQA with chunked online-softmax, local windows, KV caches.

Long sequences never materialize the full (Sq, Skv) score matrix: the
chunked path scans KV blocks with running (max, sum, acc) statistics —
flash-attention dataflow in pure JAX, differentiable through ``lax.scan``.

Decode uses a position-tagged cache: a ``pos`` array rides along with k/v so
global caches and ring-buffer (sliding-window) caches share one masking rule:
``valid = (pos <= current) & (pos > current - window)``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.act import shard_batch

NEG_INF = -1e30


def _pick_chunk(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (ragged seqs, e.g. vlm
    patch prefixes, still chunk evenly)."""
    for c in range(min(target, n), 0, -1):
        if n % c == 0:
            return c
    return n


def _mask(pos_q, pos_k, causal: bool, window: Optional[int]):
    """(..., q, k) boolean validity mask from absolute positions."""
    m = jnp.ones((pos_q.shape[-1], pos_k.shape[-1]), bool)
    if causal:
        m &= pos_q[:, None] >= pos_k[None, :]
    if window is not None:
        m &= pos_q[:, None] - pos_k[None, :] < window
    return m


def _scores(q, k, softcap):
    # q: (B, qc, Hkv, G, hd); k: (B, kc, Hkv, hd) -> (B, Hkv, G, qc, kc)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32))
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    return s


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    softcap: Optional[float] = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    dense_threshold: int = 2048,
) -> jnp.ndarray:
    """Grouped-query attention.

    q: (B, Sq, Hq, hd); k/v: (B, Skv, Hkv, hd); Hq % Hkv == 0.
    ``q_offset``: absolute position of q[0] relative to k[0] (prefill
    continuation); scores are scaled by 1/sqrt(hd).

    Returns (B, Sq, Hq, hd) in q.dtype.
    """
    # Anchor the global batch to the data axes: GSPMD loses the batch
    # sharding through the nested flash-attention while loops otherwise.
    q, k, v = shard_batch(q), shard_batch(k), shard_batch(v)
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    vd = v.shape[-1]                    # MLA: v_dim may differ from q/k dim
    g = hq // hkv
    scale = 1.0 / np.sqrt(hd)
    qg = (q * scale).reshape(b, sq, hkv, g, hd)

    if skv <= dense_threshold:
        s = _scores(qg, k, softcap)
        pos_q = q_offset + jnp.arange(sq)
        pos_k = jnp.arange(skv)
        s = jnp.where(_mask(pos_q, pos_k, causal, window), s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
        return o.reshape(b, sq, hq, vd)

    # --- chunked online-softmax path -------------------------------------
    q_chunk = _pick_chunk(sq, q_chunk)
    kv_chunk = _pick_chunk(skv, kv_chunk)
    nq, nk = sq // q_chunk, skv // kv_chunk
    qr = qg.reshape(b, nq, q_chunk, hkv, g, hd)
    kr = k.reshape(b, nk, kv_chunk, hkv, hd)
    vr = v.reshape(b, nk, kv_chunk, hkv, vd)

    def one_q_chunk(qi, q_blk):
        pos_q = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inp):
            m, l, acc = carry
            ki, k_blk, v_blk = inp
            s = _scores(q_blk, k_blk, softcap)  # (B,Hkv,G,qc,kc)
            pos_k = ki * kv_chunk + jnp.arange(kv_chunk)
            valid = (pos_q[:, None] >= pos_k[None, :]) if causal else \
                jnp.ones((q_chunk, kv_chunk), bool)
            if window is not None:
                valid &= pos_q[:, None] - pos_k[None, :] < window
            s = jnp.where(valid, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, v_blk.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        init = (
            shard_batch(jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32)),
            shard_batch(jnp.zeros((b, hkv, g, q_chunk), jnp.float32)),
            shard_batch(jnp.zeros((b, hkv, g, q_chunk, vd), jnp.float32)),
        )
        ks = jnp.arange(nk)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init, (ks, kr.swapaxes(0, 1), vr.swapaxes(0, 1)))
        o = acc / jnp.maximum(l, 1e-30)[..., None]        # (B,Hkv,G,qc,vd)
        return shard_batch(o.transpose(0, 3, 1, 2, 4))     # (B,qc,Hkv,G,vd)

    # flash-style bwd: recompute each q-chunk's inner pass instead of
    # saving the (qc, kc) probability residuals of every (q, kv) step
    chunk_fn = jax.checkpoint(one_q_chunk)
    outs = jax.lax.map(lambda args: chunk_fn(*args),
                       (jnp.arange(nq), qr.swapaxes(0, 1)))
    o = shard_batch(outs.swapaxes(0, 1).reshape(b, sq, hq, vd))
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# KV caches (position-tagged; supports global and ring/sliding layouts)
# ---------------------------------------------------------------------------
def init_cache(batch, length, n_kv, head_dim, dtype=jnp.bfloat16):
    return {
        "k": jnp.zeros((batch, length, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, length, n_kv, head_dim), dtype),
        "pos": jnp.full((batch, length), -1, jnp.int32),
    }


def cache_prefill(cache, k, v, start: int = 0):
    s = k.shape[1]
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), start, 1)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), start, 1)
    pos = jnp.broadcast_to(start + jnp.arange(s, dtype=jnp.int32)[None, :],
                           (k.shape[0], s))
    cache["pos"] = jax.lax.dynamic_update_slice_in_dim(cache["pos"], pos, start, 1)
    return cache


def cache_append(cache, k_new, v_new, index):
    """Insert one token at absolute position ``index`` (ring if cache is
    shorter than the stream)."""
    length = cache["k"].shape[1]
    slot = index % length
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
    b = cache["pos"].shape[0]
    pos_new = jnp.full((b, 1), index, jnp.int32)
    cache["pos"] = jax.lax.dynamic_update_slice(cache["pos"], pos_new, (0, slot))
    return cache


def decode_attention(q, cache, index, *, window: Optional[int] = None,
                     softcap: Optional[float] = None) -> jnp.ndarray:
    """One-token attention against a position-tagged cache.

    q: (B, 1, Hq, hd); returns (B, 1, Hq, hd).
    """
    b, _, hq, hd = q.shape
    hkv = cache["k"].shape[2]
    g = hq // hkv
    scale = 1.0 / np.sqrt(hd)
    qg = (q * scale).reshape(b, 1, hkv, g, hd)
    s = _scores(qg, cache["k"], softcap)[:, :, :, 0, :]  # (B,Hkv,G,S)
    pos = cache["pos"]                                    # (B,S)
    valid = (pos >= 0) & (pos <= index)
    if window is not None:
        valid &= pos > index - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(cache["v"].dtype), cache["v"])
    return o.reshape(b, 1, hq, hd).astype(q.dtype)
