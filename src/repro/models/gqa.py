"""GQA/MQA attention block: projections + RoPE + (self|cross) attention."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models.common import apply_rope, dense_init, ones_init, rmsnorm


def init_attn(key, cfg, dtype=jnp.float32, cross: bool = False):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, hq, hd), ("embed", "heads", None), 0, dtype),
        "wk": dense_init(ks[1], (d, hkv, hd), ("embed", "kv", None), 0, dtype),
        "wv": dense_init(ks[2], (d, hkv, hd), ("embed", "kv", None), 0, dtype),
        "wo": dense_init(ks[3], (hq, hd, d), ("heads", None, "embed"),
                         (0, 1), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = ones_init((hd,), (None,))
        p["k_norm"] = ones_init((hd,), (None,))
    return p


def _project_qkv(x, p, cfg, positions, rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"].astype(jnp.float32))
        k = rmsnorm(k, p["k_norm"].astype(jnp.float32))
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    return q, k, v


def attn_forward(x, p, cfg, *, window: Optional[int] = None, causal=True,
                 q_offset: int = 0, rope: bool = True, make_cache=False,
                 cache_len: Optional[int] = None):
    """Full-sequence attention (train/prefill).

    Returns (out, cache|None); cache covers positions [0, S).
    """
    b, s, _ = x.shape
    positions = q_offset + jnp.arange(s)[None, :]
    q, k, v = _project_qkv(x, p, cfg, positions, rope)
    o = attn_lib.attention(q, k, v, causal=causal, window=window,
                           q_offset=q_offset, softcap=None)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    cache = None
    if make_cache:
        length = cache_len or s
        cache = attn_lib.init_cache(b, length, cfg.n_kv_heads, cfg.head_dim,
                                    dtype=x.dtype)
        if length >= s:
            cache = attn_lib.cache_prefill(cache, k, v, 0)
        else:  # ring cache shorter than the prefill (sliding window)
            cache = attn_lib.cache_prefill(cache, k[:, -length:],
                                           v[:, -length:], 0)
            cache["pos"] = jnp.broadcast_to(
                jnp.arange(s - length, s, dtype=jnp.int32)[None, :],
                (b, length))
    return out, cache


def attn_decode(x, p, cfg, cache, index, *, window: Optional[int] = None,
                rope: bool = True):
    """One-token decode step. x: (B, 1, d); index: absolute position."""
    positions = jnp.full((x.shape[0], 1), index, jnp.int32)
    q, k, v = _project_qkv(x, p, cfg, positions, rope)
    cache = attn_lib.cache_append(cache, k, v, index)
    o = attn_lib.decode_attention(q, cache, index, window=window)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return out, cache


# --- cross attention (whisper decoder) -------------------------------------
def init_cross_attn(key, cfg, dtype=jnp.float32):
    return init_attn(key, cfg, dtype)


def cross_attn_forward(x, enc_kv, p, cfg):
    """x: (B, S, d); enc_kv: precomputed (k, v) from encoder output."""
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k, v = enc_kv
    o = attn_lib.attention(q, k.astype(x.dtype), v.astype(x.dtype),
                           causal=False)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))


def encode_kv(enc_out, p, cfg):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(enc_out.dtype))
    return k, v
