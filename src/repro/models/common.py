"""Shared model components: params-with-logical-axes, norms, RoPE, MLPs.

Every parameter is created together with a *logical axes* tuple (one entry
per array dim, e.g. ``("embed", "ffn")``).  The launch layer maps logical
names to mesh axes (TP / FSDP / EP) — model code never mentions the mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# Logical axis vocabulary (mapped in repro/parallel/sharding.py):
#   vocab   - vocabulary dim               -> TP
#   embed   - d_model dim of weights       -> FSDP
#   ffn     - MLP hidden dim               -> TP
#   heads   - query heads                  -> TP
#   kv      - kv heads                     -> TP (if divisible)
#   expert  - MoE expert dim               -> TP/EP
#   lru     - recurrent width              -> TP
#   qlora/kvlora - MLA latent dims         -> replicated
#   layers  - scan-stacked layer dim       -> replicated


@dataclasses.dataclass
class ParamsWithAxes:
    params: Any
    axes: Any


# Registered as a pytree with the (static) logical axes as aux data, so
# jax.eval_shape over an init function carries the axes out untouched.
jax.tree_util.register_pytree_node(
    ParamsWithAxes,
    lambda pa: ((pa.params,), pa.axes),
    lambda axes, children: ParamsWithAxes(children[0], axes),
)


def dense_init(key, shape, axes, in_axis=0, dtype=jnp.float32, scale=1.0):
    """He/LeCun-style init; returns (array, axes)."""
    fan_in = np.prod([shape[i] for i in np.atleast_1d(in_axis)])
    std = scale / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, dtype) * std).astype(dtype), axes


def zeros_init(shape, axes, dtype=jnp.float32):
    return jnp.zeros(shape, dtype), axes


def ones_init(shape, axes, dtype=jnp.float32):
    return jnp.ones(shape, dtype), axes


def split_tree(pairs: dict) -> ParamsWithAxes:
    """{'name': (param, axes) | nested dict} -> ParamsWithAxes."""
    params, axes = {}, {}
    for k, v in pairs.items():
        if isinstance(v, dict):
            sub = split_tree(v)
            params[k], axes[k] = sub.params, sub.axes
        elif isinstance(v, ParamsWithAxes):
            params[k], axes[k] = v.params, v.axes
        else:
            params[k], axes[k] = v
    return ParamsWithAxes(params, axes)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm(x, w, eps=1e-6, plus_one=False):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w) if plus_one else w
    return (x * scale).astype(dt)


def layernorm(x, w, b, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * w + b
    return y.astype(dt)


def norm_init(d, kind="rmsnorm"):
    if kind == "rmsnorm":
        return {"w": ones_init((d,), (None,))}
    return {"w": ones_init((d,), (None,)), "b": zeros_init((d,), (None,))}


def apply_norm(x, p, kind="rmsnorm", plus_one=False):
    if kind == "rmsnorm":
        return rmsnorm(x, p["w"], plus_one=plus_one)
    return layernorm(x, p["w"], p["b"])


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_angles(positions, dim, theta=10_000.0):
    """positions (...,) -> (..., dim/2) angles."""
    freqs = 1.0 / (theta ** (np.arange(0, dim, 2, dtype=np.float32) / dim))
    return positions[..., None].astype(jnp.float32) * freqs


def apply_rope(x, positions, theta=10_000.0, fraction=1.0):
    """x: (B, S, H, hd); positions: (B, S).  Rotates the first
    ``fraction * hd`` dims (partial rotary, stablelm-style)."""
    hd = x.shape[-1]
    rot = int(hd * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    ang = rope_angles(positions, rot, theta)           # (B, S, rot/2)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr, xp], axis=-1) if rot < hd else yr


# ---------------------------------------------------------------------------
# MLPs (gated silu/gelu and plain)
# ---------------------------------------------------------------------------
def mlp_init(key, d_model, d_ff, act="silu", dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    gated = act in ("silu", "geglu")
    p = {
        "w_up": dense_init(k2, (d_model, d_ff), ("embed", "ffn"), 0, dtype),
        "w_down": dense_init(k3, (d_ff, d_model), ("ffn", "embed"), 0, dtype),
    }
    if gated:
        p["w_gate"] = dense_init(k1, (d_model, d_ff), ("embed", "ffn"), 0, dtype)
    return p


def mlp_apply(x, p, act="silu"):
    up = x @ p["w_up"]
    if act == "silu":
        h = jax.nn.silu(x @ p["w_gate"]) * up
    elif act == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"]) * up
    else:  # plain gelu MLP (whisper)
        h = jax.nn.gelu(up)
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Token embedding / logits
# ---------------------------------------------------------------------------
def embed_init(key, vocab, d_model, dtype=jnp.float32):
    return dense_init(key, (vocab, d_model), ("vocab", "embed"), 1, dtype)


def embed_lookup(tokens, table, scale_by_sqrt_dim=False):
    x = jnp.take(table, tokens, axis=0)
    if scale_by_sqrt_dim:
        x = x * np.sqrt(table.shape[-1]).astype(x.dtype)
    return x


def logits_from_embedding(x, table, softcap=None):
    out = x @ table.T
    if softcap is not None:
        out = jnp.tanh(out / softcap) * softcap
    return out


def cross_entropy(logits, labels, mask=None, z_loss=0.0):
    """Token-mean cross entropy in f32, optional z-loss regularizer."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * lse ** 2
    if mask is None:
        return loss.mean()
    mask = mask.astype(jnp.float32)
    return (loss * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def cross_entropy_streamed(x, table, labels, mask=None, softcap=None,
                           chunk: int = 512):
    """CE against a tied embedding without materializing (B, S, V) logits.

    Scans the sequence in chunks; each chunk's logits are vocab-sharded and
    reduced to (B, chunk) statistics before the next chunk streams in.  At
    256k-vocab / 4k-seq / 256-batch the dense logits tensor is ~1 TB — this
    keeps the live footprint to one chunk.
    """
    from repro.parallel.act import shard_spec

    b, s, d = x.shape
    chunk = min(chunk, s)
    n = s // chunk
    rem = s - n * chunk

    def chunk_loss(xs, ls, ms):
        logits = xs @ table.T.astype(xs.dtype)
        if softcap is not None:
            logits = jnp.tanh(logits / softcap) * softcap
        logits = shard_spec(logits, d0="data", d2="model")
        logits = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        loss = (lse - ll) * ms
        return loss.sum(), ms.sum()

    # recompute chunk logits in the backward pass (vocab-dim flash)
    chunk_loss_ckpt = jax.checkpoint(chunk_loss)

    def body(carry, i):
        tot, cnt = carry
        xs = jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, 1)
        ls = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, 1)
        ms = (jax.lax.dynamic_slice_in_dim(mask, i * chunk, chunk, 1)
              .astype(jnp.float32) if mask is not None
              else jnp.ones((b, chunk), jnp.float32))
        dl, dc = chunk_loss_ckpt(xs, ls, ms)
        return (tot + dl, cnt + dc), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        jnp.arange(n))
    if rem:
        ms = (mask[:, n * chunk:].astype(jnp.float32) if mask is not None
              else jnp.ones((b, rem), jnp.float32))
        dl, dc = chunk_loss(x[:, n * chunk:], labels[:, n * chunk:], ms)
        tot, cnt = tot + dl, cnt + dc
    return tot / jnp.maximum(cnt, 1.0)
