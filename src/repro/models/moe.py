"""Mixture-of-Experts with capacity-based top-k routing + expert parallelism.

Routing follows the standard capacity discipline (tokens beyond an expert's
capacity are dropped); dispatch is *sort-based* — assignments are sorted by
expert id, positions within an expert come from a searchsorted trick, and
activations are gathered only for assignments that actually landed, so no
(tokens x experts) one-hot tensor ever exists.

Distribution: the block runs under ``shard_map`` with tokens sharded over
the data axes (replicated over 'model') and experts sharded over 'model'
(EP).  Each model shard dispatches to its local experts and the shards'
partial outputs are combined with one psum — the same collective a tensor-
parallel dense FFN needs, so EP comes at no extra communication cost.
FSDP-sharded expert weights are all-gathered per layer inside the block.

On a trivial mesh (or ``mesh=None``) the same math runs locally, which is
what the CPU smoke tests exercise.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.common import dense_init


def init_moe(key, cfg, dtype=jnp.float32):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, m.n_experts), ("embed", "expert"),
                             0, jnp.float32),
        "w_gate": dense_init(ks[1], (m.n_experts, d, m.d_expert),
                             ("expert", "embed", None), 1, dtype),
        "w_up": dense_init(ks[2], (m.n_experts, d, m.d_expert),
                           ("expert", "embed", None), 1, dtype),
        "w_down": dense_init(ks[3], (m.n_experts, m.d_expert, d),
                             ("expert", None, "embed"), 1, dtype),
    }
    if m.n_shared:
        dsh = m.d_expert * m.n_shared
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(kk[0], (d, dsh), ("embed", "ffn"), 0, dtype),
            "w_up": dense_init(kk[1], (d, dsh), ("embed", "ffn"), 0, dtype),
            "w_down": dense_init(kk[2], (dsh, d), ("ffn", "embed"), 0, dtype),
        }
    return p


def _route(x, router_w, m):
    """Top-k routing: returns (expert_idx, gate) each (T, k) + aux losses."""
    logits = (x.astype(jnp.float32) @ router_w)            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, m.top_k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # load-balance aux (Switch-style) and router z-loss
    me = probs.mean(0)                                      # (E,)
    ce = jnp.zeros_like(me).at[idx.reshape(-1)].add(
        jnp.ones_like(gate).reshape(-1)) / (idx.size)
    aux = m.n_experts * jnp.sum(me * ce) * m.aux_loss
    z = jnp.mean(jax.scipy.special.logsumexp(logits, -1) ** 2) * m.router_z_loss
    return idx, gate, aux + z


def _expert_ffn(buf, w_gate, w_up, w_down):
    """buf: (E_l, C, d) -> (E_l, C, d) through each expert's gated MLP."""
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(buf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(buf.dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, w_down.astype(buf.dtype))


def _dispatch_compute(x, idx, gate, w_gate, w_up, w_down, e_lo, n_local,
                      capacity):
    """Sort-based dispatch for experts [e_lo, e_lo + n_local).

    x: (T, d); idx/gate: (T, k).  Returns (T, d) partial output containing
    only the local experts' contributions.
    """
    t, d = x.shape
    k = idx.shape[1]
    e_flat = idx.reshape(-1)
    g_flat = gate.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(t), k)

    local = (e_flat >= e_lo) & (e_flat < e_lo + n_local)
    e_loc = jnp.where(local, e_flat - e_lo, n_local)       # non-local -> sentinel
    order = jnp.argsort(e_loc)                              # locals first, by expert
    n_slots = n_local * capacity
    n_gather = min(n_slots, t * k)                          # static
    order = order[:n_gather]
    e_sorted = e_loc[order]
    pos = jnp.arange(n_gather) - jnp.searchsorted(e_sorted, e_sorted,
                                                  side="left")
    keep = (e_sorted < n_local) & (pos < capacity)
    slot = jnp.where(keep, e_sorted * capacity + pos, n_slots)  # OOB drops

    gathered = jnp.take(x, tok_flat[order], axis=0)         # (n_gather, d)
    buf = jnp.zeros((n_slots + 1, d), x.dtype).at[slot].set(gathered)
    buf = buf[:n_slots].reshape(n_local, capacity, d)

    out_buf = _expert_ffn(buf, w_gate, w_up, w_down)        # (E_l, C, d)
    out_flat = out_buf.reshape(n_slots, d)
    contrib = jnp.take(out_flat, jnp.minimum(slot, n_slots - 1), axis=0)
    contrib = contrib * (keep * g_flat[order]).astype(x.dtype)[:, None]
    return jnp.zeros((t, d), x.dtype).at[tok_flat[order]].add(contrib)


def moe_forward(x, p, cfg, mesh=None, data_axes=("data",), model_axis="model",
                fsdp_gather: bool = True):
    """x: (B, S, d) -> (y, aux_loss).

    When ``mesh`` spans real data/model axes the block runs under shard_map
    (EP); otherwise it executes the same math locally.
    """
    m = cfg.moe
    b, s, d = x.shape
    xt = x.reshape(-1, d)

    use_shmap = mesh is not None and (
        int(np.prod([mesh.shape[a] for a in data_axes])) > 1
        or mesh.shape[model_axis] > 1)

    if not use_shmap:
        idx, gate, aux = _route(xt, p["router"], m)
        cap = int(np.ceil(xt.shape[0] * m.top_k * m.capacity_factor
                          / m.n_experts))
        y = _dispatch_compute(xt, idx, gate, p["w_gate"], p["w_up"],
                              p["w_down"], 0, m.n_experts, max(cap, 1))
    else:
        n_model = mesh.shape[model_axis]
        n_data = int(np.prod([mesh.shape[a] for a in data_axes]))
        assert m.n_experts % n_model == 0, (m.n_experts, n_model)
        n_local = m.n_experts // n_model
        t_local = xt.shape[0] // n_data
        cap = int(np.ceil(t_local * m.top_k * m.capacity_factor
                          / m.n_experts))
        cap = max(cap, 4)

        def local_fn(x_l, router_w, w_gate, w_up, w_down):
            # x_l: (T_l, d) — sharded over data, replicated over model.
            if fsdp_gather:
                # FSDP: expert weights arrive sharded on d_model; gather.
                w_gate_f = jax.lax.all_gather(w_gate, data_axes, axis=1,
                                              tiled=True)
                w_up_f = jax.lax.all_gather(w_up, data_axes, axis=1,
                                            tiled=True)
                w_down_f = jax.lax.all_gather(w_down, data_axes, axis=2,
                                              tiled=True)
            else:
                w_gate_f, w_up_f, w_down_f = w_gate, w_up, w_down
            idx, gate, aux_l = _route(x_l, router_w, m)
            e_lo = jax.lax.axis_index(model_axis) * n_local
            y_l = _dispatch_compute(x_l, idx, gate, w_gate_f, w_up_f,
                                    w_down_f, e_lo, n_local, cap)
            y_l = jax.lax.psum(y_l, model_axis)
            aux_l = jax.lax.pmean(aux_l, data_axes)
            return y_l, aux_l

        dp = P(data_axes if len(data_axes) > 1 else data_axes[0])
        y, aux = jax.shard_map(
            local_fn, mesh=mesh,
            in_specs=(P(dp[0], None), P(None, None),
                      P(model_axis, dp[0] if fsdp_gather else None, None),
                      P(model_axis, dp[0] if fsdp_gather else None, None),
                      P(model_axis, None, dp[0] if fsdp_gather else None)),
            out_specs=(P(dp[0], None), P()),
            check_vma=False,
        )(xt, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    y = y.reshape(b, s, d)
    if m.n_shared:
        sh = p["shared"]
        g = jax.nn.silu(x @ sh["w_gate"].astype(x.dtype))
        u = x @ sh["w_up"].astype(x.dtype)
        y = y + (g * u) @ sh["w_down"].astype(x.dtype)
    return y, aux
