"""RG-LRU recurrent block (RecurrentGemma / Griffin).

    r_t = sigmoid(x_t W_a + b_a)            recurrence gate
    i_t = sigmoid(x_t W_x + b_x)            input gate
    log a_t = -c * softplus(Lambda) * r_t   (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * x_t)

Training/prefill uses ``jax.lax.associative_scan`` over the sequence (the
recurrence is linear in h, so it parallelizes in O(log S) depth) — this is
what makes the 524288-token cell tractable.  Decode keeps O(1) state:
(conv buffer, h).

Block layout (Griffin): y = W_out[ GeLU(x W_gate) * RGLRU(conv4(x W_in)) ].
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init, zeros_init

_C = 8.0


def init_rglru(key, cfg, dtype=jnp.float32):
    d, r = cfg.d_model, cfg.lru_dim
    w = cfg.conv_width
    ks = jax.random.split(key, 6)
    # Lambda init so softplus(Lambda) spreads decay rates (Griffin: a in
    # [0.9, 0.999] at r=1): sample uniform then invert.
    u = jax.random.uniform(ks[0], (r,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log(a)/c)
    return {
        "w_in": dense_init(ks[1], (d, r), ("embed", "lru"), 0, dtype),
        "w_gate": dense_init(ks[2], (d, r), ("embed", "lru"), 0, dtype),
        "w_out": dense_init(ks[3], (r, d), ("lru", "embed"), 0, dtype),
        "conv_w": dense_init(ks[4], (w, r), (None, "lru"), 0, dtype, scale=0.5),
        "conv_b": zeros_init((r,), ("lru",), dtype),
        "w_a": dense_init(ks[5], (r, r), ("lru", None), 0, dtype),
        "b_a": zeros_init((r,), (None,), dtype),
        "w_x": dense_init(jax.random.fold_in(key, 7), (r, r), ("lru", None),
                          0, dtype),
        "b_x": zeros_init((r,), (None,), dtype),
        "lam": (lam.astype(jnp.float32), ("lru",)),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv, width W.  x: (B, S, r); state: (B, W-1, r)."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
              for i in range(width))
    new_state = xp[:, -(width - 1):, :] if width > 1 else pad
    return out + b.astype(x.dtype), new_state


def _gates(xc, p):
    r = jax.nn.sigmoid(xc @ p["w_a"].astype(xc.dtype) + p["b_a"].astype(xc.dtype))
    i = jax.nn.sigmoid(xc @ p["w_x"].astype(xc.dtype) + p["b_x"].astype(xc.dtype))
    log_a = (-_C * jax.nn.softplus(p["lam"]) * r.astype(jnp.float32))
    a = jnp.exp(log_a)
    gated_in = (i.astype(jnp.float32) * xc.astype(jnp.float32)
                * jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)))
    return a, gated_in


def rglru_scan(xc, p, h0=None):
    """Linear recurrence over the whole sequence via associative scan.

    xc: (B, S, r) conv output; returns (h (B, S, r) f32, h_last).
    """
    a, b = _gates(xc, p)                 # both (B, S, r) f32
    if h0 is not None:
        # fold the carried state in as a virtual step contribution
        b = b.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1, :]


def rglru_block_forward(x, p, cfg, state=None):
    """Full-sequence Griffin recurrent block.

    state: None or dict(conv (B, W-1, r), h (B, r)).
    Returns (y (B, S, d), new_state).
    """
    gate = jax.nn.gelu(x @ p["w_gate"].astype(x.dtype))
    xin = x @ p["w_in"].astype(x.dtype)
    conv_state = state["conv"] if state else None
    xc, conv_new = _causal_conv(xin, p["conv_w"], p["conv_b"], conv_state)
    h0 = state["h"] if state else None
    h, h_last = rglru_scan(xc, p, h0)
    y = (gate * h.astype(x.dtype)) @ p["w_out"].astype(x.dtype)
    new_state = {"conv": conv_new, "h": h_last}
    return y, new_state


def rglru_block_decode(x, p, cfg, state):
    """One-token step. x: (B, 1, d); state from forward/init_state."""
    gate = jax.nn.gelu(x @ p["w_gate"].astype(x.dtype))
    xin = x @ p["w_in"].astype(x.dtype)
    xc, conv_new = _causal_conv(xin, p["conv_w"], p["conv_b"], state["conv"])
    a, b = _gates(xc, p)                                   # (B, 1, r)
    h = a[:, 0] * state["h"].astype(jnp.float32) + b[:, 0]
    y = (gate * h[:, None, :].astype(x.dtype)) @ p["w_out"].astype(x.dtype)
    return y, {"conv": conv_new, "h": h}


def init_state(batch, cfg, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_dim), dtype),
        "h": jnp.zeros((batch, cfg.lru_dim), jnp.float32),
    }
