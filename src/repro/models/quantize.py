"""Frozen-weight int8 specialization for serving — the paper's technique
applied to LM inference.

The paper's core premise: when a matrix is fixed for the lifetime of the
computation, specialize its representation offline.  At LM serving time all
weights are frozen, and decode is memory-roofline-bound (every weight is
re-read per token), so halving the weight stream halves the dominant
roofline term.  We quantize every large float leaf to symmetric int8 with a
per-output-channel f32 scale (the paper's 8-bit signed weights) and
dequantize *per layer inside the scan body* — the int8 bytes are what HBM
streams; the bf16 copy lives only in VMEM-scale working set.

Dense LM weights have ~zero element sparsity, so the paper's element/block
culling lever does not apply here (DESIGN.md §Arch-applicability); the
digit-plane path stays available for genuinely sparse frozen matrices via
``repro.kernels.bitplane_gemv``.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

MIN_QUANT_SIZE = 1 << 16  # don't quantize norms/biases/small tables

__all__ = ["quantize_tree", "dequant_tree", "is_quantized_leaf",
           "quant_struct_like"]


def _should_quantize(x) -> bool:
    dt = getattr(x, "dtype", None)
    shape = getattr(x, "shape", ())
    if dt is None or not jnp.issubdtype(dt, jnp.floating):
        return False
    if int(np.prod(shape)) < MIN_QUANT_SIZE:
        return False
    # >=3D: a true matrix (possibly layer-stacked).  2D: require both dims
    # large — excludes layer-stacked norm/bias vectors like (layers, d).
    return len(shape) >= 3 or (len(shape) == 2 and min(shape) >= 1024)


def is_quantized_leaf(x) -> bool:
    return isinstance(x, dict) and set(x) == {"q", "scale"}


def quantize_tree(params: Any) -> Any:
    """Replace big float leaves with {"q": int8, "scale": f32[last_dim]}."""

    def one(x):
        if not _should_quantize(x):
            return x
        w = jnp.asarray(x, jnp.float32)
        # scale over (leading stack dim if any, out channels): layer-stacked
        # weights keep their layer dim so lax.scan can slice per layer.
        red = tuple(range(1, w.ndim - 1)) if w.ndim >= 3 else (0,)
        amax = jnp.max(jnp.abs(w), axis=red, keepdims=True)
        scale = jnp.maximum(amax / 127.0, 1e-12)
        q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
        return {"q": q, "scale": jnp.squeeze(scale, red).astype(jnp.float32)}

    return jax.tree.map(one, params)


def dequant_tree(params: Any, dtype=jnp.bfloat16) -> Any:
    """Inverse of quantize_tree (no-op on unquantized leaves)."""

    def one(x):
        if is_quantized_leaf(x):
            q, scale = x["q"], x["scale"]
            if scale.ndim == 2:    # (layers, out) — outside the layer scan
                shape = (scale.shape[0],) + (1,) * (q.ndim - 2) + (scale.shape[1],)
            else:                  # (out,) — plain or scan-sliced weight
                shape = (1,) * (q.ndim - 1) + (scale.shape[0],)
            return q.astype(dtype) * scale.reshape(shape).astype(dtype)
        return x

    return jax.tree.map(one, params, is_leaf=is_quantized_leaf)


def quant_struct_like(struct: Any) -> Any:
    """ShapeDtypeStruct tree -> the quantized-serving struct tree.

    ``q`` inherits the original sharding; ``scale`` (out-channel vector)
    takes the last axis' spec.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    def one(sds):
        if not _should_quantize(sds):
            return sds
        sh = getattr(sds, "sharding", None)
        q_sh = sh
        s_sh = None
        sc_shape = ((sds.shape[0], sds.shape[-1]) if len(sds.shape) >= 3
                    else (sds.shape[-1],))
        if sh is not None and hasattr(sh, "spec"):
            spec = list(sh.spec) + [None] * (len(sds.shape) - len(sh.spec))
            s_spec = ((spec[0], spec[-1]) if len(sds.shape) >= 3
                      else (spec[-1],))
            s_sh = NamedSharding(sh.mesh, P(*s_spec))
        return {
            "q": jax.ShapeDtypeStruct(sds.shape, jnp.int8, sharding=q_sh),
            "scale": jax.ShapeDtypeStruct(sc_shape, jnp.float32,
                                          sharding=s_sh),
        }

    return jax.tree.map(one, struct)
