"""Model assembly: block dispatch, scan-over-groups stacking, LM API.

A config's ``block_pattern`` (e.g. ``("rglru", "rglru", "local")``) defines
one *group*; the depth is ``n_groups`` repetitions (plus an optional tail).
Groups are homogeneous, so the layer stack is a single ``lax.scan`` over
stacked parameters — one compiled group body regardless of depth, which is
what keeps 512-device dry-run compiles tractable.

Block types:
  attn   - global causal attention + MLP (or MoE)
  local  - sliding-window attention + MLP
  mla    - DeepSeek-V2 latent attention + MoE
  rglru  - Griffin recurrent block + MLP
  mlstm  - xLSTM matrix-memory block (no separate MLP when d_ff == 0)
  slstm  - xLSTM scalar-memory block
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import gqa, mla as mla_lib, moe as moe_lib
from repro.models import rglru as rglru_lib, xlstm as xlstm_lib
from repro.models import attention as attn_lib
from repro.models.common import (ParamsWithAxes, apply_norm, cross_entropy,
                                 cross_entropy_streamed, dense_init,
                                 embed_init, embed_lookup,
                                 logits_from_embedding, mlp_init, mlp_apply,
                                 norm_init, split_tree)
from repro.models.quantize import dequant_tree
from repro.parallel.act import shard_batch


@dataclasses.dataclass
class ParallelCtx:
    mesh: Any = None
    data_axes: tuple = ("data",)
    model_axis: str = "model"
    fsdp: bool = True


# ---------------------------------------------------------------------------
# Single block init / forward / decode
# ---------------------------------------------------------------------------
def _init_block(key, cfg: ModelConfig, kind: str, dtype):
    ks = jax.random.split(key, 3)
    p: dict = {"norm1": norm_init(cfg.d_model, cfg.norm)}
    if kind in ("attn", "local"):
        p["attn"] = gqa.init_attn(ks[0], cfg, dtype)
    elif kind == "mla":
        p["attn"] = mla_lib.init_mla(ks[0], cfg, dtype)
    elif kind == "rglru":
        p["mixer"] = rglru_lib.init_rglru(ks[0], cfg, dtype)
    elif kind == "mlstm":
        p["mixer"] = xlstm_lib.init_mlstm(ks[0], cfg, dtype)
    elif kind == "slstm":
        p["mixer"] = xlstm_lib.init_slstm(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    has_ffn = cfg.d_ff > 0 or cfg.moe is not None
    if has_ffn and kind not in ("mlstm", "slstm"):
        p["norm2"] = norm_init(cfg.d_model, cfg.norm)
        if cfg.moe is not None:
            p["moe"] = moe_lib.init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_act,
                                dtype)
    return p


def _slstm_sharded(h, mixer, cfg: ModelConfig, ctx: ParallelCtx):
    """sLSTM under shard_map (batch over the data axes).

    GSPMD places the recurrent-weight gradient psum *inside* the 4096-step
    time loop otherwise (one (H, hd, hd) all-reduce per step per direction —
    measured 8.3e11 B/device/step on xlstm train_4k).  Under shard_map the
    step math is local and the transpose of the replicated weights inserts
    exactly one psum per block call.  §Perf iteration A3.
    """
    if ctx.mesh is None:
        return xlstm_lib.slstm_forward(h, mixer, cfg)
    from jax.sharding import PartitionSpec as P
    dp = ctx.data_axes if len(ctx.data_axes) > 1 else ctx.data_axes[0]

    def local_fn(h_l, mixer_l):
        out, cache = xlstm_lib.slstm_forward(h_l, mixer_l, cfg)
        return out, cache

    rep = jax.tree.map(lambda _: P(), mixer)
    cache_specs = {"c": P(dp), "n": P(dp), "h": P(dp), "m": P(dp)}
    out, cache = jax.shard_map(
        local_fn, mesh=ctx.mesh,
        in_specs=(P(dp, None, None), rep),
        out_specs=(P(dp, None, None), cache_specs),
        check_vma=False,
    )(h, mixer)
    return out, cache


def _block_forward(x, p, cfg: ModelConfig, kind: str, ctx: ParallelCtx,
                   *, make_cache=False, cache_len=None, q_offset=0):
    """Full-sequence block. Returns (x, cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(x, p["norm1"], cfg.norm)
    cache = None
    if kind in ("attn", "local"):
        window = cfg.window if kind == "local" else None
        clen = cache_len
        if kind == "local" and cache_len is not None and cfg.window:
            clen = min(cache_len, cfg.window)
        out, cache = gqa.attn_forward(h, p["attn"], cfg, window=window,
                                      q_offset=q_offset,
                                      make_cache=make_cache, cache_len=clen)
    elif kind == "mla":
        out, cache = mla_lib.mla_forward(h, p["attn"], cfg, q_offset=q_offset,
                                         make_cache=make_cache,
                                         cache_len=cache_len)
    elif kind == "rglru":
        out, cache = rglru_lib.rglru_block_forward(h, p["mixer"], cfg)
        if not make_cache:
            cache = None
    elif kind == "mlstm":
        out, cache = xlstm_lib.mlstm_chunk_forward(h, p["mixer"], cfg)
        if not make_cache:
            cache = None
    elif kind == "slstm":
        out, cache = _slstm_sharded(h, p["mixer"], cfg, ctx)
        if not make_cache:
            cache = None
    else:
        raise ValueError(kind)
    x = x + out
    if "norm2" in p:
        h = apply_norm(x, p["norm2"], cfg.norm)
        if "moe" in p:
            out, aux = moe_lib.moe_forward(h, p["moe"], cfg, ctx.mesh,
                                           ctx.data_axes, ctx.model_axis,
                                           fsdp_gather=ctx.fsdp)
        else:
            out = mlp_apply(h, p["mlp"], cfg.mlp_act)
        x = x + out
    return x, cache, aux


def _block_decode(x, p, cfg: ModelConfig, kind: str, ctx: ParallelCtx,
                  cache, index):
    """One-token block step. Returns (x, cache)."""
    h = apply_norm(x, p["norm1"], cfg.norm)
    if kind in ("attn", "local"):
        window = cfg.window if kind == "local" else None
        out, cache = gqa.attn_decode(h, p["attn"], cfg, cache, index,
                                     window=window)
    elif kind == "mla":
        out, cache = mla_lib.mla_decode(h, p["attn"], cfg, cache, index)
    elif kind == "rglru":
        out, cache = rglru_lib.rglru_block_decode(h, p["mixer"], cfg, cache)
    elif kind == "mlstm":
        out, cache = xlstm_lib.mlstm_decode(h, p["mixer"], cfg, cache)
    elif kind == "slstm":
        out, cache = xlstm_lib.slstm_decode(h, p["mixer"], cfg, cache)
    else:
        raise ValueError(kind)
    x = x + out
    if "norm2" in p:
        h = apply_norm(x, p["norm2"], cfg.norm)
        if "moe" in p:
            out, _ = moe_lib.moe_forward(h, p["moe"], cfg, ctx.mesh,
                                         ctx.data_axes, ctx.model_axis,
                                         fsdp_gather=ctx.fsdp)
        else:
            out = mlp_apply(h, p["mlp"], cfg.mlp_act)
        x = x + out
    return x, cache


def _init_cache_for(cfg: ModelConfig, kind: str, batch, cache_len, dtype):
    if kind == "attn":
        return attn_lib.init_cache(batch, cache_len, cfg.n_kv_heads,
                                   cfg.head_dim, dtype)
    if kind == "local":
        length = min(cache_len, cfg.window) if cfg.window else cache_len
        return attn_lib.init_cache(batch, length, cfg.n_kv_heads,
                                   cfg.head_dim, dtype)
    if kind == "mla":
        m = cfg.mla
        return {
            "c_kv": jnp.zeros((batch, cache_len, m.kv_lora), dtype),
            "k_rope": jnp.zeros((batch, cache_len, m.rope_dim), dtype),
            "pos": jnp.full((batch, cache_len), -1, jnp.int32),
        }
    if kind == "rglru":
        return rglru_lib.init_state(batch, cfg, dtype)
    if kind == "mlstm":
        return xlstm_lib.init_mlstm_state(batch, cfg)
    if kind == "slstm":
        return xlstm_lib.init_slstm_state(batch, cfg)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Group stacking (lax.scan over groups)
# ---------------------------------------------------------------------------
def _init_group(key, cfg: ModelConfig, dtype, with_cross: bool = False):
    ks = jax.random.split(key, len(cfg.block_pattern) + 2)
    g = {f"b{i}": _init_block(ks[i], cfg, kind, dtype)
         for i, kind in enumerate(cfg.block_pattern)}
    if with_cross:  # enc-dec: one cross-attention per group
        g["xnorm"] = norm_init(cfg.d_model, cfg.norm)
        g["xattn"] = gqa.init_cross_attn(ks[-1], cfg, dtype)
    return g


def _group_forward(x, gp, cfg, ctx, *, make_cache, cache_len, q_offset):
    caches, aux = {}, jnp.zeros((), jnp.float32)
    for i, kind in enumerate(cfg.block_pattern):
        x, cache, a = _block_forward(x, gp[f"b{i}"], cfg, kind, ctx,
                                     make_cache=make_cache,
                                     cache_len=cache_len, q_offset=q_offset)
        if make_cache:
            caches[f"b{i}"] = cache
        aux = aux + a
    return x, caches, aux


def _group_decode(x, gp, cfg, ctx, caches, index):
    new = {}
    for i, kind in enumerate(cfg.block_pattern):
        x, new[f"b{i}"] = _block_decode(x, gp[f"b{i}"], cfg, kind, ctx,
                                        caches[f"b{i}"], index)
    return x, new


def _stack_params(trees):
    """List of identical pytrees -> single pytree with leading layer dim."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# LM: the end-to-end decoder-only model (plus enc-dec variant)
# ---------------------------------------------------------------------------
class LM:
    """Functional language model for one ModelConfig."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    # -- init ---------------------------------------------------------------
    def init(self, key) -> ParamsWithAxes:
        cfg = self.cfg
        keys = jax.random.split(key, cfg.n_groups + 8)
        cross = cfg.encoder is not None
        groups = [_init_group(keys[i], cfg, self.dtype, with_cross=cross)
                  for i in range(cfg.n_groups)]
        tree = {
            "embed": embed_init(keys[-1], cfg.vocab_size, cfg.d_model,
                                self.dtype),
            "final_norm": norm_init(cfg.d_model, cfg.norm),
        }
        pax = [split_tree(g) for g in groups]
        stacked = ParamsWithAxes(
            _stack_params([p.params for p in pax]),
            jax.tree.map(lambda a: ("layers",) + a, pax[0].axes,
                         is_leaf=lambda a: isinstance(a, tuple)))
        tree["groups"] = stacked
        if cfg.tail_pattern:
            tail_cfg = cfg.replace(block_pattern=cfg.tail_pattern)
            tree["tail"] = split_tree(_init_group(keys[-2], tail_cfg,
                                                  self.dtype))
        if not cfg.tie_embeddings:
            tree["lm_head"] = dense_init(keys[-3],
                                         (cfg.d_model, cfg.vocab_size),
                                         ("embed", "vocab"), 0, self.dtype)
        if cfg.encoder is not None:
            tree["encoder"] = self._init_encoder(keys[-4])
        return split_tree(tree)

    def _init_encoder(self, key):
        cfg = self.cfg
        enc = cfg.replace(block_pattern=("attn",) * cfg.encoder.n_layers)
        ks = jax.random.split(key, cfg.encoder.n_layers + 2)
        blocks = [_init_block(ks[i], cfg, "attn", self.dtype)
                  for i in range(cfg.encoder.n_layers)]
        pax = [split_tree(b) for b in blocks]
        return {
            "blocks": ParamsWithAxes(
                _stack_params([p.params for p in pax]),
                jax.tree.map(lambda a: ("layers",) + a, pax[0].axes,
                             is_leaf=lambda a: isinstance(a, tuple))),
            "pos_embed": dense_init(ks[-1], (cfg.encoder.seq_len, cfg.d_model),
                                    (None, "embed"), 0, self.dtype),
            "final_norm": norm_init(cfg.d_model, cfg.norm),
        }

    # -- shared forward over the stack ---------------------------------------
    def _backbone(self, params, x, ctx, *, make_cache=False, cache_len=None,
                  q_offset=0):
        cfg = self.cfg

        def group_fn(x, gp):
            x = shard_batch(x)  # anchor the layer-scan carry
            gp = dequant_tree(gp, self.dtype)  # int8 serving: HBM streams
            return _group_forward(x, gp, cfg, ctx, make_cache=make_cache,
                                  cache_len=cache_len, q_offset=q_offset)

        body = _remat(group_fn, cfg.remat)

        if cfg.scan_layers:
            def scan_body(carry, gp):
                x, aux = carry
                x, caches, a = body(x, gp)
                return (x, aux + a), caches
            (x, aux), caches = jax.lax.scan(
                scan_body, (x, jnp.zeros((), jnp.float32)), params["groups"])
        else:
            caches_list, aux = [], jnp.zeros((), jnp.float32)
            for i in range(cfg.n_groups):
                gp = jax.tree.map(lambda a: a[i], params["groups"])
                x, c, a = body(x, gp)
                caches_list.append(c)
                aux = aux + a
            caches = (jax.tree.map(lambda *xs: jnp.stack(xs), *caches_list)
                      if make_cache else None)

        tail_caches = None
        if cfg.tail_pattern:
            tail_cfg = cfg.replace(block_pattern=cfg.tail_pattern)
            x, tail_caches, a = _group_forward(
                x, params["tail"], tail_cfg, ctx, make_cache=make_cache,
                cache_len=cache_len, q_offset=q_offset)
            aux = aux + a
        return x, (caches, tail_caches), aux

    def _encode(self, params, frames, ctx):
        """Encoder stack over stub frame/patch embeddings (B, T, d)."""
        cfg = self.cfg
        x = frames.astype(self.dtype) + params["encoder"]["pos_embed"][
            : frames.shape[1]].astype(self.dtype)

        def block_fn(x, bp):
            h = apply_norm(x, bp["norm1"], cfg.norm)
            out, _ = gqa.attn_forward(h, bp["attn"], cfg, causal=False,
                                      rope=False)
            x = x + out
            h = apply_norm(x, bp["norm2"], cfg.norm)
            return x + mlp_apply(h, bp["mlp"], cfg.mlp_act), None

        x, _ = jax.lax.scan(lambda c, bp: block_fn(c, bp), x,
                            params["encoder"]["blocks"])
        return apply_norm(x, params["encoder"]["final_norm"], cfg.norm)

    # -- embeddings / logits --------------------------------------------------
    def _embed(self, params, tokens, extra_embeds=None):
        cfg = self.cfg
        scale = cfg.name.startswith(("gemma", "recurrentgemma"))
        table = dequant_tree(params["embed"], self.dtype)
        x = embed_lookup(tokens, table, scale_by_sqrt_dim=scale)
        if extra_embeds is not None:
            x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        return shard_batch(x)

    def _logits(self, params, x):
        cfg = self.cfg
        x = apply_norm(x, params["final_norm"], cfg.norm)
        if self.cfg.tie_embeddings:
            return logits_from_embedding(
                x, dequant_tree(params["embed"], x.dtype), cfg.logit_softcap)
        out = x @ dequant_tree(params["lm_head"], x.dtype).astype(x.dtype)
        if cfg.logit_softcap:
            out = jnp.tanh(out / cfg.logit_softcap) * cfg.logit_softcap
        return out

    # -- training loss --------------------------------------------------------
    def loss(self, params, batch, ctx: Optional[ParallelCtx] = None):
        """batch: tokens (B, S+1) int32 [+ frames/patches for enc-dec/vlm]."""
        ctx = ctx or ParallelCtx()
        cfg = self.cfg
        tokens = batch["tokens"]
        inp, labels = tokens[:, :-1], tokens[:, 1:]
        extra = batch.get("patches") if cfg.frontend == "vision" else None
        x = self._embed(params, inp, extra)
        if cfg.encoder is not None:
            enc = self._encode(params, batch["frames"], ctx)
            x, _, aux = self._encdec_forward(params, x, enc, ctx)
        else:
            x, _, aux = self._backbone(params, x, ctx)
        if extra is not None:
            x = x[:, extra.shape[1]:]
        mask = batch.get("mask")
        x = apply_norm(x, params["final_norm"], cfg.norm)
        table = (params["embed"] if cfg.tie_embeddings
                 else params["lm_head"].T)
        if x.shape[1] * cfg.vocab_size > (1 << 24):
            # stream the vocab projection: never materialize (B, S, V)
            loss = cross_entropy_streamed(x, table, labels, mask,
                                          softcap=cfg.logit_softcap)
        else:
            logits = logits_from_embedding(x, table, cfg.logit_softcap)
            loss = cross_entropy(logits, labels, mask)
        return loss + aux

    # -- enc-dec (whisper) -----------------------------------------------------
    def _encdec_forward(self, params, x, enc, ctx, *, make_cache=False,
                        cache_len=None, q_offset=0):
        """Decoder with one cross-attention after each group's self blocks.

        Returns (x, caches|None, aux).
        """
        cfg = self.cfg

        def scan_body(carry, gp):
            x, aux = carry
            gp = dequant_tree(gp, self.dtype)
            x, caches, a = _group_forward(x, gp, cfg, ctx,
                                          make_cache=make_cache,
                                          cache_len=cache_len,
                                          q_offset=q_offset)
            h = apply_norm(x, gp["xnorm"], cfg.norm)
            enc_kv = gqa.encode_kv(enc, gp["xattn"], cfg)
            x = x + gqa.cross_attn_forward(h, enc_kv, gp["xattn"], cfg)
            return (x, aux + a), caches

        (x, aux), caches = jax.lax.scan(
            scan_body, (x, jnp.zeros((), jnp.float32)), params["groups"])
        return x, (caches if make_cache else None), aux

    # -- serving ---------------------------------------------------------------
    def init_caches(self, batch, cache_len):
        cfg = self.cfg
        def one_group(pattern):
            return {f"b{i}": _init_cache_for(cfg, kind, batch, cache_len,
                                             self.dtype)
                    for i, kind in enumerate(pattern)}
        g = one_group(cfg.block_pattern)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_groups,) + a.shape).copy()
            if cfg.scan_layers else a, g)
        tail = one_group(cfg.tail_pattern) if cfg.tail_pattern else None
        return {"groups": stacked, "tail": tail, "index": jnp.zeros((), jnp.int32)}

    def prefill(self, params, batch, cache_len, ctx=None):
        """Forward the prompt, building caches. Returns (last_logits, caches)."""
        ctx = ctx or ParallelCtx()
        cfg = self.cfg
        tokens = batch["tokens"]
        extra = batch.get("patches") if cfg.frontend == "vision" else None
        x = self._embed(params, tokens, extra)
        if cfg.encoder is not None:
            enc = self._encode(params, batch["frames"], ctx)
            x, caches, _ = self._encdec_forward(params, x, enc, ctx,
                                                make_cache=True,
                                                cache_len=cache_len)
            logits = self._logits(params, x[:, -1:])
            return logits, {"groups": caches, "tail": None, "enc": enc,
                            "index": jnp.array(tokens.shape[1], jnp.int32)}
        x, (caches, tail_caches), _ = self._backbone(
            params, x, ctx, make_cache=True, cache_len=cache_len)
        logits = self._logits(params, x[:, -1:])
        seq = x.shape[1]
        return logits, {"groups": caches, "tail": tail_caches,
                        "index": jnp.array(seq, jnp.int32)}

    def decode_step(self, params, caches, token, ctx=None):
        """token: (B, 1) int32. Returns (logits (B,1,V), new caches)."""
        ctx = ctx or ParallelCtx()
        cfg = self.cfg
        index = caches["index"]
        x = self._embed(params, token)
        enc = caches.get("enc")

        def scan_body(x, inp):
            gp, cache_g = inp
            gp = dequant_tree(gp, self.dtype)  # int8 serving path
            x, new_cache = _group_decode(x, gp, cfg, ctx, cache_g, index)
            if enc is not None:  # enc-dec: cross-attend after the group
                h = apply_norm(x, gp["xnorm"], cfg.norm)
                enc_kv = gqa.encode_kv(enc, gp["xattn"], cfg)
                x = x + gqa.cross_attn_forward(h, enc_kv, gp["xattn"], cfg)
            return x, new_cache

        if cfg.scan_layers:
            x, new_caches = jax.lax.scan(scan_body, x,
                                         (params["groups"], caches["groups"]))
        else:
            new_list = []
            for i in range(cfg.n_groups):
                gp = jax.tree.map(lambda a: a[i], params["groups"])
                cg = jax.tree.map(lambda a: a[i], caches["groups"])
                x, c = _group_decode(x, gp, cfg, ctx, cg, index)
                new_list.append(c)
            new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *new_list)

        tail_caches = caches.get("tail")
        if cfg.tail_pattern:
            tail_cfg = cfg.replace(block_pattern=cfg.tail_pattern)
            x, tail_caches = _group_decode(x, params["tail"], tail_cfg, ctx,
                                           caches["tail"], index)
        logits = self._logits(params, x)
        out = {"groups": new_caches, "tail": tail_caches, "index": index + 1}
        if enc is not None:
            out["enc"] = enc
        return logits, out

    # -- misc -------------------------------------------------------------------
    def param_count(self, params=None) -> int:
        if params is None:
            shapes = jax.eval_shape(lambda k: self.init(k).params,
                                    jax.random.PRNGKey(0))
            return sum(int(np.prod(a.shape)) for a in jax.tree.leaves(shapes))
        return sum(int(np.prod(a.shape)) for a in jax.tree.leaves(params))
