"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM recurrence per head (exp gating with max-stabilizer m):

    m_t  = max(f~_t + m_{t-1}, i~_t)
    f'   = exp(f~_t + m_{t-1} - m_t);  i' = exp(i~_t - m_t)
    C_t  = f' C_{t-1} + i' k_t v_t^T          (matrix memory, hd x hd)
    n_t  = f' n_{t-1} + i' k_t
    h_t  = (q_t^T C_t) / max(|q_t^T n_t|, exp(-m_t))

Training/prefill runs the *chunkwise* form: within a chunk the output is an
attention-like masked product with gate matrix D, across chunks the (C, n,
m) state is carried recurrently — O(S * L) work instead of O(S^2).  Decode
is the plain O(1) step.  sLSTM has recurrent weights on h so it is
inherently sequential: a lax.scan over time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init, norm_init, apply_norm, zeros_init

NEG = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def init_mlstm(key, cfg, dtype=jnp.float32):
    d, h = cfg.d_model, cfg.n_heads
    hd = cfg.head_dim
    ks = jax.random.split(key, 7)
    return {
        "wq": dense_init(ks[0], (d, h, hd), ("embed", "heads", None), 0, dtype),
        "wk": dense_init(ks[1], (d, h, hd), ("embed", "heads", None), 0, dtype),
        "wv": dense_init(ks[2], (d, h, hd), ("embed", "heads", None), 0, dtype),
        "w_i": dense_init(ks[3], (d, h), ("embed", "heads"), 0, jnp.float32),
        "w_f": dense_init(ks[4], (d, h), ("embed", "heads"), 0, jnp.float32),
        "b_i": zeros_init((h,), ("heads",)),
        "b_f": (jnp.full((h,), 3.0, jnp.float32), ("heads",)),  # open forget
        "w_o": dense_init(ks[5], (d, h, hd), ("embed", "heads", None), 0, dtype),
        "norm": norm_init(h * hd),
        "w_out": dense_init(ks[6], (h, hd, d), ("heads", None, "embed"),
                            (0, 1), dtype),
    }


def _mlstm_proj(x, p):
    hd = p["wq"].shape[-1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype)) / np.sqrt(hd)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype)) / np.sqrt(hd)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    it = (jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["w_i"])
          + p["b_i"])
    ft = (jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["w_f"])
          + p["b_f"])
    ft = -jax.nn.softplus(-ft)           # log sigmoid: log f in (-inf, 0)
    og = jax.nn.sigmoid(jnp.einsum("bsd,dhk->bshk", x, p["w_o"].astype(x.dtype)))
    return q, k, v, it, ft, og


def mlstm_chunk_forward(x, p, cfg, state=None, chunk: int = 256):
    """Chunkwise-parallel mLSTM over a sequence.

    x: (B, S, d) with S % chunk == 0 (or S < chunk: single chunk).
    state: None or dict(c (B,H,hd,hd), n (B,H,hd), m (B,H)).
    Returns (y, new_state).
    """
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    l = min(chunk, s)
    assert s % l == 0, (s, l)
    nchunk = s // l
    q, k, v, it, ft, og = _mlstm_proj(x, p)
    # reshape into chunks: (B, N, L, H, ...)
    rs = lambda a: a.reshape((b, nchunk, l) + a.shape[2:])
    q, k, v, it, ft, og = map(rs, (q, k, v, it, ft, og))

    if state is None:
        state = init_mlstm_state(b, cfg)

    def chunk_step(carry, inp):
        c0, n0, m0 = carry                       # (B,H,hd,hd),(B,H,hd),(B,H)
        qc, kc, vc, ic, fc = inp                 # (B,L,H,*) / (B,L,H)
        g = jnp.cumsum(fc, axis=1)               # (B,L,H) cumulative log f
        # stabilizers: intra source term a_s = i~_s - g_s ; inter term m0
        a = ic - g                               # (B,L,H)
        a_run = jax.lax.cummax(a, axis=1)        # running max over s<=t
        m_t = jnp.maximum(g + m0[:, None, :], g + a_run)   # (B,L,H)
        # inter-chunk: exp(g_t + m0 - m_t) * (q C0, q n0)
        inter_w = jnp.exp(g + m0[:, None, :] - m_t)        # (B,L,H)
        q32 = qc.astype(jnp.float32)
        inter_h = jnp.einsum("blhk,bhkj->blhj", q32, c0) * inter_w[..., None]
        inter_n = jnp.einsum("blhk,bhk->blh", q32, n0) * inter_w
        # intra-chunk masked gate matrix D[t,s] = exp(g_t - g_s + i_s - m_t)
        logd = (g[:, :, None, :] - g[:, None, :, :]
                + ic[:, None, :, :] - m_t[:, :, None, :])  # (B,L,L,H) t,s
        mask = jnp.tril(jnp.ones((l, l), bool))
        logd = jnp.where(mask[None, :, :, None], logd, NEG)
        dmat = jnp.exp(logd)
        scores = jnp.einsum("bthk,bshk->btsh", q32, kc.astype(jnp.float32))
        w = scores * dmat
        intra_h = jnp.einsum("btsh,bshj->bthj", w, vc.astype(jnp.float32))
        intra_n = w.sum(axis=2)                            # (B,L,H)
        denom = jnp.maximum(jnp.abs(inter_n + intra_n), jnp.exp(-m_t))
        h_out = (inter_h + intra_h) / denom[..., None]     # (B,L,H,hd)
        # chunk-end state
        g_l = g[:, -1, :]                                  # (B,H)
        m_end = jnp.maximum(g_l + m0, g_l + a_run[:, -1, :])
        c_new = (jnp.exp(g_l + m0 - m_end)[..., None, None] * c0
                 + jnp.einsum("blhk,blhj,blh->bhkj",
                              kc.astype(jnp.float32), vc.astype(jnp.float32),
                              jnp.exp(g_l[:, None, :] - g + ic - m_end[:, None, :])))
        n_new = (jnp.exp(g_l + m0 - m_end)[..., None] * n0
                 + jnp.einsum("blhk,blh->bhk", kc.astype(jnp.float32),
                              jnp.exp(g_l[:, None, :] - g + ic - m_end[:, None, :])))
        return (c_new, n_new, m_end), h_out

    carry = (state["c"], state["n"], state["m"])
    swap = lambda a: a.swapaxes(0, 1)            # scan over chunk dim
    (c, n, m), hs = jax.lax.scan(
        chunk_step, carry,
        (swap(q), swap(k), swap(v), swap(it), swap(ft)))
    hs = hs.swapaxes(0, 1).reshape(b, s, h, hd)  # (B,S,H,hd)
    hs = hs.astype(x.dtype) * og.reshape(b, s, h, hd)
    flat = apply_norm(hs.reshape(b, s, h * hd), p["norm"])
    y = jnp.einsum("bshk,hkd->bsd", flat.reshape(b, s, h, hd),
                   p["w_out"].astype(x.dtype))
    return y, {"c": c, "n": n, "m": m}


def mlstm_decode(x, p, cfg, state):
    """O(1) one-token step. x: (B, 1, d)."""
    b = x.shape[0]
    h, hd = cfg.n_heads, cfg.head_dim
    q, k, v, it, ft, og = _mlstm_proj(x, p)
    q, k, v, og = (a[:, 0] for a in (q, k, v, og))        # (B,H,hd)
    it, ft = it[:, 0], ft[:, 0]                            # (B,H)
    m_new = jnp.maximum(ft + state["m"], it)
    fp = jnp.exp(ft + state["m"] - m_new)[..., None]
    ip = jnp.exp(it - m_new)[..., None]
    k32, v32, q32 = (a.astype(jnp.float32) for a in (k, v, q))
    c = fp[..., None] * state["c"] + ip[..., None] * (k32[..., :, None]
                                                      * v32[..., None, :])
    n = fp * state["n"] + ip * k32
    num = jnp.einsum("bhk,bhkj->bhj", q32, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q32, n)),
                      jnp.exp(-m_new))
    hvec = (num / den[..., None]).astype(x.dtype) * og
    flat = apply_norm(hvec.reshape(b, 1, h * hd), p["norm"])
    y = jnp.einsum("bshk,hkd->bsd", flat.reshape(b, 1, h, hd),
                   p["w_out"].astype(x.dtype))
    return y, {"c": c, "n": n, "m": m_new}


def init_mlstm_state(batch, cfg):
    h, hd = cfg.n_heads, cfg.head_dim
    return {
        "c": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.zeros((batch, h), jnp.float32),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def init_slstm(key, cfg, dtype=jnp.float32):
    d, h = cfg.d_model, cfg.n_heads
    hd = cfg.head_dim
    ks = jax.random.split(key, 9)
    gate = lambda kk: dense_init(kk, (d, h, hd), ("embed", "heads", None),
                                 0, dtype)
    rec = lambda kk: dense_init(kk, (h, hd, hd), ("heads", None, None),
                                (1,), dtype, scale=0.5)
    return {
        "wz": gate(ks[0]), "wi": gate(ks[1]), "wf": gate(ks[2]),
        "wo": gate(ks[3]),
        "rz": rec(ks[4]), "ri": rec(ks[5]), "rf": rec(ks[6]), "ro": rec(ks[7]),
        "b_f": (jnp.full((h, hd), 3.0, jnp.float32), ("heads", None)),
        "norm": norm_init(h * hd),
        "w_out": dense_init(ks[8], (h, hd, d), ("heads", None, "embed"),
                            (0, 1), dtype),
    }


def _slstm_step(p, carry, xs):
    c0, n0, h0, m0 = carry                       # (B,H,hd) x3, m (B,H,hd)
    xz, xi, xf, xo = xs                          # (B,H,hd) pre-projections
    r = lambda w: jnp.einsum("bhk,hkj->bhj", h0, w.astype(h0.dtype))
    z = jnp.tanh(xz + r(p["rz"]))
    it = (xi + r(p["ri"])).astype(jnp.float32)
    ft = (xf + r(p["rf"]) + p["b_f"]).astype(jnp.float32)
    ft = -jax.nn.softplus(-ft)                   # log sigmoid
    o = jax.nn.sigmoid(xo + r(p["ro"]))
    m1 = jnp.maximum(ft + m0, it)
    ip = jnp.exp(it - m1)
    fp = jnp.exp(ft + m0 - m1)
    c1 = fp * c0 + ip * z.astype(jnp.float32)
    n1 = fp * n0 + ip
    h1 = (o.astype(jnp.float32) * (c1 / jnp.maximum(n1, 1e-6))).astype(h0.dtype)
    return (c1, n1, h1, m1), h1


def slstm_forward(x, p, cfg, state=None):
    """Sequential sLSTM over (B, S, d).  Returns (y, new_state)."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    proj = lambda w: jnp.einsum("bsd,dhk->bshk", x, w.astype(x.dtype))
    xz, xi, xf, xo = proj(p["wz"]), proj(p["wi"]), proj(p["wf"]), proj(p["wo"])
    if state is None:
        state = init_slstm_state(b, cfg)
    carry = (state["c"], state["n"], state["h"], state["m"])
    swap = lambda a: a.swapaxes(0, 1)
    carry, hs = jax.lax.scan(lambda c, xs: _slstm_step(p, c, xs), carry,
                             (swap(xz), swap(xi), swap(xf), swap(xo)))
    hs = hs.swapaxes(0, 1).astype(x.dtype)       # (B,S,H,hd)
    flat = apply_norm(hs.reshape(b, s, h * hd), p["norm"])
    y = jnp.einsum("bshk,hkd->bsd", flat.reshape(b, s, h, hd),
                   p["w_out"].astype(x.dtype))
    c1, n1, h1, m1 = carry
    return y, {"c": c1, "n": n1, "h": h1, "m": m1}


def slstm_decode(x, p, cfg, state):
    y, st = slstm_forward(x, p, cfg, state)
    return y, st


def init_slstm_state(batch, cfg):
    h, hd = cfg.n_heads, cfg.head_dim
    z = lambda: jnp.zeros((batch, h, hd), jnp.float32)
    return {"c": z(), "n": z(), "h": z(), "m": z()}
