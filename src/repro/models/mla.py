"""Multi-head Latent Attention (DeepSeek-V2) with absorbed decode.

Training/prefill computes standard multi-head attention over decompressed
keys/values; the cache stores only the latent ``c_kv`` (kv_lora dims) plus
the shared rotary key — the MLA memory win (512+64 vs 2*128*128 per token).

Decode uses the *absorption* identities: W_uk folds into the query
(q' = q @ W_uk^T) and W_uv folds into the output projection, so per-step
attention runs directly against the latent cache with no decompression.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn_lib
from repro.models.common import apply_rope, dense_init, norm_init, apply_norm


def init_mla(key, cfg, dtype=jnp.float32):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk_dim = m.nope_dim + m.rope_dim
    ks = jax.random.split(key, 8)
    return {
        "w_dq": dense_init(ks[0], (d, m.q_lora), ("embed", "qlora"), 0, dtype),
        "q_norm": norm_init(m.q_lora),
        "w_uq": dense_init(ks[1], (m.q_lora, h, qk_dim),
                           ("qlora", "heads", None), 0, dtype),
        "w_dkv": dense_init(ks[2], (d, m.kv_lora + m.rope_dim),
                            ("embed", "kvlora"), 0, dtype),
        "kv_norm": norm_init(m.kv_lora),
        "w_uk": dense_init(ks[3], (m.kv_lora, h, m.nope_dim),
                           ("kvlora", "heads", None), 0, dtype),
        "w_uv": dense_init(ks[4], (m.kv_lora, h, m.v_dim),
                           ("kvlora", "heads", None), 0, dtype),
        "wo": dense_init(ks[5], (h, m.v_dim, d), ("heads", None, "embed"),
                         (0, 1), dtype),
    }


def _latents(x, p, cfg, positions):
    """Shared path: query heads + latent kv + rotary shared key."""
    m = cfg.mla
    cq = apply_norm(x @ p["w_dq"].astype(x.dtype), p["q_norm"])
    q = jnp.einsum("bsl,lhk->bshk", cq, p["w_uq"].astype(x.dtype))
    q_nope, q_rope = q[..., : m.nope_dim], q[..., m.nope_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = x @ p["w_dkv"].astype(x.dtype)
    c_kv = apply_norm(dkv[..., : m.kv_lora], p["kv_norm"])
    k_rope = dkv[..., m.kv_lora:][:, :, None, :]          # (B,S,1,rope)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(x, p, cfg, *, q_offset: int = 0, make_cache=False,
                cache_len=None):
    """Train/prefill MLA. Returns (out, cache|None)."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    positions = q_offset + jnp.arange(s)[None, :]
    q_nope, q_rope, c_kv, k_rope = _latents(x, p, cfg, positions)

    k_nope = jnp.einsum("bsl,lhk->bshk", c_kv, p["w_uk"].astype(x.dtype))
    v = jnp.einsum("bsl,lhk->bshk", c_kv, p["w_uv"].astype(x.dtype))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope, (b, s, h, m.rope_dim))],
                        axis=-1)
    o = attn_lib.attention(q, k, v, causal=True, q_offset=q_offset)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))

    cache = None
    if make_cache:
        length = cache_len or s
        cache = {
            "c_kv": jnp.zeros((b, length, m.kv_lora), x.dtype),
            "k_rope": jnp.zeros((b, length, m.rope_dim), x.dtype),
            "pos": jnp.full((b, length), -1, jnp.int32),
        }
        cache["c_kv"] = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(x.dtype), 0, 1)
        cache["k_rope"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope[:, :, 0, :].astype(x.dtype), 0, 1)
        cache["pos"] = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"],
            jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s)),
            0, 1)
    return out, cache


def mla_decode(x, p, cfg, cache, index):
    """Absorbed one-token decode against the latent cache."""
    m = cfg.mla
    b = x.shape[0]
    positions = jnp.full((b, 1), index, jnp.int32)
    q_nope, q_rope, c_kv_new, k_rope_new = _latents(x, p, cfg, positions)

    # append to latent cache
    slot = index % cache["c_kv"].shape[1]
    cache = dict(cache)
    cache["c_kv"] = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), (0, slot, 0))
    cache["k_rope"] = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_new[:, :, 0, :].astype(cache["k_rope"].dtype),
        (0, slot, 0))
    cache["pos"] = jax.lax.dynamic_update_slice(
        cache["pos"], jnp.full((b, 1), index, jnp.int32), (0, slot))

    # absorb W_uk into q: score_nope = (q_nope @ W_uk^T) . c_kv
    # (f32 accumulation: the absorbed product order amplifies bf16 rounding)
    q_lat = jnp.einsum("bshk,lhk->bshl", q_nope, p["w_uk"].astype(x.dtype),
                       preferred_element_type=jnp.float32)
    scale = 1.0 / np.sqrt(m.nope_dim + m.rope_dim)
    s_nope = jnp.einsum("bshl,btl->bhst", q_lat, cache["c_kv"],
                        preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bshk,btk->bhst", q_rope, cache["k_rope"],
                        preferred_element_type=jnp.float32)
    s = (s_nope + s_rope) * scale
    valid = (cache["pos"] >= 0) & (cache["pos"] <= index)
    s = jnp.where(valid[:, None, None, :], s, attn_lib.NEG_INF)
    pr = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    # attend in latent space, then absorb W_uv into the output projection
    o_lat = jnp.einsum("bhst,btl->bshl", pr, cache["c_kv"])     # (B,1,H,lora)
    o = jnp.einsum("bshl,lhk->bshk", o_lat, p["w_uv"].astype(x.dtype))
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return out, cache
