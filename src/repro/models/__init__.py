"""Model substrate (attention, MoE, recurrent blocks, assembly)."""
