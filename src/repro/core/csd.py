"""Canonical Signed Digit (CSD) recoding — Section V of the paper.

The paper (Listing 1) recodes an unsigned integer's bit string into digits in
{-1, 0, +1} such that the total number of nonzero digits never increases, and
strictly decreases for any run ("chain") of >= 3 consecutive ones.  Chains of
exactly two ones are recoded with probability 1/2 ("we flip a coin ... since a
transformation of a length 2 chain has no benefit and no detriment") to balance
the positive/negative decomposition.

Two implementations live here:

* :func:`convert_to_csd` — a faithful, element-at-a-time port of the paper's
  Listing 1 (MSb-first bit list in, one-digit-wider MSb-first digit list out).
* :func:`csd_transform` — a vectorized NumPy state machine that applies the
  identical recoding to every element of an integer array at once (the per-bit
  scan is a loop of length ``width + 1``; everything else is array-parallel).

Both share the randomized length-2-chain tie-break; the vectorized version
consumes a ``numpy.random.Generator`` so the transform is reproducible.
"""

from __future__ import annotations

import random
from typing import List, Sequence

import numpy as np

__all__ = [
    "convert_to_csd",
    "int_to_bits",
    "bits_to_int",
    "digits_to_int",
    "csd_digits",
    "csd_transform",
    "pn_from_digits",
    "nonzero_digit_count",
]


# ---------------------------------------------------------------------------
# Faithful port of the paper's Listing 1.
# ---------------------------------------------------------------------------
def convert_to_csd(num_bin_list: Sequence[int], rng: random.Random | None = None) -> List[int]:
    """Recode an MSb-first bit list into CSD digits (paper Listing 1).

    Args:
        num_bin_list: bits of an unsigned integer, most significant bit first.
        rng: source of the length-2-chain coin flip.  Defaults to the module
            ``random`` generator, matching ``random.getrandbits(1)`` in the
            paper's listing.

    Returns:
        Digit list in {-1, 0, 1}, MSb first, exactly one digit wider than the
        input (the paper: "the bit-width of the decomposition is one wider").
    """
    coin = (lambda: bool(rng.getrandbits(1))) if rng is not None else (
        lambda: bool(random.getrandbits(1)))

    local_list = list(num_bin_list)
    target = [0] * (len(local_list) + 1)
    local_list.reverse()  # process LSb -> MSb
    chain_start = -1  # are we in a chain?
    for i in range(len(target)):
        bit = local_list[i] if i < len(local_list) else 0
        if bit == 0:
            if chain_start == -1:  # no chain; nothing to be done here
                target[i] = 0
            else:
                # We terminate a chain; how long is it?
                chain_length = i - chain_start
                if chain_length == 1:  # leave it alone
                    target[chain_start] = 1
                elif chain_length == 2:  # a chain of two: coin flip
                    if coin():
                        target[chain_start] = -1  # do the substitution
                        target[i] = 1
                    else:
                        target[chain_start] = 1
                        target[i - 1] = 1
                else:  # length >= 3: will get benefit
                    target[chain_start] = -1
                    target[i] = 1
                chain_start = -1  # not in a chain anymore
        else:  # bit == 1
            if chain_start == -1:
                chain_start = i
    target.reverse()
    return target


# ---------------------------------------------------------------------------
# Bit/digit helpers.
# ---------------------------------------------------------------------------
def int_to_bits(value: int, width: int) -> List[int]:
    """Unsigned ``value`` as an MSb-first bit list of length ``width``."""
    if value < 0:
        raise ValueError("int_to_bits takes unsigned values; PN-split first")
    if value >= (1 << width):
        raise ValueError(f"{value} does not fit in {width} bits")
    return [(value >> (width - 1 - i)) & 1 for i in range(width)]


def bits_to_int(bits: Sequence[int]) -> int:
    """MSb-first bit list back to an unsigned integer."""
    out = 0
    for b in bits:
        out = (out << 1) | int(b)
    return out


def digits_to_int(digits: Sequence[int]) -> int:
    """MSb-first {-1,0,1} digit list to its signed integer value."""
    out = 0
    for d in digits:
        out = (out << 1) + int(d)
    return out


def csd_digits(value: int, width: int, rng: random.Random | None = None) -> List[int]:
    """CSD digits (MSb first, ``width + 1`` long) of an unsigned integer."""
    return convert_to_csd(int_to_bits(value, width), rng)


# ---------------------------------------------------------------------------
# Vectorized CSD over integer arrays.
# ---------------------------------------------------------------------------
def csd_transform(
    values: np.ndarray,
    width: int,
    rng: np.random.Generator | None = None,
) -> np.ndarray:
    """Apply the paper's CSD recoding to every element of an unsigned array.

    Runs the identical state machine as :func:`convert_to_csd`, but with the
    per-element state (``chain_start``) held in arrays so the scan over bit
    positions is the only Python loop.

    Args:
        values: array of unsigned integers, each < 2**width.
        width: input bit width.
        rng: generator for the length-2 coin flips (one flip per terminated
            length-2 chain, like the reference).  Defaults to a fixed seed so
            the transform is deterministic unless the caller opts out.

    Returns:
        int8 array of shape ``values.shape + (width + 1,)`` holding digits in
        {-1, 0, 1}, **LSb first** (index d = weight 2**d).
    """
    if rng is None:
        rng = np.random.default_rng(0)
    vals = np.asarray(values)
    if vals.size and (vals.min() < 0 or vals.max() >= (1 << width)):
        raise ValueError("values must be unsigned and fit in `width` bits")

    flat = vals.reshape(-1).astype(np.int64)
    n = flat.shape[0]
    target = np.zeros((n, width + 1), dtype=np.int8)
    chain_start = np.full(n, -1, dtype=np.int64)

    for i in range(width + 1):
        bit = ((flat >> i) & 1).astype(bool) if i < width else np.zeros(n, dtype=bool)
        in_chain = chain_start >= 0

        ends = (~bit) & in_chain          # chains terminating at this position
        starts = bit & (~in_chain)        # chains starting at this position

        if ends.any():
            idx = np.nonzero(ends)[0]
            length = i - chain_start[idx]
            cs = chain_start[idx]

            len1 = length == 1
            target[idx[len1], cs[len1]] = 1

            len2 = length == 2
            if len2.any():
                heads = rng.integers(0, 2, size=int(len2.sum())).astype(bool)
                i2 = idx[len2]
                c2 = cs[len2]
                # heads: substitute (-1 at LSb of chain, +1 one past MSb)
                target[i2[heads], c2[heads]] = -1
                target[i2[heads], i] = 1
                # tails: leave the original two ones
                target[i2[~heads], c2[~heads]] = 1
                target[i2[~heads], i - 1] = 1

            len3 = length >= 3
            target[idx[len3], cs[len3]] = -1
            target[idx[len3], i] = 1

            chain_start[idx] = -1

        chain_start[starts] = i

    return target.reshape(vals.shape + (width + 1,))


def pn_from_digits(digits: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Split an LSb-first digit array into unsigned (P, N) integer arrays.

    ``value = P - N`` where P collects the +1 digits and N the -1 digits
    (paper Eq. 6: V = P - N  =>  o = aT.P - aT.N).
    """
    weights = (1 << np.arange(digits.shape[-1], dtype=np.int64))
    pos = (digits > 0).astype(np.int64)
    neg = (digits < 0).astype(np.int64)
    return pos @ weights, neg @ weights


def nonzero_digit_count(digits: np.ndarray) -> int:
    """Total nonzero digits — the paper's hardware cost metric ("ones")."""
    return int(np.count_nonzero(digits))
