"""FPGA cost / frequency / power / latency models — Sections IV and VI.

The paper's headline cost model is deliberately simple:

  * LUTs  ~= number of set digit bits ("ones") in the PN/CSD planes
            ("LUTs are essentially equivalent to the number of ones", Fig 10)
  * FFs   ~= 2 x LUTs ("there are two registers per LUT", Fig 10)
  * Fmax  : banded by SLR occupancy on the XCVU13P (Fig 11) —
            <=1 SLR: 597..445 MHz, <=2 SLR: 400..296 MHz, >2 SLR: 250..225 MHz
  * Power : static + dynamic ~ ones x f (Fig 12, ~150 W thermal limit)
  * Latency (Eq 5): BW_i + BW_w + log2(R) + 2 cycles.

Everything here is NumPy-scalar math so the benchmark harness can sweep
thousands of design points instantly.  Calibrated constants are marked
``# calibrated:`` with the paper anchor that pins them.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = [
    "XCVU13P",
    "FPGADesignPoint",
    "ROLLOUT_FEATURES",
    "RolloutCostModel",
    "expected_ones",
    "luts_for_ones",
    "ffs_for_ones",
    "fmax_hz",
    "power_w",
    "latency_cycles",
    "design_point",
    "tpu_decode_bytes",
    "rollout_cost_features",
    "default_rollout_cost_model",
    "fit_rollout_cost",
]

# --- Xilinx XCVU13P (paper Sec. VI) ---------------------------------------
@dataclasses.dataclass(frozen=True)
class _XCVU13P:
    total_luts: int = 1_700_000          # "capacity of 1.7M 6-input LUTs"
    total_ffs: int = 3_400_000           # "3.4M logic flip-flops"
    slr_luts: int = 425_000              # "maximum capacity of 425k LUTs" per SLR
    n_slr: int = 4                       # "four chiplets in the package"
    thermal_limit_w: float = 150.0       # "thermal power limit ... approximately 150W"


XCVU13P = _XCVU13P()

# Fmax bands measured in Fig 11 (place-and-route results).
_FMAX_BANDS = (
    # (lut_low, lut_high, f_at_low_hz, f_at_high_hz)
    (0,         425_000,   597e6, 445e6),   # "within one SLR ... 597MHz to 445MHz"
    (425_000,   850_000,   400e6, 296e6),   # "2 SLRs range from 296MHz to 400MHz"
    (850_000, 1_700_000,   250e6, 225e6),   # ">2 SLRs ... between 225MHz and 250MHz"
)

# calibrated: Vivado-style static floor + per-toggle energy such that a
# 1.5M-ones design at 225 MHz sits at the ~150 W thermal limit (Fig 12).
_STATIC_POWER_W = 3.0
_ENERGY_PER_ONE_TOGGLE_J = (XCVU13P.thermal_limit_w - _STATIC_POWER_W) / (1.5e6 * 225e6)


def expected_ones(
    rows: int,
    cols: int,
    element_sparsity: float,
    weight_bits: int = 8,
    mode: str = "pn",
) -> float:
    """Expected set digit bits for a random matrix (the paper's cost driver).

    Uniform nonzero magnitudes set half their magnitude bits on average; CSD
    recoding removes ~17% of them at 8-bit ("CSD ... reduces the hardware by
    17% for any level of element-sparsity", Fig 9).
    """
    nnz = rows * cols * (1.0 - element_sparsity)
    mag_bits = max(weight_bits - 1, 1)
    bits_per_nz = mag_bits / 2.0
    if mode == "csd":
        bits_per_nz *= 0.83  # paper Fig 9: -17% at any element sparsity
    return nnz * bits_per_nz


def luts_for_ones(ones: float) -> float:
    """Fig 10: 'LUTs are essentially equivalent to the number of ones'."""
    return float(ones)


def ffs_for_ones(ones: float) -> float:
    """Fig 10: 'there are two registers per LUT'."""
    return 2.0 * ones


def fmax_hz(luts: float) -> float:
    """Piecewise-linear Fmax within the paper's SLR occupancy bands (Fig 11)."""
    if luts > XCVU13P.total_luts:
        raise ValueError(
            f"design needs {luts:.0f} LUTs > device capacity "
            f"{XCVU13P.total_luts} (paper: 'bound by the number of 6-input LUTs')")
    for lo, hi, f_lo, f_hi in _FMAX_BANDS:
        if luts <= hi:
            frac = (luts - lo) / (hi - lo)
            return f_lo + frac * (f_hi - f_lo)
    raise AssertionError("unreachable")


def power_w(ones: float, f_hz: float) -> float:
    """Fig 12: static + activity-proportional dynamic power."""
    return _STATIC_POWER_W + _ENERGY_PER_ONE_TOGGLE_J * ones * f_hz


def latency_cycles(input_bits: int, weight_bits: int, rows: int) -> int:
    """Paper Eq. 5."""
    return input_bits + weight_bits + int(math.ceil(math.log2(rows))) + 2


@dataclasses.dataclass(frozen=True)
class FPGADesignPoint:
    """One compiled fixed-matrix design on the XCVU13P."""

    rows: int
    cols: int
    element_sparsity: float
    weight_bits: int
    input_bits: int
    mode: str
    ones: float
    luts: float
    ffs: float
    fmax_hz: float
    power_w: float
    cycles: int

    @property
    def latency_s(self) -> float:
        return self.cycles / self.fmax_hz

    @property
    def latency_ns(self) -> float:
        return self.latency_s * 1e9

    @property
    def slrs(self) -> int:
        return int(math.ceil(self.luts / XCVU13P.slr_luts)) or 1

    def batch_latency_s(self, batch: int) -> float:
        """Streaming batches through the spatial array is fully pipelined at
        one vector per ``input_bits`` cycles after the first result (the
        input shift registers are the only per-vector resource)."""
        extra = (batch - 1) * self.input_bits
        return (self.cycles + extra) / self.fmax_hz

    @property
    def fits(self) -> bool:
        return self.luts <= XCVU13P.total_luts


def design_point(
    rows: int,
    cols: int,
    element_sparsity: float,
    weight_bits: int = 8,
    input_bits: int = 8,
    mode: str = "pn",
    ones: float | None = None,
) -> FPGADesignPoint:
    """Build a design point; ``ones`` may come from a real decomposed matrix
    (exact) or default to the :func:`expected_ones` analytic estimate."""
    if ones is None:
        ones = expected_ones(rows, cols, element_sparsity, weight_bits, mode)
    luts = luts_for_ones(ones)
    f = fmax_hz(luts)
    return FPGADesignPoint(
        rows=rows, cols=cols, element_sparsity=element_sparsity,
        weight_bits=weight_bits, input_bits=input_bits, mode=mode,
        ones=ones, luts=luts, ffs=ffs_for_ones(ones), fmax_hz=f,
        power_w=power_w(ones, f),
        cycles=latency_cycles(input_bits, weight_bits, rows),
    )


# --- Rollout schedule cost model (plan autotuning) -------------------------
# The same "simple and extensible" philosophy as the FPGA model above,
# pointed at the TPU/CPU rollout: a specialized RolloutProgram's runtime is
# a linear combination of the work terms its schedule implies.  The
# autotuner (repro.plan.autotune) prices every candidate schedule with
# these coefficients, prunes, then measures the survivors — and
# ``fit_rollout_cost`` closes the loop by refitting the coefficients from
# the measured rows, so the prior below only has to get the *ordering*
# roughly right, never the absolute seconds.

ROLLOUT_FEATURES = (
    "matmul_macs",     # folded-tile MAC count across the whole rollout
    "shiftadd_ops",    # unrolled digit adds across the whole rollout
    "stream_bytes",    # weight bytes moved (once if resident, per step if
                       # pipelined — the regime axis of the search)
    "band_steps",      # band-grid iterations (per-band launch overhead)
    "tile_steps",      # batch-tile-grid iterations (per-tile overhead)
    "steps",           # scan/grid steps (per-step dispatch overhead)
)


def rollout_cost_features(summary: dict, block: int, batch: int,
                          steps: int = 1) -> dict:
    """Work terms of one specialized schedule over a ``(batch, steps)``
    rollout, computed from :func:`~repro.plan.specialize.specialize_summary`
    counts only — no tile data is ever materialized to price a candidate.
    """
    batch_tile_max = summary.get("batch_tile_max", 16)
    n_tiles = max(1, -(-batch // batch_tile_max))
    b_tile = -(-batch // n_tiles)
    b_pad = b_tile * n_tiles
    itemsize = 4 if summary["mode"] == "fp32" else 1
    tile_bytes = block * block * itemsize
    payload = summary["n_matmul_terms"] * tile_bytes
    if summary["regime"] == "resident":
        stream = payload                       # hoisted on-chip once
    else:
        stream = payload * steps               # re-streamed every step
    return {
        "matmul_macs": summary["n_matmul_terms"] * block * block
        * b_pad * steps,
        "shiftadd_ops": summary["shiftadd_digits"] * b_pad * steps,
        "stream_bytes": stream,
        "band_steps": summary["n_bands"] * steps,
        "tile_steps": summary["n_bands"] * n_tiles * steps,
        "steps": steps,
    }


@dataclasses.dataclass
class RolloutCostModel:
    """Per-backend linear model over :data:`ROLLOUT_FEATURES` + intercept.

    ``coeffs[backend]`` is an ndarray of ``len(ROLLOUT_FEATURES) + 1``
    seconds-per-unit weights (intercept last).  Coefficients come from
    :func:`default_rollout_cost_model` (platform prior) or
    :func:`fit_rollout_cost` (calibrated against measured bench rows).
    """

    coeffs: dict
    platform: str = "cpu"

    def predict(self, backend: str, features: dict) -> float:
        c = self.coeffs.get(backend)
        if c is None:
            raise KeyError(f"no coefficients for backend {backend!r} "
                           f"(have {sorted(self.coeffs)})")
        v = np.array([features[k] for k in ROLLOUT_FEATURES] + [1.0])
        return float(v @ np.asarray(c))

    def as_dict(self) -> dict:
        return {"platform": self.platform,
                "features": list(ROLLOUT_FEATURES) + ["intercept"],
                "coeffs": {bk: [float(x) for x in c]
                           for bk, c in self.coeffs.items()}}

    @classmethod
    def from_dict(cls, d: dict) -> "RolloutCostModel":
        return cls(coeffs={bk: np.asarray(c, np.float64)
                           for bk, c in d["coeffs"].items()},
                   platform=d.get("platform", "cpu"))


def default_rollout_cost_model(platform: str = "cpu") -> RolloutCostModel:
    """Platform prior for the rollout cost model.

    calibrated: the absolute values are napkin numbers (CPU gemm tens of
    GFLOP/s, TPU MXU hundreds of TOP/s int8, HBM at the roofline's 819
    GB/s); what the autotuner's pruning needs is only that the *relative*
    cost of regimes/backends is right.  On non-TPU platforms the pallas
    kernels run in interpret mode, so its per-term coefficients carry an
    interpreter penalty large enough that pallas never survives pruning
    off-TPU — preserving the XLA-first dispatch the serve tests pin.
    """
    if platform == "tpu":
        coeffs = {
            #       macs    shiftadd stream   band     tile     step  icept
            "xla": [1e-14, 2e-12, 1.3e-12, 1e-7, 2e-8, 5e-7, 2e-5],
            # fused grid: no per-step dispatch back to the host
            "pallas": [1e-14, 2e-12, 1.3e-12, 5e-8, 1e-8, 2e-8, 1e-5],
        }
    else:
        coeffs = {
            "xla": [2e-11, 2e-9, 2e-11, 2e-6, 1e-6, 2e-6, 1e-4],
            # interpret-mode pallas: every grid step is python dispatch
            "pallas": [2e-9, 2e-7, 2e-9, 1e-3, 1e-3, 1e-2, 1e-2],
        }
    return RolloutCostModel(
        coeffs={bk: np.asarray(c, np.float64) for bk, c in coeffs.items()},
        platform=platform)


def fit_rollout_cost(samples, platform: str = "cpu") -> RolloutCostModel:
    """Calibrate the cost model from measured rows.

    ``samples``: iterable of ``(backend, features_dict, measured_seconds)``
    — the autotuner's measured trials, or rows replayed from
    ``BENCH_specialize.json``.  Per backend, a ridge regression regularized
    toward the platform prior (bench runs yield few rows against 7
    unknowns, so the prior anchors the underdetermined directions), with
    coefficients clipped nonnegative — a negative seconds-per-op weight is
    always noise.  Backends with no samples keep their prior.
    """
    base = default_rollout_cost_model(platform)
    coeffs = dict(base.coeffs)
    by_backend: dict = {}
    for backend, feats, seconds in samples:
        by_backend.setdefault(backend, []).append((feats, float(seconds)))
    n_coef = len(ROLLOUT_FEATURES) + 1
    for backend, rows in by_backend.items():
        a = np.array([[f[k] for k in ROLLOUT_FEATURES] + [1.0]
                      for f, _s in rows], np.float64)
        y = np.array([s for _f, s in rows], np.float64)
        scale = np.abs(a).max(axis=0)
        scale[scale == 0] = 1.0
        an = a / scale
        c0 = np.asarray(base.coeffs.get(backend,
                                        np.zeros(n_coef))) * scale
        lam = 1e-2
        lhs = an.T @ an + lam * np.eye(n_coef)
        rhs = an.T @ y + lam * c0
        c = np.linalg.solve(lhs, rhs) / scale
        coeffs[backend] = np.maximum(c, 0.0)
    return RolloutCostModel(coeffs=coeffs, platform=platform)


# --- TPU analogue: what the technique buys on a memory-bound decode --------
def tpu_decode_bytes(
    rows: int,
    cols: int,
    element_sparsity: float,
    weight_bits: int = 8,
    mode: str = "csd",
    block: int = 128,
) -> dict[str, float]:
    """Bytes a TPU must move for one gemv under different weight encodings.

    Decode (batch-1 gemv) is memory-roofline-bound: latency ~ bytes / HBM_bw.
    The paper's fixed-matrix specialization maps to (a) int8 storage and
    (b) culling all-zero ``block x block`` tiles, with per-tile digit-plane
    counts from CSD.  Returns bytes per encoding for napkin comparison;
    §Perf uses this to pick the frozen-weight serving path.
    """
    dense_bf16 = rows * cols * 2.0
    dense_int8 = rows * cols * 1.0
    # Probability a block has at least one nonzero element:
    p_nz_block = 1.0 - element_sparsity ** (block * block)
    n_blocks = math.ceil(rows / block) * math.ceil(cols / block)
    blocks_kept = n_blocks * p_nz_block
    bcsr_int8 = blocks_kept * block * block * 1.0 + n_blocks / 8.0
    # Digit-plane encoding: one bit per plane entry, planes kept per block.
    mag_bits = max(weight_bits - 1, 1)
    planes = mag_bits + (1 if mode == "csd" else 0)
    plane_density = (1.0 - element_sparsity) * (0.5 * (0.83 if mode == "csd" else 1.0))
    # Bitmap planes: block*block/8 bytes per kept (plane, block); a plane-block
    # is kept if any bit in it is set.
    p_keep = 1.0 - (1.0 - plane_density) ** (block * block)
    plane_bytes = n_blocks * planes * p_keep * (block * block / 8.0)
    return {
        "dense_bf16": dense_bf16,
        "dense_int8": dense_int8,
        "bcsr_int8": bcsr_int8,
        "digit_planes": plane_bytes,
    }
