"""Fixed sparse matrices "compiled" for TPU — the paper's core, JAX-side.

The FPGA flow takes a fixed matrix and runs it through synthesis/place&route
once, paying the specialization cost offline.  The TPU analogue here is
:class:`FixedMatrix`: an offline compile step that

  1. quantizes the (frozen) matrix to signed ``weight_bits`` integers,
  2. decomposes it into PN or CSD digit planes (``core.bitplanes``),
  3. extracts a static block-sparse (BCSR) structure whose zero blocks are
     culled — at *trace* time, like the paper culls adders at synthesis,
  4. attaches the FPGA cost model so every instance reports the same
     area/latency/power numbers the paper's design flow would.

The matmul implementations here are the pure-jnp reference paths; the Pallas
kernels in ``repro.kernels`` consume the same static structure.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitplanes as bp
from repro.core import costmodel

__all__ = ["BlockSparse", "FixedMatrix", "random_sparse_matrix"]


def random_sparse_matrix(
    rows: int,
    cols: int,
    element_sparsity: float,
    rng: np.random.Generator,
    weight_bits: int | None = None,
    dtype=np.float32,
) -> np.ndarray:
    """Random fixed matrix with the paper's initialization scheme.

    Integer mode ("weights are sampled from a uniform distribution of all
    possible values for the given bit-width", Sec. IV) when ``weight_bits``
    is given; float uniform(-1, 1) otherwise.  Elements are then zeroed
    until the requested element sparsity is met.
    """
    if weight_bits is not None:
        lo, hi = -(1 << (weight_bits - 1)), (1 << (weight_bits - 1))
        m = rng.integers(lo, hi, size=(rows, cols)).astype(np.float64)
    else:
        m = rng.uniform(-1.0, 1.0, size=(rows, cols))
    mask = rng.random((rows, cols)) >= element_sparsity
    return (m * mask).astype(dtype)


@dataclasses.dataclass
class BlockSparse:
    """Static BCSR: block mask decided offline, data gathered per-nnz-block.

    The block mask is a *Python-level* constant: kernels and reference paths
    iterate only the nonzero blocks, so zero blocks cost nothing at runtime —
    the trace-time analogue of the paper's constant propagation.
    """

    shape: tuple[int, int]
    block: int
    block_rows: np.ndarray        # (n_nnz,) int32 — block row index
    block_cols: np.ndarray        # (n_nnz,) int32 — block col index
    data: jnp.ndarray             # (n_nnz, block, block)
    mask: np.ndarray              # (nbr, nbc) bool

    @classmethod
    def from_dense(cls, dense: np.ndarray, block: int = 128) -> "BlockSparse":
        r, c = dense.shape
        nbr, nbc = math.ceil(r / block), math.ceil(c / block)
        padded = np.zeros((nbr * block, nbc * block), dtype=dense.dtype)
        padded[:r, :c] = dense
        tiles = padded.reshape(nbr, block, nbc, block).transpose(0, 2, 1, 3)
        mask = np.abs(tiles).sum(axis=(2, 3)) != 0
        br, bc = np.nonzero(mask)
        data = jnp.asarray(tiles[br, bc])  # (n_nnz, block, block)
        return cls(shape=(r, c), block=block, block_rows=br.astype(np.int32),
                   block_cols=bc.astype(np.int32), data=data, mask=mask)

    @property
    def n_blocks_total(self) -> int:
        return int(self.mask.size)

    @property
    def n_blocks_nnz(self) -> int:
        return int(self.mask.sum())

    @property
    def density(self) -> float:
        return self.n_blocks_nnz / max(self.n_blocks_total, 1)

    def to_dense(self) -> np.ndarray:
        nbr, nbc = self.mask.shape
        out = np.zeros((nbr * self.block, nbc * self.block),
                       dtype=np.asarray(self.data).dtype)
        data = np.asarray(self.data)
        for i, (br, bc) in enumerate(zip(self.block_rows, self.block_cols)):
            out[br * self.block:(br + 1) * self.block,
                bc * self.block:(bc + 1) * self.block] = data[i]
        return out[: self.shape[0], : self.shape[1]]

    def matmul_ref(self, x: jnp.ndarray) -> jnp.ndarray:
        """Pure-jnp blocked ``x @ M`` over nonzero blocks only.

        x: (..., rows) -> (..., cols).  The Python loop is over the *static*
        nonzero-block list, so XLA sees a fixed unrolled program — zero
        blocks are culled exactly like the paper's degenerate adders.
        """
        r, c = self.shape
        nbr, nbc = self.mask.shape
        xpad = jnp.zeros(x.shape[:-1] + (nbr * self.block,), x.dtype
                         ).at[..., :r].set(x)
        out = [None] * nbc
        for i in range(len(self.block_rows)):
            br, bc = int(self.block_rows[i]), int(self.block_cols[i])
            xs = xpad[..., br * self.block:(br + 1) * self.block]
            contrib = xs @ self.data[i].astype(x.dtype)
            out[bc] = contrib if out[bc] is None else out[bc] + contrib
        zeros = jnp.zeros(x.shape[:-1] + (self.block,), x.dtype)
        cols = [o if o is not None else zeros for o in out]
        return jnp.concatenate(cols, axis=-1)[..., :c]


@dataclasses.dataclass
class FixedMatrix:
    """A frozen matrix compiled for fast fixed-structure multiplication.

    ``y = x @ dense`` is reproduced three ways, all sharing one offline
    compile: exact integer digit-plane math (mirrors the FPGA bit-serial
    semantics), dequantized block-sparse float math, and — via
    ``repro.kernels`` — Pallas TPU kernels over the same static structure.
    """

    shape: tuple[int, int]
    weight_bits: int
    mode: Literal["pn", "csd"]
    scale: float                      # dequant scale: dense ~ q * scale
    planes: bp.DigitPlanes
    blocks: BlockSparse
    q: jnp.ndarray                    # (rows, cols) int8 quantized weights
    element_sparsity: float

    # -- compile ------------------------------------------------------------
    @classmethod
    def compile(
        cls,
        dense: np.ndarray,
        weight_bits: int = 8,
        mode: Literal["pn", "csd"] = "csd",
        block: int = 128,
        rng: np.random.Generator | None = None,
    ) -> "FixedMatrix":
        dense = np.asarray(dense, dtype=np.float64)
        qmax = (1 << (weight_bits - 1)) - 1
        amax = np.abs(dense).max()
        scale = (amax / qmax) if amax > 0 else 1.0
        q = np.clip(np.round(dense / scale), -qmax - 1, qmax).astype(np.int64)
        planes = bp.decompose(q, weight_bits, mode=mode, rng=rng)
        blocks = BlockSparse.from_dense(q.astype(np.float32) * scale, block)
        sparsity = 1.0 - (np.count_nonzero(q) / q.size)
        return cls(shape=dense.shape, weight_bits=weight_bits, mode=mode,
                   scale=float(scale), planes=planes, blocks=blocks,
                   q=jnp.asarray(q, dtype=jnp.int8),
                   element_sparsity=float(sparsity))

    # -- downstream lowering --------------------------------------------------
    def plan(self):
        """The shared :class:`repro.plan.ExecutionPlan` lowering of this
        matrix (cached per instance; import deferred to avoid a cycle)."""
        from repro.plan import plan_for
        return plan_for(self)

    # -- cost reporting -------------------------------------------------------
    @property
    def ones(self) -> int:
        return self.planes.ones

    def fpga_cost(self, input_bits: int = 8) -> costmodel.FPGADesignPoint:
        return costmodel.design_point(
            rows=self.shape[0], cols=self.shape[1],
            element_sparsity=self.element_sparsity,
            weight_bits=self.weight_bits, input_bits=input_bits,
            mode=self.mode, ones=self.ones)

    # -- math ----------------------------------------------------------------
    def matvec_int_exact(self, a: jnp.ndarray) -> jnp.ndarray:
        """Exact ``a @ q`` through shifted digit-plane products (int32).

        Mirrors the FPGA dataflow: one single-bit dot product per plane,
        shift-combined, PN subtracted.  ``a``: (..., rows) integer.
        """
        a = a.astype(jnp.int32)
        pos = jnp.asarray(self.planes.pos.astype(np.int8))
        neg = jnp.asarray(self.planes.neg.astype(np.int8))
        out = jnp.zeros(a.shape[:-1] + (self.shape[1],), jnp.int32)
        for b in range(self.planes.width):
            pterm = a @ pos[b].astype(jnp.int32)
            nterm = a @ neg[b].astype(jnp.int32)
            out = out + ((pterm - nterm) << b)
        return out

    def matvec_int_dense_ref(self, a: jnp.ndarray) -> jnp.ndarray:
        return a.astype(jnp.int32) @ self.q.astype(jnp.int32)

    def matmul(self, x: jnp.ndarray) -> jnp.ndarray:
        """Dequantized float path over the culled block structure."""
        return self.blocks.matmul_ref(x)

    def dense_f32(self) -> jnp.ndarray:
        return self.q.astype(jnp.float32) * self.scale
