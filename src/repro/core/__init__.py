"""Core library: the paper's contribution as composable JAX modules.

- ``csd`` / ``bitplanes``: digit recoding and plane decomposition (Secs III/V)
- ``spatial``: register-level emulator of the bit-serial design (oracle)
- ``costmodel`` / ``baselines``: FPGA + GPU/SIGMA analytic models (Secs IV-VII)
- ``sparse``: FixedMatrix — offline-compiled fixed sparse matrices for TPU
- ``esn`` / ``ridge``: reservoir computing on top of FixedMatrix (Sec II)
"""

from repro.core.bitplanes import DigitPlanes, decompose, pn_split  # noqa: F401
from repro.core.costmodel import design_point, expected_ones  # noqa: F401
from repro.core.csd import convert_to_csd, csd_transform  # noqa: F401
from repro.core.esn import ESNConfig, init_esn, run_reservoir  # noqa: F401
from repro.core.sparse import BlockSparse, FixedMatrix  # noqa: F401
