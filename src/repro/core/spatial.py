"""Register-level functional emulation of the paper's bit-serial multiplier.

This module is the *fidelity oracle* for the reproduction: it simulates the
spatial design of Section III clock-by-clock —

  leaf ANDs -> per-plane bit-serial adder trees (one register per level)
  -> MSb-first combining chain (DFF for the MSb, then one bit-serial adder
     per remaining plane; chain position supplies the power-of-two weighting)
  -> final bit-serial subtractor for the PN split (carry seeded to 1).

All state elements (adder carries and output registers) are explicit, so the
emulator demonstrates that the architecture computes the exact integer gemv
and lets tests cross-check the latency bookkeeping of Eq. 5:

    Latency = BW_i + BW_w + log2(R) + 2           (paper Eq. 5)

The emulator is vectorized over matrix columns and digit planes with NumPy;
only the clock loop is Python.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.bitplanes import DigitPlanes, decompose

__all__ = ["SpatialResult", "pipeline_delay", "simulate_gemv", "eq5_latency"]


def eq5_latency(input_bits: int, weight_bits: int, rows: int) -> int:
    """Paper Eq. 5: BW_i + BW_w + log2(R) + 2 cycles."""
    return input_bits + weight_bits + int(math.ceil(math.log2(rows))) + 2


def pipeline_delay(tree_depth: int, plane_width: int) -> int:
    """Registers between the first input bit and the first output bit.

    One register per tree level, one per combining-chain stage (the MSb DFF
    plus W-1 adders = W stages), one for the PN subtractor.
    """
    return tree_depth + plane_width + 1


@dataclasses.dataclass(frozen=True)
class SpatialResult:
    output: np.ndarray        # (C,) int64 — the exact gemv result a^T V
    cycles_simulated: int     # clock cycles run to stream the full result out
    delay: int                # pipeline registers before the first output bit
    eq5: int                  # the paper's latency model for this instance
    ones: int                 # set bits across digit planes (hardware cost)


class _BitSerialAdder:
    """A rank of bit-serial adders, vectorized over an arbitrary shape."""

    def __init__(self, shape: tuple[int, ...], subtract: bool = False):
        self.subtract = subtract
        # "a bit-serial subtractor ... initializing the carry bit to 1, and
        #  adding a NOT gate between b's register and the full adder"
        self.carry = (np.ones if subtract else np.zeros)(shape, dtype=np.uint8)
        self.out = np.zeros(shape, dtype=np.uint8)

    def clock(self, a: np.ndarray, b: np.ndarray) -> None:
        if self.subtract:
            b = 1 - b
        s = a ^ b ^ self.carry
        self.carry = (a & b) | (a & self.carry) | (b & self.carry)
        self.out = s.astype(np.uint8)


def _input_bit(a: np.ndarray, t: int, input_bits: int) -> np.ndarray:
    """Two's-complement bit t of each input, sign-extended past BW_i.

    "To ensure signed inputs produce the correct sign bit, we sign extend the
    input a from the shift register until the computation has finished."
    """
    tt = min(t, input_bits - 1)
    return ((a.astype(np.int64) >> tt) & 1).astype(np.uint8)


class _PlaneStack:
    """Adder trees + MSb-first combining chain for one sign (P or N) stack."""

    def __init__(self, planes: np.ndarray):
        # planes: (W, R, C) uint8
        w, r, c = planes.shape
        self.width, self.rows, self.cols = w, r, c
        self.depth = max(1, int(math.ceil(math.log2(max(r, 2)))))
        self.rows_pad = 1 << self.depth
        pad = self.rows_pad - r
        self.planes = planes
        if pad:
            self.planes = np.concatenate(
                [planes, np.zeros((w, pad, c), dtype=np.uint8)], axis=1)
        # Tree level l halves the node count; level 0 consumes the leaf ANDs.
        self.tree = [
            _BitSerialAdder((w, self.rows_pad >> (l + 1), c))
            for l in range(self.depth)
        ]
        # Combining chain: stage 0 is the MSb DFF ("fed into a bit-serial
        # adder along with 0, which becomes a D flip-flop"), stages 1..W-1
        # add successively less-significant planes.  Chain position provides
        # the 2**b weighting — no explicit delay lines are needed.
        self.chain = [_BitSerialAdder((c,)) for _ in range(w)]

    def clock(self, abit: np.ndarray) -> np.ndarray:
        """Advance one cycle; returns the chain's registered output stream."""
        # Leaf ANDs: "because we are multiplying single bits, we can realize
        # the multiplication with a simple AND gate".  With the weight bit
        # fixed this is the constant propagation the paper culls in hardware;
        # the emulator keeps the gate to model the un-minimized dataflow.
        leaves = abit[None, :, None] & self.planes  # (W, Rp, C)

        # Synchronous update: every register consumes last cycle's outputs.
        tree_prev = [lvl.out.copy() for lvl in self.tree]
        chain_prev = [st.out.copy() for st in self.chain]

        x = leaves
        for l, lvl in enumerate(self.tree):
            lvl.clock(x[:, 0::2, :], x[:, 1::2, :])
            x = tree_prev[l]

        roots = tree_prev[-1][:, 0, :]  # (W, C) previous-cycle tree roots

        self.chain[0].clock(roots[self.width - 1],
                            np.zeros_like(roots[self.width - 1]))
        for k in range(1, self.width):
            self.chain[k].clock(chain_prev[k - 1], roots[self.width - 1 - k])
        return self.chain[-1].out


def simulate_gemv(
    matrix: np.ndarray,
    a: np.ndarray,
    input_bits: int,
    weight_bits: int,
    mode: str = "pn",
    rng: np.random.Generator | None = None,
    planes: DigitPlanes | None = None,
) -> SpatialResult:
    """Clock-level simulation of ``o = a^T V`` on the spatial architecture.

    Args:
        matrix: (R, C) signed integer weight matrix (the fixed reservoir V).
        a: (R,) signed integer input vector, |a| < 2**(input_bits-1).
        input_bits: streamed input precision BW_i.
        weight_bits: source weight precision BW_w.
        mode: "pn" or "csd" digit decomposition.
        rng: coin-flip source for CSD.
        planes: optionally a precompiled :class:`DigitPlanes` (skips decompose).

    Returns:
        :class:`SpatialResult` with the exact integer output and cycle counts.
    """
    matrix = np.asarray(matrix)
    a = np.asarray(a)
    if planes is None:
        planes = decompose(matrix, weight_bits, mode=mode, rng=rng)
    r, c = planes.shape

    pstack = _PlaneStack(planes.pos)
    nstack = _PlaneStack(planes.neg)
    sub = _BitSerialAdder((c,), subtract=True)

    depth = pstack.depth
    width = pstack.width
    # Structural latency (registers input->output); reported for bookkeeping.
    delay = pipeline_delay(depth, width)
    # Stream-value reconstruction shift: every tree level multiplies the
    # output stream's value by 2; the combining-chain registers are absorbed
    # into the 2**j plane weighting and the subtractor is read same-cycle in
    # this model, so the net left-shift of the captured stream is `depth`.
    shift = depth
    # Full-precision result width; the output stream is sign-extended past it.
    result_width = input_bits + width + depth + 2
    total = delay + result_width

    # Zero-pad the input vector to the padded leaf count.
    a_pad = np.zeros(pstack.rows_pad, dtype=np.int64)
    a_pad[:r] = a.astype(np.int64)

    acc = [0] * c  # arbitrary-precision two's-complement accumulation
    for t in range(total):
        abit = _input_bit(a_pad, t, input_bits)
        p_out = pstack.clock(abit)
        n_out = nstack.clock(abit)
        sub.clock(p_out, n_out)
        bits = sub.out
        for j in range(c):
            acc[j] |= int(bits[j]) << t

    window = total
    vals = np.empty(c, dtype=np.int64)
    for j in range(c):
        v = acc[j] & ((1 << window) - 1)
        if v >> (window - 1):
            v -= 1 << window
        vals[j] = v >> shift

    return SpatialResult(
        output=vals,
        cycles_simulated=total,
        delay=delay,
        eq5=eq5_latency(input_bits, weight_bits, r),
        ones=planes.ones,
    )
