"""Echo State Networks (reservoir computing) — paper Section II, in JAX.

    x(n) = (1 - leak) * x(n-1) + leak * f(W_in u(n) + W x(n-1))      (Eq. 1)
    y(n) = W_out x(n)                                                 (Eq. 2)

W and W_in are random, sparse and *fixed*; only W_out is trained (ridge).
The recurrent multiply ``W x`` is the primitive the whole paper accelerates;
here it runs through :class:`repro.core.sparse.FixedMatrix`, so the same
offline-compiled structure backs the float reference path, the exact-integer
digit-plane path (paper [16]-style integer ESN), and the Pallas kernels.

Rollouts dispatch to the fused batched engine in :mod:`repro.serve.engine`
by default; pass ``engine="scan"`` for the legacy per-step scan baseline.

Reservoir construction follows the standard echo-state heuristics the paper
cites: Bernoulli element sparsity ([5] uses 75%, [10] recommends >80%),
spectral-radius rescaling below 1, and uniform input weights.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ridge
from repro.core.sparse import FixedMatrix, random_sparse_matrix

__all__ = ["ESNConfig", "ESNParams", "init_esn", "run_reservoir",
           "run_readout", "fit_readout", "predict", "nrmse"]


@dataclasses.dataclass(frozen=True)
class ESNConfig:
    reservoir_dim: int = 800            # [5]'s baseline reservoir: dim 800
    input_dim: int = 1
    output_dim: int = 1
    element_sparsity: float = 0.75      # [5]: "75% of the elements being 0"
    spectral_radius: float = 0.9
    input_scale: float = 0.5
    leak: float = 1.0
    weight_bits: int = 8                # paper: 8-bit signed weights
    state_bits: int = 8                 # [16]: 3-4 bits lose no accuracy
    mode: Literal["fp32", "int8-pn", "int8-csd"] = "fp32"
    block: int = 128
    seed: int = 0

    @property
    def digit_mode(self) -> str:
        return "csd" if self.mode == "int8-csd" else "pn"


@dataclasses.dataclass
class ESNParams:
    w: FixedMatrix                      # reservoir matrix, compiled offline
    w_in: jnp.ndarray                   # (input_dim, reservoir_dim)
    w_out: jnp.ndarray | None           # (reservoir_dim, output_dim)
    config: ESNConfig


def _spectral_rescale(m: np.ndarray, target: float) -> np.ndarray:
    """Scale so the spectral radius equals ``target``.

    Random reservoirs have complex dominant eigenvalues (circular law), so a
    real power iteration underestimates rho badly; use ARPACK (complex) with
    a dense-eig fallback for small matrices.
    """
    n = m.shape[0]
    rho = 0.0
    try:
        import scipy.sparse.linalg as sla
        vals = sla.eigs(m.astype(np.float64), k=1, which="LM",
                        return_eigenvectors=False, maxiter=n * 20)
        rho = float(np.abs(vals[0]))
    except Exception:
        pass
    if not np.isfinite(rho) or rho <= 0:
        rho = float(np.abs(np.linalg.eigvals(m)).max())
    return m * (target / max(rho, 1e-12))


def init_esn(cfg: ESNConfig) -> ESNParams:
    rng = np.random.default_rng(cfg.seed)
    w_dense = random_sparse_matrix(cfg.reservoir_dim, cfg.reservoir_dim,
                                   cfg.element_sparsity, rng)
    w_dense = _spectral_rescale(w_dense, cfg.spectral_radius)
    w = FixedMatrix.compile(w_dense, weight_bits=cfg.weight_bits,
                            mode=cfg.digit_mode, block=cfg.block, rng=rng)
    w_in = rng.uniform(-cfg.input_scale, cfg.input_scale,
                       size=(cfg.input_dim, cfg.reservoir_dim))
    return ESNParams(w=w, w_in=jnp.asarray(w_in, jnp.float32),
                     w_out=None, config=cfg)


def _step_fp32(params: ESNParams, x: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    cfg = params.config
    pre = u @ params.w_in + params.w.matmul(x)
    nxt = jnp.tanh(pre)
    return (1.0 - cfg.leak) * x + cfg.leak * nxt


def _step_int8(params: ESNParams, x: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Integer reservoir update (paper [16]): states quantized each step.

    The recurrent product runs through the exact digit-plane path — the same
    arithmetic the bit-serial FPGA performs — then is rescaled to float for
    the activation.
    """
    cfg = params.config
    smax = (1 << (cfg.state_bits - 1)) - 1
    xq = jnp.clip(jnp.round(x * smax), -smax - 1, smax).astype(jnp.int32)
    recur = params.w.matvec_int_exact(xq).astype(jnp.float32)
    recur = recur * (params.w.scale / smax)
    pre = u @ params.w_in + recur
    nxt = jnp.tanh(pre)
    return (1.0 - cfg.leak) * x + cfg.leak * nxt


def _run_reservoir_scan(params: ESNParams, inputs: jnp.ndarray,
                        x0: jnp.ndarray | None = None) -> jnp.ndarray:
    """Legacy per-step rollout: lax.scan of one step, vmap over batch."""
    if inputs.ndim == 3:
        return jax.vmap(lambda seq: _run_reservoir_scan(params, seq, x0)
                        )(inputs)
    cfg = params.config
    step = _step_int8 if cfg.mode.startswith("int8") else _step_fp32
    if x0 is None:
        x0 = jnp.zeros((cfg.reservoir_dim,), jnp.float32)

    def body(x, u):
        nxt = step(params, x, u)
        return nxt, nxt

    _, states = jax.lax.scan(body, x0, inputs.astype(jnp.float32))
    return states


def run_reservoir(params: ESNParams, inputs: jnp.ndarray,
                  x0: jnp.ndarray | None = None,
                  engine: str = "auto") -> jnp.ndarray:
    """Roll the reservoir over ``inputs`` (T, input_dim) -> states (T, dim).

    Batched inputs (B, T, input_dim) return (B, T, dim) states.

    ``engine`` picks the rollout implementation:
      * "auto" / "xla" / "pallas" — the fused batched engine in
        :mod:`repro.serve.engine` (input projection hoisted, native batch,
        int8 per-step requantization preserved).
      * "scan" — the legacy per-step ``lax.scan`` path (benchmark
        baseline).
    """
    if engine == "scan":
        return _run_reservoir_scan(params, inputs, x0)
    if engine not in ("auto", "xla", "pallas"):
        raise ValueError(f"unknown engine {engine!r}; expected one of "
                         "'auto', 'xla', 'pallas', 'scan'")
    from repro.serve.engine import engine_for  # deferred: serve imports esn
    eng = engine_for(params) if engine == "auto" else engine_for(
        params, backend=engine)
    return eng.rollout(jnp.asarray(inputs), x0)


def run_readout(params: ESNParams, inputs: jnp.ndarray,
                x0: jnp.ndarray | None = None,
                engine: str = "auto") -> jnp.ndarray:
    """Roll the reservoir AND apply the trained readout in one fused pass.

    (T, input_dim) -> (T, output_dim) predictions (batched inputs return
    (B, T, output_dim)).  ``W_out`` is applied inside the rollout — the
    scan body on the XLA backend, the Pallas launch epilogue on the TPU
    backend — so the state trajectory is never materialized; this is the
    serving path ("serving returns predictions, not states").
    """
    if params.w_out is None:
        raise ValueError("readout not trained; call fit_readout first")
    if engine == "scan":
        return predict(params, _run_reservoir_scan(params, inputs, x0))
    if engine not in ("auto", "xla", "pallas"):
        raise ValueError(f"unknown engine {engine!r}; expected one of "
                         "'auto', 'xla', 'pallas', 'scan'")
    from repro.serve.engine import engine_for  # deferred: serve imports esn
    eng = engine_for(params) if engine == "auto" else engine_for(
        params, backend=engine)
    return eng.predictions(jnp.asarray(inputs), x0)


def fit_readout(params: ESNParams, states: jnp.ndarray, targets: jnp.ndarray,
                lam: float = 1e-6, washout: int = 0) -> ESNParams:
    """Ridge-fit ``W_out`` on (T, R) or batched (B, T, R) state trajectories.

    ``washout`` discards the initial transient of *each* sequence: for
    batched states the first ``washout`` steps are dropped per sequence
    (along the time axis) before flattening, not just from the head of the
    flattened array.
    """
    if washout:
        states = states[..., washout:, :]
        targets = targets[..., washout:, :]
    s = states.reshape(-1, states.shape[-1])
    t = targets.reshape(-1, targets.shape[-1])
    w_out = ridge.ridge_fit(s, t, lam)
    return dataclasses.replace(params, w_out=w_out)


def predict(params: ESNParams, states: jnp.ndarray) -> jnp.ndarray:
    if params.w_out is None:
        raise ValueError("readout not trained; call fit_readout first")
    return states @ params.w_out


def nrmse(pred: jnp.ndarray, target: jnp.ndarray) -> jnp.ndarray:
    err = jnp.mean((pred - target) ** 2)
    var = jnp.var(target) + 1e-12
    return jnp.sqrt(err / var)
