"""Analytic baseline models for the paper's evaluation (Section VII).

This container has no V100 and no SIGMA RTL simulator, so the comparison
baselines are implemented as physics-grounded cost models whose free
constants are calibrated once against the anchors the paper states in text:

  GPU (V100, fp16 sparse libraries):
    * "the GPU cannot break the 1 us barrier" (all configs measured)
    * dimension sweep @98% sparsity: speedup falls 86x -> 60x while the GPU
      is latency-bound (dim <= 512), levels at ~50x for dim >= 1024
    * sparsity sweep @1024: 77x @70% -> 60x @98%
    * batching: GPU scales sublinearly; crossover ~batch 16..64 for 64x64

  SIGMA (128x128 fp16 PE grid, assumed 1 GHz for int8/process parity):
    * fits-in-grid -> nanosecond regime; tiling pushes it memory-bound
    * dimension sweep @98%: 4.1x @1024 growing to ~25x @4096
    * sparsity sweep @1024: microsecond regime below ~90% sparsity, max 47x
    * batching @1024/95%: saturates at ~5.4x

Every constant is tagged ``# calibrated:`` with its anchor.  The FPGA side
of every comparison comes from :mod:`repro.core.costmodel` (not from these
tables), so the reproduction logic is: model our design from first
principles, model the baselines from published measurements, and check the
derived speedups against the paper's claims in tests/benchmarks.
"""

from __future__ import annotations

import dataclasses
import math

__all__ = ["V100Model", "SigmaModel", "gpu_latency_s", "sigma_latency_s"]


@dataclasses.dataclass(frozen=True)
class V100Model:
    """V100 sparse-gemv latency model: library floor + streaming terms."""

    hbm_bw: float = 900e9           # V100 HBM2 bandwidth, B/s
    # calibrated: 86x over a ~40 ns FPGA point at dim 64 (Fig 14)
    cusparse_floor_s: float = 3.45e-6
    # calibrated: optimized kernel [9] "comparatively spends less time
    # indexing"; ~35% lower floor reproduces the 60-77x band (Figs 14/16)
    sputnik_floor_s: float = 2.25e-6
    # floor shrinks mildly with dim as launch overheads amortize;
    # calibrated: 86x@64 -> 60x@512 latency-bound fall-off (Fig 14)
    floor_decay_per_oct: float = 0.92
    # CSR per-nonzero cost: value (2B fp16) + column index (4B) + row ptr
    # amortized + output; effective streaming efficiency ~35% of HBM peak
    # calibrated: ~50x plateau at dim >= 1024 (Fig 14: "linear scaling")
    bytes_per_nnz: float = 6.0
    stream_eff: float = 0.35

    def latency_s(self, dim: int, element_sparsity: float,
                  library: str = "cusparse", batch: int = 1) -> float:
        nnz = dim * dim * (1.0 - element_sparsity)
        floor = (self.cusparse_floor_s if library == "cusparse"
                 else self.sputnik_floor_s)
        floor *= self.floor_decay_per_oct ** math.log2(max(dim, 64) / 64)
        # batched columns reuse the fetched matrix: sublinear scaling
        # ("the latency for the GPU solution scales sublinearly with respect
        #  to batch size")
        vec_bytes = dim * 2.0 * 2.0 * batch
        mat_bytes = nnz * self.bytes_per_nnz
        stream = (mat_bytes + vec_bytes) / (self.hbm_bw * self.stream_eff)
        compute = nnz * batch * 2 / 15.7e12  # fp16 FMA throughput bound
        return max(floor, stream, compute)


@dataclasses.dataclass(frozen=True)
class SigmaModel:
    """SIGMA [20]: 128x128 PE grid, weight-stationary, Benes broadcast.

    One unified latency formula covers the paper's three SIGMA experiments:

      tiles    = ceil(nnz / PEs)                    (weight-stationary fit)
      per_tile = c_tile + c_stream*dim + c_occ*(1-es)
      latency  = base + tiles*per_tile + (batch-1)*c_batch*dim   [cycles]

    c_stream models re-streaming the input segment every tiled pass;
    c_occ models the denser weight/activation pairing at low sparsity
    ("even 90% sparsity and below is enough to push it back into the
    microsecond regime"); c_batch is the incremental activation stream per
    batched column under weight reuse.
    """

    pes: int = 128 * 128
    clock_hz: float = 1e9           # paper's int8/process-parity assumption
    # fits-in-grid latency: broadcast + log-depth reduction + pipeline
    # ("For small dimensions, SIGMA does report nanosecond-scale latency")
    base_cycles: float = 40.0
    # calibrated: 4.1x @ (1024, 98%) and ~25x @ (4096, 98%) (Figs 19-20)
    c_tile: float = 58.4
    c_stream: float = 0.0215
    # calibrated: ~47x max over the 1024 sparsity sweep (Figs 21-22)
    c_occ: float = 600.0
    # calibrated: 5.4x batching saturation @ (1024, 95%) (Fig 23)
    c_batch: float = 0.077

    def latency_s(self, dim: int, element_sparsity: float,
                  batch: int = 1) -> float:
        nnz = dim * dim * (1.0 - element_sparsity)
        # "only maps non-zero weight and activation pairs to PEs"
        if nnz <= self.pes and batch == 1:
            return (self.base_cycles + math.log2(max(dim, 2))) / self.clock_hz
        tiles = math.ceil(nnz / self.pes)
        per_tile = (self.c_tile + self.c_stream * dim
                    + self.c_occ * (1.0 - element_sparsity))
        cycles = (self.base_cycles + tiles * per_tile
                  + (batch - 1) * self.c_batch * dim)
        return cycles / self.clock_hz


_V100 = V100Model()
_SIGMA = SigmaModel()


def gpu_latency_s(dim: int, element_sparsity: float,
                  library: str = "cusparse", batch: int = 1) -> float:
    return _V100.latency_s(dim, element_sparsity, library, batch)


def sigma_latency_s(dim: int, element_sparsity: float,
                    batch: int = 1) -> float:
    return _SIGMA.latency_s(dim, element_sparsity, batch)
