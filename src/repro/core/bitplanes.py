"""Bit-plane / digit-plane decomposition of fixed integer matrices.

Section III of the paper maps a fixed matrix into per-bit-position hardware:
each bit position of the weights gets its own single-bit dot-product circuit
and the positions are combined through a chain of bit-serial adders (delay of
one cycle per position == multiply by two).  Signed weights are handled by
splitting the matrix into positive and negative unsigned parts (PN split) and
subtracting the two result streams.

The TPU analogue implemented here: ``V = sum_b 2**b * (P_b - N_b)`` where
``P_b`` / ``N_b`` are {0,1} planes.  A gemv against V becomes a sum of shifted
plane-gemvs — exactly the computation the FPGA performs in time, executed in
space on the MXU.  The number of nonzero plane entries ("ones") is the paper's
cost metric and drives both the FPGA cost model and the TPU kernels.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import numpy as np

from repro.core import csd as csd_mod

__all__ = [
    "pn_split",
    "to_bitplanes",
    "from_bitplanes",
    "DigitPlanes",
    "decompose",
]


def pn_split(matrix: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Split a signed integer matrix into unsigned ``(P, N)`` with V = P - N.

    "An easy way to implement signed weights is to separate the positive and
    negative terms of the b vector into two separate unsigned vectors, and
    simply subtract the two resultant streams." (paper, Sec. III-c)
    """
    m = np.asarray(matrix)
    return np.where(m > 0, m, 0).astype(np.int64), np.where(m < 0, -m, 0).astype(np.int64)


def to_bitplanes(matrix: np.ndarray, width: int) -> np.ndarray:
    """Unsigned integer matrix -> uint8 bit planes of shape ``(width, *shape)``.

    Plane ``b`` holds bit ``b`` (LSb = plane 0), so
    ``matrix == sum_b 2**b * planes[b]``.
    """
    m = np.asarray(matrix).astype(np.int64)
    if m.size and (m.min() < 0 or m.max() >= (1 << width)):
        raise ValueError("matrix must be unsigned and fit in `width` bits")
    shifts = np.arange(width, dtype=np.int64).reshape((width,) + (1,) * m.ndim)
    return ((m[None, ...] >> shifts) & 1).astype(np.uint8)


def from_bitplanes(planes: np.ndarray) -> np.ndarray:
    """Inverse of :func:`to_bitplanes` (planes may be signed digit planes)."""
    width = planes.shape[0]
    weights = (1 << np.arange(width, dtype=np.int64)).reshape(
        (width,) + (1,) * (planes.ndim - 1))
    return (planes.astype(np.int64) * weights).sum(axis=0)


@dataclasses.dataclass(frozen=True)
class DigitPlanes:
    """A fixed signed matrix compiled to unsigned P/N digit planes.

    Attributes:
        pos: uint8 planes ``(width, rows, cols)`` for the positive part.
        neg: uint8 planes ``(width, rows, cols)`` for the negative part.
        mode: "pn" (plain positive/negative split) or "csd".
        source_bits: bit width of the original signed weights.
    """

    pos: np.ndarray
    neg: np.ndarray
    mode: Literal["pn", "csd"]
    source_bits: int

    @property
    def width(self) -> int:
        return self.pos.shape[0]

    @property
    def shape(self) -> tuple[int, int]:
        return self.pos.shape[1:]

    @property
    def ones(self) -> int:
        """Total set bits across both plane stacks — the paper's cost metric."""
        return int(self.pos.sum() + self.neg.sum())

    def to_dense(self) -> np.ndarray:
        return from_bitplanes(self.pos) - from_bitplanes(self.neg)

    def ones_per_plane(self) -> np.ndarray:
        """Set bits per (sign, plane); shape (2, width)."""
        axes = tuple(range(1, self.pos.ndim))
        return np.stack([self.pos.sum(axis=axes), self.neg.sum(axis=axes)])


def decompose(
    matrix: np.ndarray,
    weight_bits: int,
    mode: Literal["pn", "csd"] = "pn",
    rng: np.random.Generator | None = None,
) -> DigitPlanes:
    """Compile a signed integer matrix into digit planes.

    This is the software analogue of the paper's "design flow [that] takes the
    content of the matrices and compiles it to a physical design": the matrix
    is fixed, so all decomposition cost is paid once, offline.

    Args:
        matrix: signed integers in [-(2**(weight_bits-1)), 2**(weight_bits-1)).
        weight_bits: source precision (the paper uses 8-bit signed).
        mode: "pn" splits positive/negative magnitudes into plain bit planes;
            "csd" additionally recodes each magnitude into canonical signed
            digits (Sec. V) — CSD digits of either sign land in the matching
            P/N stack ("positive elements that result from CSD remain in the
            original matrix, and negative elements are transferred to the
            opposite weight matrix").
        rng: coin-flip source for CSD length-2 chains.
    """
    m = np.asarray(matrix).astype(np.int64)
    lo, hi = -(1 << (weight_bits - 1)), (1 << (weight_bits - 1))
    if m.size and (m.min() < lo or m.max() >= hi):
        raise ValueError(f"weights out of signed {weight_bits}-bit range")

    p_int, n_int = pn_split(m)
    mag_bits = weight_bits - 1 if weight_bits > 1 else 1
    # |v| can reach 2**(weight_bits-1) for the most negative value.
    if n_int.size and n_int.max() > (1 << mag_bits) - 1:
        mag_bits += 1

    if mode == "pn":
        pos = to_bitplanes(p_int, mag_bits)
        neg = to_bitplanes(n_int, mag_bits)
        return DigitPlanes(pos=pos, neg=neg, mode="pn", source_bits=weight_bits)

    if mode != "csd":
        raise ValueError(f"unknown mode {mode!r}")

    if rng is None:
        rng = np.random.default_rng(0)
    # CSD on both unsigned magnitude matrices; width grows by one digit.
    dig_p = csd_mod.csd_transform(p_int, mag_bits, rng)  # (*shape, mag_bits+1)
    dig_n = csd_mod.csd_transform(n_int, mag_bits, rng)
    # Digits are LSb-first on the last axis; move planes to axis 0.
    dig_p = np.moveaxis(dig_p, -1, 0)
    dig_n = np.moveaxis(dig_n, -1, 0)
    # P stack: +digits of P and -digits of N.  N stack: the converse.
    pos = ((dig_p > 0) | (dig_n < 0)).astype(np.uint8)
    neg = ((dig_p < 0) | (dig_n > 0)).astype(np.uint8)
    return DigitPlanes(pos=pos, neg=neg, mode="csd", source_bits=weight_bits)
