"""Ridge-regression readout training — the only *trained* piece of an ESN.

"W_out is trained via linear regression ... which completely eliminates the
need for error backpropagation" (paper Sec. II).  The solver accumulates the
Gram statistics ``X^T X`` and ``X^T Y`` so it streams over arbitrarily long
state trajectories (and sums across data-parallel shards with one psum),
then solves the regularized normal equations once.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "gram_accumulate",
    "ridge_solve",
    "ridge_fit",
    "ridge_fit_sharded",
]


def gram_accumulate(x: jnp.ndarray, y: jnp.ndarray,
                    carry: tuple[jnp.ndarray, jnp.ndarray] | None = None
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Accumulate (X^T X, X^T Y) in float32 from a chunk of rows."""
    x = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    y = y.reshape(-1, y.shape[-1]).astype(jnp.float32)
    xtx = x.T @ x
    xty = x.T @ y
    if carry is not None:
        xtx = xtx + carry[0]
        xty = xty + carry[1]
    return xtx, xty


@partial(jax.jit, static_argnames=())
def ridge_solve(xtx: jnp.ndarray, xty: jnp.ndarray, lam: float | jnp.ndarray
                ) -> jnp.ndarray:
    """Solve (X^T X + lam I) W = X^T Y.

    Uses a symmetric eigendecomposition rather than Cholesky: reservoir Gram
    matrices are often near-singular (strongly correlated states) and f32
    Cholesky NaNs where eigh merely clamps the tiny eigenvalues, which the
    ridge term then regularizes.
    """
    evals, evecs = jnp.linalg.eigh(xtx)
    evals = jnp.maximum(evals, 0.0)  # clamp negative round-off
    inv = 1.0 / (evals + lam)
    return evecs @ (inv[:, None] * (evecs.T @ xty))


def ridge_fit(x: jnp.ndarray, y: jnp.ndarray, lam: float = 1e-6) -> jnp.ndarray:
    """One-shot ridge fit: returns W_out with ``y ~ x @ W_out``.

    The Gram statistics accumulate on-device (f32, distributed-friendly);
    the final (d x d) solve runs on host in float64 — reservoir Grams are
    ill-conditioned enough that f32 solves visibly hurt readout quality,
    and the solve is a one-time O(d^3) epilogue.
    """
    import numpy as np

    xtx, xty = gram_accumulate(x, y)
    a = np.asarray(xtx, dtype=np.float64)
    b = np.asarray(xty, dtype=np.float64)
    w = np.linalg.solve(a + lam * np.eye(a.shape[0]), b)
    return jnp.asarray(w, dtype=jnp.float32)


def ridge_fit_sharded(x: jnp.ndarray, y: jnp.ndarray, lam: float,
                      axis_name: str) -> jnp.ndarray:
    """Ridge fit inside shard_map/pmap: rows sharded over ``axis_name``.

    Each shard accumulates its local Gram block; one psum of the
    (d x d) / (d x k) statistics replaces gathering the raw trajectories —
    the communication volume is independent of sequence length.
    """
    xtx, xty = gram_accumulate(x, y)
    xtx = jax.lax.psum(xtx, axis_name)
    xty = jax.lax.psum(xty, axis_name)
    return ridge_solve(xtx, xty, lam)
