"""DeepSeek-V2 236B: MLA (kv_lora=512) + 2 shared / 160 routed top-6 MoE
[arXiv:2405.04434]."""
from repro.configs.base import ModelConfig, MoEConfig, MLAConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=192,
    d_ff=0, vocab_size=102400, block_pattern=("mla",), tie_embeddings=False,
    moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536, n_shared=2),
    mla=MLAConfig(kv_lora=512, q_lora=1536, rope_dim=64, nope_dim=128,
                  v_dim=128),
    microbatches=16,
))
