"""Architecture configs: importing this package populates the registry."""

from repro.configs import (deepseek_v2_236b, gemma_2b, internvl2_76b,  # noqa
                           mistral_nemo_12b, olmoe_1b_7b, qwen3_32b,
                           recurrentgemma_2b, stablelm_1_6b, whisper_base,
                           xlstm_350m)
from repro.configs.base import (SHAPES, ModelConfig, ShapeSpec, get_config,  # noqa
                                list_archs, supports_shape)


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Shrink a config to CPU-smoke size, preserving its structural family.

    Same block pattern, same attention variant (GQA ratio, MLA, qk-norm),
    same routing (top-k, shared experts) — just tiny dims.
    """
    kw = dict(
        n_layers=len(cfg.block_pattern) * 2,   # two scanned groups
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, 4 * cfg.n_kv_heads // max(cfg.n_heads, 1)),
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=512,
        window=16 if cfg.window else None,
        lru_dim=64 if cfg.lru_dim else None,
        remat="none",
    )
    if cfg.moe is not None:
        # capacity_factor covers every assignment at smoke scale so the
        # prefill and decode paths route identically (capacity drops are a
        # train-time behaviour, exercised separately in test_moe).
        kw["moe"] = cfg.moe.__class__(
            n_experts=8, top_k=min(cfg.moe.top_k, 2), d_expert=32,
            n_shared=min(cfg.moe.n_shared, 1), capacity_factor=8.0)
    if cfg.mla is not None:
        kw["mla"] = cfg.mla.__class__(kv_lora=32, q_lora=48, rope_dim=8,
                                      nope_dim=16, v_dim=16)
        kw["head_dim"] = 24  # nope + rope
    if cfg.encoder is not None:
        kw["encoder"] = cfg.encoder.__class__(n_layers=2, seq_len=12)
    return cfg.replace(**kw)
