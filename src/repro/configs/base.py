"""Unified model configuration + registry for the assigned architectures.

Every architecture in the assignment is expressible as a ``ModelConfig``:
a stack of repeated *block groups* (so heterogeneous patterns like
RecurrentGemma's recurrent/recurrent/local-attention triple still scan), a
family tag, and optional MoE / MLA / recurrent sub-configs.

``reduced()`` shrinks any config to a CPU-smokeable size while preserving
its structural family (same block pattern, same attention variant, same
routing), per the assignment brief.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["MoEConfig", "MLAConfig", "EncoderConfig", "ModelConfig",
           "ShapeSpec", "SHAPES", "register", "get_config", "list_archs"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    n_shared: int = 0             # always-on shared experts
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    aux_loss: float = 1e-2


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention dims."""
    kv_lora: int = 512
    q_lora: int = 1536
    rope_dim: int = 64
    nope_dim: int = 128
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec (whisper) / frontend token count (vlm)."""
    n_layers: int = 6
    seq_len: int = 1500           # whisper: 30 s audio -> 1500 frames
    is_causal: bool = False


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | audio | vlm | esn
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # block pattern repeated over the depth; len(pattern) divides into
    # n_layers with an optional remainder tail.
    block_pattern: tuple = ("attn",)

    # attention details
    qk_norm: bool = False
    window: Optional[int] = None          # local attention window
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0            # stablelm: partial rotary
    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    mlp_act: str = "silu"                 # silu | geglu | gelu
    logit_softcap: Optional[float] = None

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[str] = None        # audio | vision (stub embeddings)

    # recurrent dims
    lru_dim: Optional[int] = None         # RG-LRU width
    conv_width: int = 4                   # temporal conv in recurrent blocks

    tie_embeddings: bool = True
    dtype: str = "bfloat16"

    # training-time structure
    remat: str = "full"                   # none | dots | full
    scan_layers: bool = True
    # gradient-accumulation microbatches for train_4k (memory fit); chosen
    # per arch so every train cell's activations fit 16 GB/device HBM.
    microbatches: int = 1
    # tensor-parallel mapping: when False the 'model' mesh axis is used as
    # additional FSDP instead of TP (better for collective-bound dense
    # models that fit without TP) — a §Perf lever, default paper-baseline on.
    use_tp: bool = True

    # paper-technique integration: frozen-weight serving specialization
    # (int8 symmetric quantization of all big weights; the paper's "matrix
    # fixed for the lifetime of the computation" applied to LM serving)
    frozen_sparse_serving: bool = False
    # FSDP-shard expert weights over the data axes (baseline True; False
    # keeps experts EP-resident — kills per-microbatch expert gathers)
    expert_fsdp: bool = True
    # AdamW m/v dtype ("float32" | "bfloat16")
    opt_dtype: str = "float32"
    # FSDP-shard weights at serving time (baseline True = same sharding as
    # train; False keeps weights TP-resident — no per-token weight gathers)
    serving_fsdp: bool = True
    # global FSDP toggle (False = replicate weights over the data axes;
    # right for small models where FSDP'd contractions force activation
    # all-reduces)
    fsdp: bool = True

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def tail_pattern(self) -> tuple:
        rem = self.n_layers % len(self.block_pattern)
        return self.block_pattern[:rem]

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: dict = {}


def register(cfg_or_fn):
    """Register a ModelConfig (or a zero-arg factory) under its name."""
    cfg = cfg_or_fn() if callable(cfg_or_fn) else cfg_or_fn
    _REGISTRY[cfg.name] = cfg
    return cfg_or_fn


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (triggers per-arch module imports)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)


def supports_shape(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether a (arch x shape) cell runs; reasons documented in DESIGN.md."""
    sub_quadratic = all(b in ("rglru", "local", "mlstm", "slstm")
                        for b in cfg.block_pattern)
    if shape.name == "long_500k" and not sub_quadratic:
        return False, ("SKIP: pure full-attention arch; a 524288-token dense "
                       "KV cache is not sub-quadratic (DESIGN.md §Shapes)")
    return True, ""
