"""xLSTM-350M: mLSTM matrix-memory blocks with interleaved sLSTM
[arXiv:2405.04517]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, head_dim=256,
    d_ff=0, vocab_size=50304,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"), tie_embeddings=True,
    microbatches=4,
))
