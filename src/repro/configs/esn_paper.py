"""The paper's own workload: fixed sparse reservoirs (Sec. II/VI).

Not an LM config — these drive the ESN examples and benchmark harness.
Dims/sparsities follow Sec. VI (512 and 1024, 40-98% element sparsity,
8-bit signed weights).
"""
from repro.core.esn import ESNConfig

PAPER_BASELINE = ESNConfig(reservoir_dim=800, element_sparsity=0.75)  # [5]
LARGE_512 = ESNConfig(reservoir_dim=512, element_sparsity=0.90,
                      mode="int8-csd")
LARGE_1024 = ESNConfig(reservoir_dim=1024, element_sparsity=0.95,
                       mode="int8-csd")
