"""Whisper-base: enc-dec, conv frontend stubbed to precomputed frame
embeddings [arXiv:2212.04356]."""
from repro.configs.base import EncoderConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=51865, mlp_act="gelu", norm="layernorm",
    encoder=EncoderConfig(n_layers=6, seq_len=1500), frontend="audio",
    tie_embeddings=True,
    microbatches=8,
))
