"""InternVL2-76B: InternViT frontend (stubbed patch embeddings) + 80-layer
LLaMA-family backbone [arXiv:2404.16821]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256, rope_theta=5e5, frontend="vision",
    tie_embeddings=False,
    microbatches=32,
))
