"""StableLM-2-1.6B: dense MHA, LayerNorm, 25% partial rotary
[hf:stabilityai/stablelm-2-1_6b]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=5632, vocab_size=100352, norm="layernorm", rope_fraction=0.25,
    tie_embeddings=False,
    microbatches=2,
))
