"""RecurrentGemma-2B (Griffin): RG-LRU + local attention, 2:1 pattern
[arXiv:2402.19427]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000, mlp_act="geglu",
    block_pattern=("rglru", "rglru", "local"), window=2048, lru_dim=2560,
    logit_softcap=30.0, tie_embeddings=True,
    microbatches=4,
))
