"""OLMoE-1B-7B: 64-expert top-8 MoE [arXiv:2409.02060]."""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=0, vocab_size=50304, qk_norm=True, tie_embeddings=False,
    moe=MoEConfig(n_experts=64, top_k=8, d_expert=1024),
    microbatches=2,
))
