"""Sharded multi-device serving: batch-axis data parallelism.

The reservoir matrix is fixed and replicated (the paper's core premise),
so scaling serving throughput is pure batch-axis data parallelism with
zero collectives in the rollout hot loop:

- ``engine``    — :class:`ShardedReservoirEngine`: the single-device
  engine's rollout callable under ``shard_map`` over the 'data' mesh
  axis; plan artifacts and ``W_out`` replicated, batch sharded,
  bit-identical per sequence on both backends
- ``scheduler`` — :class:`ShardedContinuousBatcher` (per-shard slot
  sub-pools, least-loaded admission off one global FIFO) and
  :class:`DistributedReservoirServer` (merged + per-shard telemetry,
  elastic :meth:`~DistributedReservoirServer.shrink` on shard loss and
  :meth:`~DistributedReservoirServer.grow` under live traffic, driven
  manually or by a :class:`~repro.runtime.elastic.AutoscalePolicy`;
  fault-plan driven shard-death detection recovers through the same
  shrink path with zero request loss)
"""

from repro.dist.engine import ShardedReservoirEngine  # noqa: F401
from repro.dist.scheduler import (DistributedReservoirServer,  # noqa: F401
                                  ShardedContinuousBatcher)

__all__ = ["ShardedReservoirEngine", "ShardedContinuousBatcher",
           "DistributedReservoirServer"]
