"""Batch-axis sharded rollout engine: ``shard_map`` over the ``data`` mesh.

The paper's throughput argument scales by *replication*: the spatial
multiplier is a fixed circuit, so more traffic means stamping more copies
of the same structure, never re-synthesizing it.  The TPU analogue is
data parallelism with zero collectives in the hot loop — the
:class:`~repro.plan.ExecutionPlan` artifacts, ``w_in`` and ``w_out`` are
closure constants replicated once per device, and the batch axis is the
only thing sharded.  Each shard runs the *identical* single-device rollout
callable (:meth:`ReservoirEngine._local_rollout`) on its batch slice, so
the sharded output is bit-identical per sequence to the single-device
engine on both backends: rows never mix through the recurrence, and the
per-row arithmetic is the same compiled program either way.  (One caveat,
pinned by tests: when a shard holds a single row, XLA may lower the
recurrent matmul as a gemv whose accumulation order differs by an ulp —
size the batch to at least two rows per shard for exactness.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map

from repro.launch.mesh import make_data_mesh
from repro.parallel.sharding import (batch_spec, data_axis_names,
                                     data_axis_size)
from repro.serve.api import _UNSET
from repro.serve.engine import (DENSE_DISPATCH_DENSITY, ReservoirEngine,
                                donated_call)
from repro.serve.stats import ServeStats


class ShardedReservoirEngine(ReservoirEngine):
    """:class:`ReservoirEngine` with the batch dimension sharded on a mesh.

    Same public API (``submit`` / ``rollout`` / ``predictions`` / the
    ``run_segment`` chunk API) and the same compiled per-shard program;
    the only new behavior is batch padding up to a multiple of the shard
    count (padded rows are zero sequences riding along in otherwise-idle
    shard capacity, and never leave the engine).

    Pass a ``mesh`` (any mesh with 'data' — and optionally 'pod' — axes)
    or just ``n_shards`` to build a 1-D data mesh over the first N local
    devices.
    """

    def __init__(self, params, *, mesh=None, n_shards: int | None = None,
                 backend: str = "auto", interpret: bool = True,
                 stats: ServeStats | None = None,
                 dense_dispatch_density: float = DENSE_DISPATCH_DENSITY,
                 vmem_budget: int | None = _UNSET,
                 specialize: bool = True, tenant=None,
                 crossover: int | None = None,
                 batch_tile_max: int | None = None, schedule=None):
        self.mesh = mesh if mesh is not None else make_data_mesh(n_shards)
        assert data_axis_names(self.mesh), \
            f"mesh has no data axes: {self.mesh.axis_names}"
        self.n_shards = data_axis_size(self.mesh)
        self._batch_spec = batch_spec(self.mesh)
        self.interpret = interpret
        # kept for elastic rebuilds: shrink() must reconstruct the engine
        # with the same dispatch policy, not the default
        self.dense_dispatch_density = dense_dispatch_density
        # backend="auto" resolves through the plan autotuner in the base
        # constructor — the per-shard program IS the single-device program
        # (shard_map wraps _local_rollout), so the sharded engine inherits
        # the tuned schedule for free.
        super().__init__(params, backend=backend, interpret=interpret,
                         stats=stats,
                         dense_dispatch_density=dense_dispatch_density,
                         vmem_budget=vmem_budget, specialize=specialize,
                         tenant=tenant, crossover=crossover,
                         batch_tile_max=batch_tile_max, schedule=schedule)
        self._sharded_fns: dict = {}

    def like(self, params=None, *, mesh=None, stats=None, tenant=None):
        """A sibling engine with this one's dispatch policy.

        Elastic rebuilds (new ``mesh``, same params) and multi-tenant
        routing (new ``params``, same mesh) both need "the same engine,
        but for X" — mesh-mapped engines are built per server, not
        through the global ``engine_for`` LRU, because the mesh is part
        of their identity.  Same params carry this engine's resolved
        schedule verbatim; new params re-resolve through the tuner (a
        different matrix has its own schedule space), inheriting the
        tuned-ness rather than this matrix's tuned values."""
        same = params is None or params is self.params
        return ShardedReservoirEngine(
            self.params if params is None else params,
            mesh=self.mesh if mesh is None else mesh,
            backend=self.backend if same else self.requested_backend,
            interpret=self.interpret,
            stats=self.stats if stats is None else stats,
            dense_dispatch_density=self.dense_dispatch_density,
            vmem_budget=self.vmem_budget if same else _UNSET,
            specialize=self.specialize, tenant=tenant,
            crossover=self.crossover if same else None,
            batch_tile_max=self.batch_tile_max if same else None,
            schedule=self.schedule if same else None)

    def _sharded(self, with_readout: bool, with_final: bool,
                 donate: bool = False):
        """jit(shard_map(local_rollout)) cached per output signature.

        The shard_map body is the *specialized* local rollout callable —
        the sharded path inherits whatever program the plan selected
        (folded int8 gemm, resident/pipelined pallas kernel) for free.
        ``donate`` donates the carried state at the jit boundary, so the
        zero-copy chunk API works sharded too.
        """
        key = (with_readout, with_final, donate)
        fn = self._sharded_fns.get(key)
        if fn is None:
            spec = self._batch_spec
            out_specs = (spec, spec) if with_final else spec
            # check_rep=False: the weights/plan artifacts enter as closure
            # constants (replicated), which the replication checker cannot
            # see through on the pallas path.
            fn = jax.jit(shard_map(
                self._local_rollout(with_readout, with_final),
                mesh=self.mesh, in_specs=(spec, spec), out_specs=out_specs,
                check_rep=False),
                donate_argnums=(1,) if donate else ())
            self._sharded_fns[key] = fn
        return fn

    def _dispatch(self, u, x0b, with_readout: bool, with_final: bool,
                  donate: bool = False):
        b = u.shape[0]
        bpad = -(-b // self.n_shards) * self.n_shards
        if bpad != b:
            u = jnp.pad(u, ((0, bpad - b), (0, 0), (0, 0)))
            x0b = jnp.pad(x0b, ((0, bpad - b), (0, 0)))
        fn = self._sharded(with_readout, with_final, donate)
        out = donated_call(fn, u, x0b) if donate else fn(u, x0b)
        out, xf = out if with_final else (out, None)
        if bpad != b:
            out = out[:b]
            xf = None if xf is None else xf[:b]
        return out, xf

    def _record(self, out, batch, steps, t0, real_steps, defer=False):
        # account the shard-padding rows as executed-but-padded work, so
        # padding_efficiency stays honest about the sharding overhead
        bpad = -(-batch // self.n_shards) * self.n_shards
        if real_steps is None:
            real_steps = batch * steps
        return super()._record(out, bpad, steps, t0, real_steps, defer=defer)
