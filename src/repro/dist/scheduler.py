"""Shard-aware continuous batching + elastic shrink.

One global FIFO feeds a slot pool that is physically partitioned across
the mesh: slot ``k`` lives on shard ``k // slots_per_shard``, the pool's
state array is sharded over the data axis, and every chunk is still ONE
(sharded) engine call — each shard rolls its own sub-pool concurrently
with zero collectives.  Admission is *least-loaded*: a request seats in
the shard with the most free slots, keeping the sub-pools balanced so no
shard idles while another queues.

Elastic shrink (:meth:`DistributedReservoirServer.shrink`) is the serving
side of :mod:`repro.runtime.elastic`: on a simulated shard loss the mesh
is re-planned to the survivors, the engine is rebuilt from the cached
:class:`~repro.plan.ExecutionPlan` (no re-lowering), and every in-flight
sequence is re-admitted through the global FIFO with its snapshotted
reservoir state as ``x0`` — the chunk API makes the resumed trajectory
bit-identical, so no request is lost and no step is recomputed.
"""

from __future__ import annotations

import dataclasses
import heapq

import jax
import numpy as np

from repro import obs
from repro.dist.engine import ShardedReservoirEngine
from repro.launch.mesh import make_data_mesh
from repro.runtime.elastic import grow_serve_plan, shrink_serve_plan
from repro.serve.api import _UNSET, RolloutResult, warn_deprecated
from repro.serve.batching import RolloutRequest
from repro.serve.scheduler import AsyncReservoirServer, ContinuousBatcher
from repro.serve.stats import ServeStats


class ShardedContinuousBatcher(ContinuousBatcher):
    """Slot pool partitioned into per-shard sub-pools.

    ``n_slots = n_shards * slots_per_shard``; the chunk mechanics (state
    carry, retirement, mid-flight admission, per-model grouping) are
    inherited — the engine call is sharded under the hood, so each
    shard's sub-pool rolls on its own device.  Per-shard telemetry
    accumulates in ``shard_stats`` and aggregates through
    :meth:`ServeStats.merge`.
    """

    def __init__(self, engine: ShardedReservoirEngine, *,
                 slots_per_shard: int = 8, chunk_steps: int = 16,
                 want_states: bool | None = None,
                 return_states: bool | None = _UNSET,
                 zero_copy: bool | None = None,
                 resolver=None):
        assert slots_per_shard >= 1
        if return_states is not _UNSET:
            warn_deprecated(
                "ShardedContinuousBatcher(return_states=...) is "
                "deprecated; pass want_states=...")
            if want_states is None:
                want_states = return_states
        self.n_shards = engine.n_shards
        self.slots_per_shard = slots_per_shard
        super().__init__(engine, n_slots=engine.n_shards * slots_per_shard,
                         chunk_steps=chunk_steps,
                         want_states=want_states, zero_copy=zero_copy,
                         resolver=resolver)
        self.shard_stats = [ServeStats() for _ in range(self.n_shards)]

    def shard_of(self, slot: int) -> int:
        return slot // self.slots_per_shard

    def free_slots_by_shard(self) -> list:
        free = [0] * self.n_shards
        for i, q in enumerate(self._slots):
            if q is None:
                free[self.shard_of(i)] += 1
        return free

    def _free_slot(self) -> int:
        """Least-loaded admission: the emptiest shard's first free slot
        (lowest shard id on ties, so placement is deterministic)."""
        free = self.free_slots_by_shard()
        shard = max(range(self.n_shards), key=lambda s: (free[s], -s))
        lo = shard * self.slots_per_shard
        for i in range(lo, lo + self.slots_per_shard):
            if self._slots[i] is None:
                return i
        raise RuntimeError("no free slot")       # guarded by has_free_slot

    def admit(self, qreq) -> int:
        slot = super().admit(qreq)
        wait = (0.0 if qreq.admit_time is None
                else qreq.admit_time - qreq.arrival_time)
        self.shard_stats[self.shard_of(slot)].record_admission(wait)
        return slot

    def run_chunk(self):
        retired, real = super().run_chunk()
        live = [0] * self.n_shards
        for slot, n in self.last_take.items():
            live[self.shard_of(slot)] += n
        for s in range(self.n_shards):
            self.shard_stats[s].record_chunk(
                live_steps=live[s],
                total_steps=self.slots_per_shard * self.chunk_steps)
        for slot in self.last_retired_slots:
            self.shard_stats[self.shard_of(slot)].record_completion()
        return retired, real

    def snapshot_live(self) -> list:
        """Freeze the in-flight work: ``(qreq, remaining_inputs, state,
        produced_chunks)`` per live slot — everything shrink needs to
        re-admit a sequence with nothing lost or recomputed."""
        states = np.asarray(self._states)
        out = []
        for i, q in enumerate(self._slots):
            if q is None:
                continue
            out.append((q, self.remaining_inputs(i), states[i].copy(),
                        self.chunk_outputs(i)))
        return out


class DistributedReservoirServer(AsyncReservoirServer):
    """Global FIFO + sharded slot pool + elastic shrink.

    The event loop is inherited from :class:`AsyncReservoirServer`
    (virtual clock, FIFO admission sweep, deadline drops); this class adds
    the sharded batcher, per-shard telemetry aggregation
    (:meth:`shard_summary`) and the failure path (:meth:`shrink`).
    """

    def __init__(self, engine: ShardedReservoirEngine, *,
                 slots_per_shard: int = 8, chunk_steps: int = 16,
                 want_states: bool | None = None,
                 return_states: bool | None = _UNSET,
                 stats: ServeStats | None = None,
                 chunk_time: float | None = None,
                 zero_copy: bool | None = None,
                 registry=None, admission=None, fault_plan=None,
                 autoscale=None):
        if return_states is not _UNSET:
            warn_deprecated(
                "DistributedReservoirServer(return_states=...) is "
                "deprecated; pass want_states=...")
            if want_states is None:
                want_states = return_states
        self.engine = engine
        self.slots_per_shard = slots_per_shard
        self.chunk_steps = chunk_steps
        self.want_states = want_states
        batcher = ShardedContinuousBatcher(
            engine, slots_per_shard=slots_per_shard,
            chunk_steps=chunk_steps, want_states=want_states,
            zero_copy=zero_copy)
        super().__init__(engine, stats=stats, chunk_time=chunk_time,
                         batcher=batcher, registry=registry,
                         admission=admission, fault_plan=fault_plan)
        # elastic autoscaling: an AutoscalePolicy consulted once per step
        # (None = manual grow()/shrink() only)
        self.autoscale = autoscale
        self._autoscale_cooldown = 0
        self.reshards = 0                 # completed shrink operations
        self.grows = 0                    # completed grow operations
        self.readmitted = 0               # in-flight seqs carried across
        self._prefixes: dict = {}         # uid -> chunks produced pre-shrink
        self._shard_epochs: list = []     # pre-shrink batchers' shard stats
        # mesh-mapped engines are per-server (the mesh is part of their
        # identity), so tenant routing keeps its own (name, version) map
        # instead of the global engine_for LRU; shrink() clears it
        self._model_engines: dict = {}

    @property
    def n_shards(self) -> int:
        return self.engine.n_shards

    def _tenant_engine(self, name: str, version: int):
        """Mesh-mapped engine for a pinned (model, version): built as a
        sibling of the primary engine (same mesh/dispatch policy, that
        model's params) and cached per server."""
        key = (name, version)
        eng = self._model_engines.get(key)
        if eng is None:
            mv = self.registry.get(name, version)
            eng = self.engine.like(mv.params, tenant=key)
            self._model_engines[key] = eng
        return eng

    def shard_summary(self) -> ServeStats:
        """All per-shard telemetry merged into one ``ServeStats`` (the
        parts stay addressable on ``.shards``).  Covers the whole run:
        after a shrink the retired topology's stats stay in the merge,
        labelled ``epochN/shardK`` so totals (completions, admissions)
        never understate what the server actually served."""
        epochs = self._shard_epochs + [self.batcher.shard_stats]
        parts, labels = [], []
        for e, shard_list in enumerate(epochs):
            for i, s in enumerate(shard_list):
                parts.append(s)
                labels.append(f"shard{i}" if len(epochs) == 1
                              else f"epoch{e}/shard{i}")
        return ServeStats.merge(parts, labels)

    def step(self) -> bool:
        if self.autoscale is not None:
            self._maybe_autoscale()
        alive = super().step()
        # a sequence resumed across a shrink retires with only its
        # post-shrink output; prepend the snapshotted prefix chunks
        if self._prefixes:
            for uid in [u for u in self._prefixes if u in self.results]:
                prefix = self._prefixes.pop(uid)
                res = self.results[uid]
                if isinstance(res, RolloutResult):
                    full = np.concatenate(
                        prefix + [np.asarray(res.output)], axis=0)
                    self.results[uid] = dataclasses.replace(
                        res,
                        preds=None if res.preds is None else full,
                        states=None if res.states is None else full)
                else:
                    self.results[uid] = np.concatenate(
                        prefix + [res], axis=0)
        return alive

    # -- fault detection / autoscale -----------------------------------------
    def _handle_faults(self) -> None:
        """Convert activated shard deaths into the elastic shrink path.

        Unplanned shard death is *detected* here (the plan's clock
        passed the event) and handled with exactly the machinery a
        planned shrink uses: snapshot, rebuild on the survivors,
        re-admit — zero request loss, no new recovery code path."""
        dead = set(self.fault_plan.take_dead_shards())
        if not dead:
            return
        failed = min(len(dead), self.n_shards - 1)
        if failed <= 0:
            return
        obs.event("shard_death_detected", shards=sorted(dead),
                  at=self.now)
        self.shrink(failed=failed)

    def _maybe_autoscale(self) -> None:
        """One :class:`~repro.runtime.elastic.AutoscalePolicy` consult,
        rate-limited by the policy's cooldown so a rebuild's re-admission
        transient cannot immediately trigger the next decision."""
        if self._autoscale_cooldown > 0:
            self._autoscale_cooldown -= 1
            return
        pol = self.autoscale
        verdict = pol.decide(pending=self.pending,
                             live=self.batcher.live,
                             n_slots=self.batcher.n_slots,
                             n_shards=self.n_shards)
        if verdict > 0:
            ceiling = min(pol.max_shards, len(jax.devices()))
            if self.n_shards < ceiling:
                self.grow(min(verdict, ceiling - self.n_shards))
                self._autoscale_cooldown = pol.cooldown_steps
        elif verdict < 0 and self.n_shards > pol.min_shards:
            self.shrink(
                failed=min(-verdict, self.n_shards - pol.min_shards))
            self._autoscale_cooldown = pol.cooldown_steps

    # -- elastic -------------------------------------------------------------
    def _rebuild(self, new_n: int) -> int:
        """Rebuild the pool on a ``new_n``-shard mesh, carrying every
        live slot across — the shared core of :meth:`shrink` and
        :meth:`grow`.

        Snapshots every live slot (state + remaining inputs + output so
        far), rebuilds the engine on the new mesh (the
        :class:`ExecutionPlan` is cached per matrix, so this is jit
        setup only), stands up a fresh sharded batcher, and pushes the
        snapshots back through the global FIFO — they sort by their
        original arrival times, so they re-seat first (and on a grow the
        least-loaded admission spreads them over the new width).
        Returns the number of carried sequences.
        """
        carried = self.batcher.snapshot_live()
        devices = list(self.engine.mesh.devices.ravel())
        if new_n > len(devices):
            # grow: extend with devices not already in the mesh, keeping
            # the surviving shard order stable
            devices += [d for d in jax.devices() if d not in devices]
        engine = self.engine.like(
            mesh=make_data_mesh(devices=devices[:new_n]))
        self.engine = engine
        self._shard_epochs.append(self.batcher.shard_stats)
        self.batcher = ShardedContinuousBatcher(
            engine, slots_per_shard=self.slots_per_shard,
            chunk_steps=self.chunk_steps, want_states=self.want_states,
            zero_copy=self.batcher.zero_copy,
            resolver=self._resolve_engine)
        self.batcher.fault_plan = self.fault_plan
        # tenant engines were mapped on the old mesh — rebuild lazily on
        # the new mesh as pinned requests re-resolve
        self._model_engines.clear()

        for qreq, remaining, state, chunks in carried:
            if chunks:
                self._prefixes[qreq.uid] = \
                    self._prefixes.pop(qreq.uid, []) + chunks
            qreq.request = RolloutRequest(uid=qreq.uid, inputs=remaining,
                                          x0=state)
            # original (arrival_time, seq) key: carried work re-seats
            # ahead of everything that queued behind it
            heapq.heappush(self._queue,
                           (qreq.arrival_time, qreq.seq, qreq))
            qreq.admit_time = None
            # wait accounting restarts at the rebuild; the heap key above
            # keeps the original priority
            qreq.arrival_time = self.now
            # it was already admitted once — carried work is never dropped
            # and never double-counted in the server's admission stats
            qreq.deadline = None
            qreq.requeued = True
        self.readmitted += len(carried)
        return len(carried)

    def shrink(self, failed: int = 1) -> dict:
        """Simulated shard loss: rebuild on the survivors, lose nothing.

        Executes :func:`repro.runtime.elastic.shrink_serve_plan`'s action
        list through :meth:`_rebuild`.  Returns the plan dict (with
        ``n_shards`` before/after) for the caller's logs.
        """
        plan = shrink_serve_plan(self.n_shards, failed)
        new_n = max(plan["usable_devices"], 1)
        carried = self._rebuild(new_n)
        self.reshards += 1
        plan["n_shards_before"] = plan["survivors"] + failed
        plan["n_shards_after"] = new_n
        plan["readmitted"] = carried
        obs.event("shrink", failed=failed, n_shards_after=new_n,
                  readmitted=carried)
        obs.inc("shrinks_total")
        obs.set_gauge("n_shards", new_n)
        return plan

    def grow(self, added: int = 1) -> dict:
        """Elastic scale-up: admit ``added`` new shards under live
        traffic — the inverse of :meth:`shrink` (ROADMAP 4b).

        Executes :func:`repro.runtime.elastic.grow_serve_plan` through
        the same snapshot/re-admit machinery: in-flight sequences resume
        from their carried states (bit-identical — the per-shard program
        shape is independent of the shard count), completed chunks are
        stitched as prefixes, nothing is dropped or re-run, and the
        least-loaded FIFO admission rebalances the sub-pools over the
        wider pool.  The target width is capped at the visible device
        count.  Returns the executed plan dict.
        """
        plan = grow_serve_plan(self.n_shards, added,
                               max_shards=len(jax.devices()))
        new_n = plan["n_shards_after"]
        if new_n <= self.n_shards:
            plan["readmitted"] = 0
            return plan                   # nothing to add (device ceiling)
        carried = self._rebuild(new_n)
        self.grows += 1
        plan["readmitted"] = carried
        obs.event("grow", added=plan["added"], n_shards_after=new_n,
                  readmitted=carried)
        obs.inc("grows_total")
        obs.set_gauge("n_shards", new_n)
        return plan
