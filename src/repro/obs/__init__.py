"""End-to-end observability: metrics, request tracing, compile events.

Off by default, and cheap when off: every instrumented site in the serve /
dist / plan stack funnels through the module-level one-liners below
(:func:`inc`, :func:`observe`, :func:`span`, :func:`event`, ...), each of
which is a single global read plus a ``None`` check when
:func:`configure` has not been called — the hot path pays nanoseconds,
and the ``serve_obs`` benchmark gates the *enabled* overhead at <= 3% of
goodput.  The three sinks:

* :class:`~repro.obs.metrics.MetricsRegistry` — counters, gauges and
  mergeable fixed-bucket latency histograms (exact p50/p99/p999 from
  bucket counts), exported as Prometheus text or JSON;
* :class:`~repro.obs.trace.Tracer` — structured spans (request lifecycle
  on the server clock, engine dispatch/sync on the wall clock, plan and
  autotune stages) in a bounded flight recorder with JSONL export;
* :class:`~repro.obs.events.EventLog` — named, timestamped compile /
  retrace / cache-miss events, so an unexpected recompile under steady
  traffic is a fact in a log, not a latency mystery.

Typical session::

    from repro import obs
    obs.configure()                       # all three sinks on
    ... serve traffic ...
    print(obs.metrics().prometheus_text())           # scrape payload
    print(obs.metrics().summary())                   # p50/p99/p999 view
    obs.tracer().export_jsonl("trace.jsonl")         # flight recorder
    assert obs.events().count("retrace") == 0        # steady state held
    obs.disable()                         # back to zero-cost no-ops

``configure`` is idempotent-by-replacement: each call installs fresh
sinks (a clean measurement window); ``disable`` detaches them.
"""

from __future__ import annotations

import dataclasses
import time
from contextlib import contextmanager
from typing import Any

from repro.obs.events import Event, EventLog
from repro.obs.metrics import (DEFAULT_LATENCY_BUCKETS, Counter, Gauge,
                               Histogram, HistogramData, MetricsRegistry)
from repro.obs.trace import Span, Tracer

__all__ = [
    "DEFAULT_LATENCY_BUCKETS",
    "Counter",
    "Event",
    "EventLog",
    "Gauge",
    "Histogram",
    "HistogramData",
    "MetricsRegistry",
    "ObsState",
    "Span",
    "Tracer",
    "active",
    "configure",
    "disable",
    "enabled",
    "event",
    "events",
    "inc",
    "metrics",
    "new_trace_id",
    "observe",
    "set_gauge",
    "span",
    "timed_span",
    "tracer",
]


@dataclasses.dataclass
class ObsState:
    """The installed sinks; any of the three may be individually off."""

    metrics: MetricsRegistry | None = None
    tracer: Tracer | None = None
    events: EventLog | None = None


_ACTIVE: ObsState | None = None


def configure(*, metrics: bool = True, tracing: bool = True,
              events: bool = True, namespace: str = "repro",
              trace_capacity: int = 4096,
              event_capacity: int = 2048) -> ObsState:
    """Install fresh sinks and enable instrumentation.  Returns the new
    state (also reachable via :func:`active` / the accessors)."""
    global _ACTIVE
    _ACTIVE = ObsState(
        metrics=MetricsRegistry(namespace=namespace) if metrics else None,
        tracer=Tracer(capacity=trace_capacity) if tracing else None,
        events=EventLog(capacity=event_capacity) if events else None)
    return _ACTIVE


def disable() -> None:
    """Detach every sink: instrumented sites return to no-ops."""
    global _ACTIVE
    _ACTIVE = None


def active() -> ObsState | None:
    return _ACTIVE


def enabled() -> bool:
    return _ACTIVE is not None


def metrics() -> MetricsRegistry | None:
    return None if _ACTIVE is None else _ACTIVE.metrics


def tracer() -> Tracer | None:
    return None if _ACTIVE is None else _ACTIVE.tracer


def events() -> EventLog | None:
    return None if _ACTIVE is None else _ACTIVE.events


# -- hot-path one-liners (no-ops unless the matching sink is installed) ------
def inc(name: str, amount: float = 1.0, **labels) -> None:
    st = _ACTIVE
    if st is not None and st.metrics is not None:
        st.metrics.inc(name, amount, **labels)


def set_gauge(name: str, value: float, **labels) -> None:
    st = _ACTIVE
    if st is not None and st.metrics is not None:
        st.metrics.set(name, value, **labels)


def observe(name: str, value: float, **labels) -> None:
    st = _ACTIVE
    if st is not None and st.metrics is not None:
        st.metrics.observe(name, value, **labels)


def span(name: str, start: float, end: float | None = None, *,
         trace_id: str | None = None, clock: str = "wall",
         **attrs: Any) -> None:
    """Record one finished span (no-op without a tracer)."""
    st = _ACTIVE
    if st is not None and st.tracer is not None:
        st.tracer.record(name, start, end, trace_id=trace_id, clock=clock,
                         **attrs)


def event(kind: str, ts: float | None = None, **fields: Any) -> None:
    st = _ACTIVE
    if st is not None and st.events is not None:
        st.events.record(kind, ts=ts, **fields)


def new_trace_id() -> str | None:
    """A fresh request trace id, or ``None`` when tracing is off (callers
    simply don't thread an id then)."""
    st = _ACTIVE
    if st is not None and st.tracer is not None:
        return st.tracer.new_trace_id()
    return None


@contextmanager
def timed_span(name: str, *, trace_id: str | None = None, **attrs: Any):
    """Wall-clock span context manager; a plain passthrough when tracing
    is off (the clock is not even read)."""
    st = _ACTIVE
    if st is None or st.tracer is None:
        yield
        return
    t0 = time.perf_counter()
    try:
        yield
    finally:
        st.tracer.record(name, t0, time.perf_counter(), trace_id=trace_id,
                         clock="wall", **attrs)
