"""Compile/retrace event log: every recompile is a named, timestamped fact.

Steady-state serving must never compile: the batcher owns one static pool
shape, the engine caches its jitted rollouts per (shape, outputs, regime),
and ``engine_for`` / ``plan_for`` / the autotune :class:`ScheduleCache`
all memoize their expensive steps.  When that property breaks — a shape
leaks through admission, a cache key regresses, a republish misses the
prewarm — the only symptom used to be a mysterious latency spike.  This
log turns it into evidence: the instrumented trace-counter and cache-miss
sites emit an :class:`Event` (``kind`` plus free-form fields), and the
``retrace`` kind specifically marks a *re*-trace of an already-compiled
program — the thing that must count zero under steady traffic (the
``serve_obs`` benchmark gates exactly that).

Well-known kinds emitted by the instrumented sites:

====================  ======================================================
``xla_trace``         first trace of an XLA rollout variant (expected, once)
``pallas_trace``      first trace of a specialized Pallas launch
``retrace``           the same variant traced AGAIN — unexpected recompile
``engine_build``      a ReservoirEngine constructed (compile work follows)
``engine_cache_miss`` ``engine_for`` built instead of reusing
``plan_lowering``     ``plan_for`` lowered a matrix (cache miss)
``schedule_resolve``  autotuner resolved a schedule (source: cache /
                      predicted / measured)
``publish``           registry live swap executed
``shrink``            elastic reshard executed
====================  ======================================================
"""

from __future__ import annotations

import collections
import dataclasses
import json
import time
from typing import Any

__all__ = ["Event", "EventLog"]


@dataclasses.dataclass(frozen=True)
class Event:
    """One named, timestamped occurrence (``ts`` is epoch seconds)."""

    ts: float
    kind: str
    fields: dict

    def as_dict(self) -> dict:
        return {"ts": self.ts, "kind": self.kind, **self.fields}


class EventLog:
    """Bounded event ring with per-kind lifetime counters.

    The ring holds the last ``capacity`` events (the incident record);
    ``counts`` keeps exact per-kind totals for the whole process lifetime
    even after old events fall off, so "how many retraces, ever" never
    under-reports.
    """

    def __init__(self, capacity: int = 2048):
        assert capacity >= 1
        self.capacity = capacity
        self._events: collections.deque = collections.deque(maxlen=capacity)
        self.counts: collections.Counter = collections.Counter()
        self.dropped = 0

    def record(self, kind: str, ts: float | None = None,
               **fields: Any) -> Event:
        ev = Event(ts=time.time() if ts is None else float(ts),
                   kind=kind, fields=fields)
        if len(self._events) == self.capacity:
            self.dropped += 1
        self._events.append(ev)
        self.counts[kind] += 1
        return ev

    def events(self, kind: str | None = None) -> list:
        """Buffered events oldest-first, optionally one kind."""
        return [e for e in self._events if kind is None or e.kind == kind]

    def count(self, kind: str) -> int:
        """Lifetime count of ``kind`` (survives ring eviction)."""
        return self.counts[kind]

    def __len__(self) -> int:
        return len(self._events)

    def drain(self, kind: str | None = None) -> list:
        """Return-and-forget: the buffered events (optionally one kind)
        are removed from the ring so a steady-state window can be
        measured as "events recorded since the last drain".  Lifetime
        ``counts`` are untouched."""
        if kind is None:
            out = list(self._events)
            self._events.clear()
            return out
        out, keep = [], []
        for e in self._events:
            (out if e.kind == kind else keep).append(e)
        self._events.clear()
        self._events.extend(keep)
        return out

    def to_jsonl(self) -> str:
        return "".join(json.dumps(e.as_dict(), sort_keys=True, default=str)
                       + "\n" for e in self._events)

    def export_jsonl(self, path) -> int:
        text = self.to_jsonl()
        with open(path, "w") as f:
            f.write(text)
        return len(self._events)
