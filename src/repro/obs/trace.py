"""Structured span tracing into a bounded in-memory flight recorder.

One :class:`Tracer` holds a fixed-capacity ring of :class:`Span` records —
enough history to reconstruct *why* the last N requests were slow (queue
wait vs. chunk stall vs. an autotune recompile) without growing without
bound under sustained traffic.  Spans carry:

* ``name``      — the stage (``request.queued``, ``scheduler.chunk``,
  ``engine.dispatch``, ``autotune.trial``, ...);
* ``trace_id``  — threaded from ``SubmitSpec.trace_id`` through every
  stage a request touches, so one grep over the JSONL dump reassembles a
  request's whole lifecycle;
* ``clock``     — ``"wall"`` (``time.perf_counter``) or ``"server"``
  (the scheduler's virtual clock): the two timelines must never be
  compared directly, so every span says which one it is on.

``export_jsonl`` dumps the recorder for post-incident analysis — one JSON
object per line, oldest first.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import json
import time
from contextlib import contextmanager
from typing import Any

__all__ = ["Span", "Tracer"]


@dataclasses.dataclass(slots=True)
class Span:
    """One timed stage.  ``start == end`` marks an instant event.

    A plain (slotted, non-frozen) dataclass: span construction sits on
    the serve hot path, and frozen's ``object.__setattr__`` per field
    roughly doubles its cost."""

    name: str
    start: float
    end: float
    trace_id: str | None = None
    clock: str = "wall"
    attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        return self.end - self.start

    def as_dict(self) -> dict:
        return {"name": self.name, "start": self.start, "end": self.end,
                "duration_s": self.duration_s, "trace_id": self.trace_id,
                "clock": self.clock, "attrs": self.attrs}


class Tracer:
    """Bounded span recorder ("flight recorder").

    Appends are O(1); once ``capacity`` is reached the oldest span falls
    off (``dropped`` counts how many), so the recorder's memory is fixed
    no matter how long the server runs.
    """

    def __init__(self, capacity: int = 4096):
        assert capacity >= 1
        self.capacity = capacity
        self._spans: collections.deque = collections.deque(maxlen=capacity)
        self.dropped = 0
        self._ids = itertools.count(1)

    def new_trace_id(self) -> str:
        """A process-unique request id (``t-000001``, ...)."""
        return f"t-{next(self._ids):06d}"

    def record(self, name: str, start: float, end: float | None = None, *,
               trace_id: str | None = None, clock: str = "wall",
               **attrs: Any) -> Span:
        """Record one finished span (``end`` defaults to ``start`` — an
        instant event)."""
        span = Span(name=name, start=float(start),
                    end=float(start if end is None else end),
                    trace_id=trace_id, clock=clock, attrs=attrs)
        if len(self._spans) == self.capacity:
            self.dropped += 1
        self._spans.append(span)
        return span

    @contextmanager
    def span(self, name: str, *, trace_id: str | None = None, **attrs: Any):
        """Wall-clock context manager: times the enclosed block."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.record(name, t0, time.perf_counter(), trace_id=trace_id,
                        clock="wall", **attrs)

    def spans(self, *, name: str | None = None,
              trace_id: str | None = None) -> list:
        """Recorded spans, oldest first, optionally filtered."""
        return [s for s in self._spans
                if (name is None or s.name == name)
                and (trace_id is None or s.trace_id == trace_id)]

    def __len__(self) -> int:
        return len(self._spans)

    def clear(self) -> None:
        self._spans.clear()

    def to_jsonl(self) -> str:
        return "".join(json.dumps(s.as_dict(), sort_keys=True) + "\n"
                       for s in self._spans)

    def export_jsonl(self, path) -> int:
        """Dump the recorder to ``path`` (one span per line, oldest
        first); returns the number of spans written."""
        text = self.to_jsonl()
        with open(path, "w") as f:
            f.write(text)
        return len(self._spans)
