"""Counters, gauges and fixed-bucket latency histograms with exports.

The paper's claim is a latency story, and a latency story needs tails:
``ServeStats`` accumulates sums and counts, so it can quote *means* but
not the p99/p999 a sustained-load SLO is written against.  This module is
the percentile half of the telemetry stack:

* every metric family holds one series per label set (``tenant=``,
  ``shard=``, ...), so a multi-tenant sharded server gets per-tenant and
  per-shard breakdowns for free;
* :class:`Histogram` series use *fixed* bucket boundaries, which makes
  them mergeable by plain addition — per-shard histograms merged give
  exactly the percentiles of one histogram fed the union of the samples
  (the property test in ``tests/test_obs.py`` pins this), mirroring how
  ``ServeStats.merge`` sums its counters across shards;
* :meth:`MetricsRegistry.prometheus_text` renders the standard text
  exposition format (scrape it, or dump it next to an incident trace)
  and :meth:`MetricsRegistry.to_json` / :meth:`MetricsRegistry.from_json`
  round-trip the registry losslessly.

Percentiles are computed from bucket counts by nearest rank: the reported
value is the upper bound of the bucket the rank falls in (the recorded
maximum for the overflow bucket), so a merged histogram and a union
histogram can never disagree.
"""

from __future__ import annotations

import bisect
import dataclasses
import json
import math
from typing import Iterable, Mapping, Sequence

__all__ = [
    "DEFAULT_LATENCY_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "HistogramData",
    "MetricsRegistry",
]

# 1-2-5 per decade from 1 us to 100 s: wide enough for a virtual-clock
# chunk (tens of us) and a queue wait under sustained load (seconds),
# fine enough that nearest-rank bucket percentiles stay meaningful.
DEFAULT_LATENCY_BUCKETS = tuple(
    float(f"{m}e{e}") for e in range(-6, 2) for m in (1, 2, 5)) + (100.0,)


def _labelkey(labels: Mapping[str, object]) -> tuple:
    if not labels:
        return ()
    if len(labels) == 1:
        [(k, v)] = labels.items()
        return ((str(k), str(v)),)
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _labelstr(key: tuple, extra: str = "") -> str:
    parts = [f'{k}="{v}"' for k, v in key]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt(v: float) -> str:
    """Prometheus-style number: integers render bare, floats repr()."""
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


@dataclasses.dataclass
class HistogramData:
    """One histogram series: fixed-bucket counts + sum/count/max.

    ``counts[i]`` counts observations ``<= buckets[i]``-and-above the
    previous bound; ``counts[-1]`` is the +Inf overflow bucket.  All
    fields are additive (``vmax`` maxes), which is what makes
    :meth:`merge` exact.
    """

    buckets: tuple
    counts: list = None
    total: int = 0
    sum: float = 0.0
    vmax: float = 0.0

    def __post_init__(self):
        self.buckets = tuple(float(b) for b in self.buckets)
        assert list(self.buckets) == sorted(set(self.buckets)), \
            "bucket bounds must be strictly increasing"
        if self.counts is None:
            self.counts = [0] * (len(self.buckets) + 1)
        assert len(self.counts) == len(self.buckets) + 1

    def observe(self, value: float) -> None:
        v = float(value)
        self.total += 1
        self.sum += v
        if self.total == 1 or v > self.vmax:
            self.vmax = v
        # first bound >= v (== the overflow slot when v beats them all)
        self.counts[bisect.bisect_left(self.buckets, v)] += 1

    @staticmethod
    def merge(parts: "Iterable[HistogramData]") -> "HistogramData":
        """Sum bucket counts across series (identical bucket layouts
        required) — percentiles of the merge equal percentiles of the
        union of the underlying samples, exactly."""
        parts = list(parts)
        assert parts, "nothing to merge"
        base = HistogramData(buckets=parts[0].buckets)
        for p in parts:
            assert p.buckets == base.buckets, \
                f"bucket layouts differ: {p.buckets} vs {base.buckets}"
            base.total += p.total
            base.sum += p.sum
            base.vmax = max(base.vmax, p.vmax)
            for i, c in enumerate(p.counts):
                base.counts[i] += c
        return base

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile from bucket counts (0.0 when empty).

        Returns the upper bound of the bucket the rank lands in; the
        overflow bucket answers with the recorded maximum so the tail is
        never reported as infinity.
        """
        if self.total == 0:
            return 0.0
        rank = max(1, math.ceil(p / 100.0 * self.total))
        seen = 0
        for i, c in enumerate(self.counts[:-1]):
            seen += c
            if seen >= rank:
                return self.buckets[i]
        return self.vmax

    @property
    def mean(self) -> float:
        return self.sum / self.total if self.total else 0.0

    def as_dict(self) -> dict:
        return {"counts": list(self.counts), "total": self.total,
                "sum": self.sum, "vmax": self.vmax}


class _Family:
    """Shared per-label-set series bookkeeping for all metric types."""

    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._series: dict = {}

    @property
    def series(self) -> dict:
        return self._series

    def labelsets(self) -> list:
        return sorted(self._series)


class Counter(_Family):
    """Monotonic per-label-set count (``requests_total{tenant="A"}``)."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = _labelkey(labels)
        self._series[key] = self._series.get(key, 0.0) + float(amount)

    def value(self, **labels) -> float:
        if labels:
            return float(self._series.get(_labelkey(labels), 0.0))
        return float(sum(self._series.values()))


class Gauge(_Family):
    """Point-in-time value per label set (``slot_occupancy{shard="0"}``)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._series[_labelkey(labels)] = float(value)

    def value(self, **labels) -> float:
        return float(self._series.get(_labelkey(labels), 0.0))


class Histogram(_Family):
    """Fixed-bucket histogram family; one :class:`HistogramData` per
    label set, and label-free reads merge every series."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS):
        super().__init__(name, help)
        self.buckets = tuple(float(b) for b in buckets)

    def observe(self, value: float, **labels) -> None:
        key = _labelkey(labels)
        data = self._series.get(key)
        if data is None:
            data = self._series[key] = HistogramData(buckets=self.buckets)
        data.observe(value)

    def data(self, **labels) -> HistogramData:
        """The series for ``labels`` — or, with no labels, the merge of
        every series (empty histogram when nothing was observed)."""
        if labels:
            return self._series.get(_labelkey(labels)) \
                or HistogramData(buckets=self.buckets)
        if not self._series:
            return HistogramData(buckets=self.buckets)
        return HistogramData.merge(self._series.values())

    def percentile(self, p: float, **labels) -> float:
        return self.data(**labels).percentile(p)

    def count(self, **labels) -> int:
        return self.data(**labels).total


class MetricsRegistry:
    """Named metric families behind one export surface.

    Families auto-create on first use (``inc``/``set``/``observe``), so
    instrumentation sites never have to pre-declare; ``declare_*`` pins
    help text and custom buckets up front.  A name maps to exactly one
    type — observing a counter is a bug and raises.
    """

    def __init__(self, namespace: str = "repro"):
        self.namespace = namespace
        self._families: dict = {}

    # -- declaration / access ------------------------------------------------
    def _family(self, name: str, cls, **kwargs):
        fam = self._families.get(name)
        if fam is None:
            fam = self._families[name] = cls(name, **kwargs)
        elif not isinstance(fam, cls):
            raise TypeError(
                f"metric {name!r} is a {fam.kind}, not a {cls.kind}")
        return fam

    def counter(self, name: str, help: str = "") -> Counter:
        return self._family(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._family(name, Gauge, help=help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS
                  ) -> Histogram:
        return self._family(name, Histogram, help=help, buckets=buckets)

    def get(self, name: str):
        return self._families.get(name)

    def families(self) -> list:
        return [self._families[n] for n in sorted(self._families)]

    # -- one-liner record surface (hot path: the serve loop calls these
    # several times per request, so the existing-family case skips the
    # declaration helpers and goes straight to the series update) -----------
    def inc(self, name: str, amount: float = 1.0, **labels) -> None:
        fam = self._families.get(name)
        if fam is None or fam.__class__ is not Counter:
            fam = self.counter(name)
        fam.inc(amount, **labels)

    def set(self, name: str, value: float, **labels) -> None:
        fam = self._families.get(name)
        if fam is None or fam.__class__ is not Gauge:
            fam = self.gauge(name)
        fam.set(value, **labels)

    def observe(self, name: str, value: float, **labels) -> None:
        fam = self._families.get(name)
        if fam is None or fam.__class__ is not Histogram:
            fam = self.histogram(name)
        fam.observe(value, **labels)

    # -- exports -------------------------------------------------------------
    def prometheus_text(self) -> str:
        """The standard text exposition format (one scrape payload)."""
        lines = []
        ns = self.namespace
        for fam in self.families():
            full = f"{ns}_{fam.name}" if ns else fam.name
            if fam.help:
                lines.append(f"# HELP {full} {fam.help}")
            lines.append(f"# TYPE {full} {fam.kind}")
            if isinstance(fam, Histogram):
                for key in fam.labelsets():
                    d = fam.series[key]
                    cum = 0
                    for b, c in zip(d.buckets, d.counts):
                        cum += c
                        le = 'le="' + _fmt(b) + '"'
                        lines.append(
                            f"{full}_bucket{_labelstr(key, le)} {cum}")
                    inf = 'le="+Inf"'
                    lines.append(
                        f"{full}_bucket{_labelstr(key, inf)} {d.total}")
                    lines.append(f"{full}_sum{_labelstr(key)} {_fmt(d.sum)}")
                    lines.append(f"{full}_count{_labelstr(key)} {d.total}")
            else:
                # counters get the conventional _total suffix unless the
                # author already named them with it
                suffix = ("_total" if isinstance(fam, Counter)
                          and not fam.name.endswith("_total") else "")
                for key in fam.labelsets():
                    lines.append(f"{full}{suffix}{_labelstr(key)} "
                                 f"{_fmt(fam.series[key])}")
        return "\n".join(lines) + ("\n" if lines else "")

    def to_json(self) -> dict:
        """Lossless snapshot: :meth:`from_json` of it renders the exact
        same Prometheus text."""
        fams = []
        for fam in self.families():
            rec = {"name": fam.name, "kind": fam.kind, "help": fam.help}
            if isinstance(fam, Histogram):
                rec["buckets"] = list(fam.buckets)
                rec["series"] = [
                    {"labels": dict(key), **fam.series[key].as_dict()}
                    for key in fam.labelsets()]
            else:
                rec["series"] = [{"labels": dict(key),
                                  "value": fam.series[key]}
                                 for key in fam.labelsets()]
            fams.append(rec)
        return {"namespace": self.namespace, "metrics": fams}

    @classmethod
    def from_json(cls, data: dict) -> "MetricsRegistry":
        reg = cls(namespace=data.get("namespace", "repro"))
        for rec in data.get("metrics", ()):
            name, kind = rec["name"], rec["kind"]
            if kind == "histogram":
                fam = reg.histogram(name, help=rec.get("help", ""),
                                    buckets=rec["buckets"])
                for s in rec["series"]:
                    fam.series[_labelkey(s["labels"])] = HistogramData(
                        buckets=fam.buckets, counts=list(s["counts"]),
                        total=int(s["total"]), sum=float(s["sum"]),
                        vmax=float(s["vmax"]))
            else:
                fam = (reg.counter if kind == "counter" else reg.gauge)(
                    name, help=rec.get("help", ""))
                for s in rec["series"]:
                    fam.series[_labelkey(s["labels"])] = float(s["value"])
        return reg

    def save_json(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)

    def summary(self) -> dict:
        """Compact human-readable snapshot: counters/gauges by value,
        histograms by count/mean/p50/p99/p999 (merged across labels)."""
        out: dict = {}
        for fam in self.families():
            if isinstance(fam, Histogram):
                d = fam.data()
                out[fam.name] = {
                    "count": d.total, "mean": d.mean,
                    "p50": d.percentile(50.0), "p99": d.percentile(99.0),
                    "p999": d.percentile(99.9)}
            else:
                out[fam.name] = fam.value()
        return out
