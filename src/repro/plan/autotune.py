"""Measured-cost plan autotuning: close the loop on the cost model.

PR 5's specialization pass picks its schedule — weight-residency regime,
shift-add crossover, VMEM band budget, batch tile — from fixed heuristics,
and ``backend="auto"`` silently always means XLA.  The paper's actual
contribution is an *extensible cost model driving the implementation*:
predicted cost picks the design point, measurement calibrates the
predictor.  This module is that loop for the rollout schedule space:

  predict  — enumerate every valid candidate schedule (budgets x
             crossovers x batch tiles x backends; regime falls out of the
             budget) and price each one with the calibrated linear model
             in :mod:`repro.core.costmodel`, using counts-only
             ``specialize_summary`` analysis — no tile data, no compile.
  prune    — keep the top-K predicted schedules (the default-heuristic
             schedule is ALWAYS kept, so the measured winner can never
             lose to the default on the tuner's own trials).
  measure  — build real engines through the ``specialize_rollout`` ->
             ``RolloutProgram`` path and time the actual rollout,
             best-of-reps.
  cache    — the winner lands on the plan (``plan.describe()`` reports
             it), in the process-wide :class:`ScheduleCache`, and — via
             ``autotune_cache_save`` — in a JSON file keyed on plan
             fingerprint + hardware fingerprint, so serve startup after
             ``autotune_cache_load`` pays zero re-tuning.

Every candidate schedule is bit-identical to every other (the programs
differ only in term grouping and residency; int8 accumulates in exact
int32, fp32 keeps ascending-row order — property-tested), so tuning is
purely a throughput decision and can never change served results.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import time

import numpy as np

from repro import obs
from repro.core import costmodel
from repro.plan.plan import DEFAULT_VMEM_BUDGET, ExecutionPlan
from repro.plan.specialize import DEFAULT_BATCH_TILE, default_crossover, \
    specialize_summary

__all__ = [
    "BACKENDS",
    "Schedule",
    "TunedSchedule",
    "ScheduleCache",
    "default_schedule",
    "candidate_schedules",
    "predict_cost",
    "plan_fingerprint",
    "hardware_fingerprint",
    "resolve_schedule",
    "resolve_backend",
    "autotune_rollout",
    "autotune_cache",
    "autotune_cache_load",
    "autotune_cache_save",
]

BACKENDS = ("xla", "pallas")

# Default tuning shape: small enough to measure in milliseconds, big
# enough that the regime/backend choice it makes transfers to serve-sized
# batches (the cache key buckets the batch axis, so other shapes re-tune).
TUNE_BATCH = 8
TUNE_STEPS = 8


@dataclasses.dataclass(frozen=True)
class Schedule:
    """One point in the rollout schedule space.

    The regime (resident vs pipelined) is not a free axis: it falls out of
    ``vmem_budget`` deterministically (``None`` forces resident; a finite
    budget pipelines iff the folded tiles overflow it), so enumerating
    budgets enumerates regimes.
    """

    mode: str                  # "fp32" | "int8" (kernel mode)
    backend: str               # "xla" | "pallas"
    vmem_budget: int | None
    crossover: int
    batch_tile_max: int

    def key(self) -> tuple:
        return (self.mode, self.backend, self.vmem_budget, self.crossover,
                self.batch_tile_max)

    def sort_key(self) -> tuple:
        """Total order for deterministic tie-breaking (``None`` budget —
        forced resident — sorts as -1, below every finite budget)."""
        return (self.mode, self.backend,
                -1 if self.vmem_budget is None else self.vmem_budget,
                self.crossover, self.batch_tile_max)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Schedule":
        return cls(mode=d["mode"], backend=d["backend"],
                   vmem_budget=d["vmem_budget"],
                   crossover=int(d["crossover"]),
                   batch_tile_max=int(d["batch_tile_max"]))

    def describe(self) -> str:
        budget = "none" if self.vmem_budget is None else str(self.vmem_budget)
        return (f"{self.backend} budget={budget} "
                f"crossover={self.crossover} tile={self.batch_tile_max}")


def default_schedule(plan: ExecutionPlan, mode: str,
                     backend: str = "xla") -> Schedule:
    """The PR-5 fixed-heuristic schedule — the tuner's reference point and
    the fallback when tuning is disabled or impossible."""
    return Schedule(mode=mode, backend=backend,
                    vmem_budget=DEFAULT_VMEM_BUDGET,
                    crossover=default_crossover(plan.block),
                    batch_tile_max=DEFAULT_BATCH_TILE)


@dataclasses.dataclass(frozen=True)
class TunedSchedule:
    """A tuning decision: the chosen schedule plus the evidence for it.

    ``source`` is ``"measured"`` (full predict -> prune -> measure loop),
    ``"predicted"`` (analytic model only — what engine construction does
    on a cache miss, so startup never blocks on wall-clock measurement),
    or ``"cache"`` (replayed from the persisted JSON cache).  ``trials``
    records every measured candidate as ``(schedule_dict, predicted_s,
    measured_s)`` — the calibration rows ``fit_rollout_cost`` consumes.
    """

    schedule: Schedule
    batch: int
    steps: int
    predicted_s: float
    measured_s: float | None = None
    default_predicted_s: float | None = None
    default_measured_s: float | None = None
    source: str = "predicted"
    n_candidates: int = 0
    trials: tuple = ()

    def as_dict(self) -> dict:
        return {
            "schedule": self.schedule.as_dict(),
            "batch": self.batch, "steps": self.steps,
            "predicted_s": self.predicted_s,
            "measured_s": self.measured_s,
            "default_predicted_s": self.default_predicted_s,
            "default_measured_s": self.default_measured_s,
            "source": self.source, "n_candidates": self.n_candidates,
            "trials": [{"schedule": s, "predicted_s": p, "measured_s": m}
                       for s, p, m in self.trials],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "TunedSchedule":
        return cls(
            schedule=Schedule.from_dict(d["schedule"]),
            batch=int(d["batch"]), steps=int(d["steps"]),
            predicted_s=float(d["predicted_s"]),
            measured_s=d.get("measured_s"),
            default_predicted_s=d.get("default_predicted_s"),
            default_measured_s=d.get("default_measured_s"),
            source=d.get("source", "cache"),
            n_candidates=int(d.get("n_candidates", 0)),
            trials=tuple((t["schedule"], t["predicted_s"], t["measured_s"])
                         for t in d.get("trials", ())))

    def describe(self) -> str:
        meas = (f"{self.measured_s * 1e3:.3f} ms measured"
                if self.measured_s is not None else "predict-only")
        return (f"{self.schedule.describe()} "
                f"({self.predicted_s * 1e3:.3f} ms predicted, {meas}, "
                f"{self.source} over {self.n_candidates} candidates)")


# -- fingerprints ------------------------------------------------------------
def plan_fingerprint(plan: ExecutionPlan) -> str:
    """Stable digest of the structure the schedule space depends on.

    Two matrices with the same block sparsity pattern, digit mode and
    set-digit count have identical schedule spaces and near-identical
    costs, so they share a cache entry — a registry republishing a
    same-shaped matrix reuses the tuning.  Uses ``fm.ones`` (already
    computed at matrix compile) rather than ``plan.stats`` so fp32-only
    consumers never pay for the integer lowering just to be fingerprinted.
    """
    h = hashlib.sha1()
    for part in (plan.shape, plan.block, plan.mode, plan.weight_bits,
                 plan.blocks_nnz, plan._fm.ones):
        h.update(repr(part).encode())
    h.update(np.ascontiguousarray(plan.block_rows).tobytes())
    h.update(np.ascontiguousarray(plan.block_cols).tobytes())
    return h.hexdigest()[:16]


def hardware_fingerprint() -> str:
    """Device identity the measurements are valid for — a persisted cache
    recorded on one machine never silently serves another."""
    import jax
    dev = jax.devices()[0]
    kind = str(getattr(dev, "device_kind", dev.platform)).replace(" ", "_")
    return f"{jax.default_backend()}:{kind}x{jax.device_count()}"


def _batch_bucket(batch: int) -> int:
    """Round the batch up to a power of two: one cache entry per regime of
    batch sizes, not per exact batch."""
    return 1 << max(0, int(batch) - 1).bit_length()


# -- candidate enumeration + prediction --------------------------------------
def candidate_schedules(plan: ExecutionPlan, mode: str,
                        backends=BACKENDS) -> list:
    """Every *valid* schedule in the search grid.

    Budgets sweep the regime axis (``None`` = forced resident, then
    halvings of the default that push big matrices into pipelined bands);
    crossovers sweep the matmul/shift-add split (int8 only — fp32 has no
    digit planes to strength-reduce, so its crossover is pinned to the
    default and the axis collapses); batch tiles sweep grid parallelism.
    Candidates whose band packing is infeasible (a single column's folded
    tiles overflow half the budget — ``specialize_rollout`` would raise)
    are dropped here, so everything returned can actually build.
    """
    block = plan.block
    budgets = [None, DEFAULT_VMEM_BUDGET, DEFAULT_VMEM_BUDGET // 2,
               DEFAULT_VMEM_BUDGET // 4]
    if mode == "fp32":
        crossovers = [default_crossover(block)]
    else:
        crossovers = sorted({0, block // 4, default_crossover(block),
                             block, 2 * block})
    tiles = sorted({8, DEFAULT_BATCH_TILE, 32})
    out, seen = [], set()
    for backend in backends:
        for budget in budgets:
            for crossover in crossovers:
                for tile in tiles:
                    try:
                        specialize_summary(plan, mode, vmem_budget=budget,
                                           crossover=crossover,
                                           batch_tile_max=tile)
                    except ValueError:
                        continue  # infeasible double-buffer packing
                    s = Schedule(mode, backend, budget, crossover, tile)
                    if s.key() not in seen:
                        seen.add(s.key())
                        out.append(s)
    return out


def predict_cost(plan: ExecutionPlan, schedule: Schedule, batch: int,
                 steps: int,
                 model: costmodel.RolloutCostModel | None = None) -> float:
    """Analytic seconds for one rollout under ``schedule`` — counts-only
    summary in, calibrated linear model out.  Never compiles anything."""
    if model is None:
        model = _default_model()
    summary = specialize_summary(
        plan, schedule.mode, vmem_budget=schedule.vmem_budget,
        crossover=schedule.crossover,
        batch_tile_max=schedule.batch_tile_max)
    feats = costmodel.rollout_cost_features(summary, plan.block, batch,
                                            steps)
    return model.predict(schedule.backend, feats)


_MODEL_CACHE: dict = {}


def _default_model() -> costmodel.RolloutCostModel:
    import jax
    platform = jax.default_backend()
    model = _MODEL_CACHE.get(platform)
    if model is None:
        model = _MODEL_CACHE[platform] = \
            costmodel.default_rollout_cost_model(platform)
    return model


def set_cost_model(model: costmodel.RolloutCostModel) -> None:
    """Install a calibrated model as the default predictor (e.g. one
    refit from measured bench rows)."""
    _MODEL_CACHE[model.platform] = model


# -- measurement -------------------------------------------------------------
def _probe_params(plan: ExecutionPlan, mode: str):
    """Synthetic ESNParams over the plan's own matrix, for measuring when
    the caller has no trained params at hand (the matrix is what matters;
    w_in only sets the projection gemm's inner dim)."""
    from repro.core.esn import ESNConfig, ESNParams
    fm = plan._fm
    dim = plan.shape[0]
    digit = fm.mode if fm.mode in ("pn", "csd") else "csd"
    esn_mode = f"int8-{digit}" if mode == "int8" else "fp32"
    cfg = ESNConfig(reservoir_dim=dim, input_dim=4, mode=esn_mode)
    rng = np.random.default_rng(0)
    w_in = np.asarray(rng.standard_normal((4, dim)) * 0.1, np.float32)
    return ESNParams(config=cfg, w=fm, w_in=w_in)


def _measure_schedule(plan: ExecutionPlan, schedule: Schedule, params,
                      batch: int, steps: int, reps: int = 2) -> float:
    """Wall-clock one candidate through the real engine path (compile
    excluded; best-of-reps, matching the bench harness convention)."""
    import jax
    import jax.numpy as jnp

    from repro.serve.engine import ReservoirEngine  # deferred: serve imports plan

    eng = ReservoirEngine(
        params, backend=schedule.backend,
        vmem_budget=schedule.vmem_budget, crossover=schedule.crossover,
        batch_tile_max=schedule.batch_tile_max, specialize=True)
    rng = np.random.default_rng(1)
    u = jnp.asarray(rng.standard_normal(
        (batch, steps, params.config.input_dim)), jnp.float32)
    jax.block_until_ready(eng.rollout(u))          # compile outside the clock
    best = float("inf")
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(eng.rollout(u))
        best = min(best, time.perf_counter() - t0)
    return best


# -- persisted schedule cache ------------------------------------------------
class ScheduleCache:
    """``(plan fingerprint, mode, batch bucket, hardware) -> TunedSchedule``
    with JSON persistence, so a serve process can load the winners a bench
    run measured and never re-tune at startup."""

    VERSION = 1

    def __init__(self):
        self._entries: dict = {}
        self.hits = 0
        self.misses = 0

    @staticmethod
    def entry_key(fingerprint: str, mode: str, batch: int,
                  hardware: str) -> str:
        return f"{fingerprint}|{mode}|b{_batch_bucket(batch)}|{hardware}"

    def get(self, key: str):
        tuned = self._entries.get(key)
        if tuned is None:
            self.misses += 1
        else:
            self.hits += 1
        return tuned

    def put(self, key: str, tuned: TunedSchedule) -> None:
        self._entries[key] = tuned

    def __len__(self) -> int:
        return len(self._entries)

    def clear(self) -> None:
        self._entries.clear()
        self.hits = self.misses = 0

    def stats(self) -> dict:
        return {"size": len(self._entries), "hits": self.hits,
                "misses": self.misses}

    def as_dict(self) -> dict:
        return {"version": self.VERSION,
                "entries": {k: t.as_dict()
                            for k, t in sorted(self._entries.items())}}

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.as_dict(), f, indent=1, sort_keys=True)

    def load(self, path, merge: bool = True) -> int:
        """Merge (or replace) entries from ``path``; returns the number of
        entries loaded.  Entries replay as ``source="cache"``."""
        with open(path) as f:
            data = json.load(f)
        if data.get("version") != self.VERSION:
            raise ValueError(
                f"schedule cache version {data.get('version')} != "
                f"{self.VERSION}: re-tune rather than trust stale entries")
        if not merge:
            self._entries.clear()
        n = 0
        for key, d in data.get("entries", {}).items():
            self._entries[key] = dataclasses.replace(
                TunedSchedule.from_dict(d), source="cache")
            n += 1
        return n


_CACHE = ScheduleCache()


def autotune_cache() -> ScheduleCache:
    """The process-wide tuning cache (engine construction resolves
    through it)."""
    return _CACHE


def autotune_cache_save(path) -> None:
    _CACHE.save(path)


def autotune_cache_load(path, merge: bool = True) -> int:
    return _CACHE.load(path, merge=merge)


# -- resolution: the one entry point engines call ----------------------------
def resolve_schedule(plan: ExecutionPlan, mode: str, *,
                     backend: str = "auto", batch: int = TUNE_BATCH,
                     steps: int = TUNE_STEPS, measure: bool = False,
                     params=None, top_k: int = 3, reps: int = 2,
                     model: costmodel.RolloutCostModel | None = None,
                     cache: ScheduleCache | None = None,
                     refresh: bool = False) -> TunedSchedule:
    """The tuner's front door: cache -> predict [-> prune -> measure].

    ``measure=False`` (engine construction) never compiles or times
    anything: a cache hit replays the persisted winner, a miss falls back
    to the analytic model's pick.  ``measure=True`` (benchmarks, explicit
    ``autotune_rollout``) runs the full loop and caches the measured
    winner, which subsequent engine constructions then inherit.  An
    explicit ``backend`` restricts the search to that backend.
    """
    assert mode in ("fp32", "int8"), mode
    cache = _CACHE if cache is None else cache
    backends = BACKENDS if backend == "auto" else (backend,)
    hw = hardware_fingerprint()
    key = "|".join((ScheduleCache.entry_key(
        plan_fingerprint(plan), mode, batch, hw),) + backends)
    if not refresh:
        tuned = cache.get(key)
        if tuned is not None and (tuned.source == "measured"
                                  or tuned.measured_s is not None
                                  or not measure):
            _pin_to_plan(plan, mode, batch, hw, tuned)
            obs.event("schedule_resolve", source=tuned.source, mode=mode,
                      schedule=tuned.schedule.describe())
            obs.inc("schedule_cache_requests_total", outcome="hit")
            return tuned
    model = _default_model() if model is None else model
    cands = candidate_schedules(plan, mode, backends)
    if not cands:
        cands = [default_schedule(plan, mode, backends[0])]
    scored = sorted(
        ((predict_cost(plan, s, batch, steps, model), s) for s in cands),
        key=lambda t: (t[0], t[1].sort_key()))
    default = default_schedule(plan, mode,
                               "xla" if "xla" in backends else backends[0])
    default_pred = predict_cost(plan, default, batch, steps, model)

    if not measure:
        pred, best = scored[0]
        tuned = TunedSchedule(
            schedule=best, batch=batch, steps=steps, predicted_s=pred,
            default_predicted_s=default_pred, source="predicted",
            n_candidates=len(cands))
    else:
        chosen = scored[:max(1, top_k)]
        if not any(s.key() == default.key() for _p, s in chosen):
            chosen.append((default_pred, default))
        trials = []
        for pred, s in chosen:
            t0 = time.perf_counter()
            meas = _measure_schedule(plan, s, params, batch, steps, reps)
            obs.span("autotune.trial", t0, time.perf_counter(),
                     clock="wall", schedule=s.describe(),
                     predicted_s=pred, measured_s=meas)
            trials.append((s, pred, meas))
        win_sched, win_pred, win_meas = min(
            trials, key=lambda t: (t[2], t[0].sort_key()))
        default_meas = next(m for s, _p, m in trials
                            if s.key() == default.key())
        tuned = TunedSchedule(
            schedule=win_sched, batch=batch, steps=steps,
            predicted_s=win_pred, measured_s=win_meas,
            default_predicted_s=default_pred,
            default_measured_s=default_meas, source="measured",
            n_candidates=len(cands),
            trials=tuple((s.as_dict(), p, m) for s, p, m in trials))
    cache.put(key, tuned)
    _pin_to_plan(plan, mode, batch, hw, tuned)
    obs.event("schedule_resolve", source=tuned.source, mode=mode,
              schedule=tuned.schedule.describe())
    obs.inc("schedule_cache_requests_total", outcome="miss")
    return tuned


def _pin_to_plan(plan: ExecutionPlan, mode: str, batch: int, hw: str,
                 tuned: TunedSchedule) -> None:
    pinned = getattr(plan, "_tuned", None)
    if pinned is None:
        pinned = plan._tuned = {}
    pinned[(mode, _batch_bucket(batch), hw)] = tuned


def autotune_rollout(plan: ExecutionPlan, mode: str, *,
                     batch: int = TUNE_BATCH, steps: int = TUNE_STEPS,
                     params=None, backends=BACKENDS, top_k: int = 3,
                     reps: int = 2,
                     model: costmodel.RolloutCostModel | None = None,
                     cache: ScheduleCache | None = None,
                     refresh: bool = False) -> TunedSchedule:
    """Run the full predict -> prune -> measure -> cache loop for one plan.

    The measured winner can never lose to the default-heuristic schedule
    on its own trials: the default is always among the measured candidates
    and the winner is the measured argmin.
    """
    backend = "auto" if tuple(backends) == BACKENDS else backends[0]
    return resolve_schedule(
        plan, mode, backend=backend, batch=batch, steps=steps,
        measure=True, params=params, top_k=top_k, reps=reps, model=model,
        cache=cache, refresh=refresh)


def resolve_backend(params, backend: str = "auto",
                    batch: int = TUNE_BATCH) -> str:
    """The backend ``backend="auto"`` resolves to for these params — the
    one function ``engine_for``'s cache key AND ``ReservoirEngine``'s
    constructor both route through, so they can never disagree."""
    if backend != "auto":
        return backend
    from repro.plan.plan import plan_for
    plan = plan_for(params.w)
    mode = "int8" if params.config.mode.startswith("int8") else "fp32"
    return resolve_schedule(plan, mode, batch=batch).schedule.backend
