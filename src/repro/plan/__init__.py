"""Unified matrix -> ExecutionPlan compiler (the paper's synthesis step).

One offline lowering of a :class:`repro.core.sparse.FixedMatrix` produces
every static artifact the kernels, the serve engine and the cost reports
consume: gathered nonzero tiles, per-column reduction term lists (with
block- and plane-level culling), whole-plane masks, VMEM-banded rollout
layouts, the sorted BCSR tile list, and the FPGA cost model attached to
the exact decomposed structure.
"""

from repro.plan.plan import (
    DEFAULT_VMEM_BUDGET,
    BandedRollout,
    BcsrLayout,
    ExecutionPlan,
    PlanStats,
    RolloutBand,
    plan_for,
)

__all__ = [
    "DEFAULT_VMEM_BUDGET",
    "BandedRollout",
    "BcsrLayout",
    "ExecutionPlan",
    "PlanStats",
    "RolloutBand",
    "plan_for",
]
