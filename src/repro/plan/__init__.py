"""Unified matrix -> ExecutionPlan compiler (the paper's synthesis step).

One offline lowering of a :class:`repro.core.sparse.FixedMatrix` produces
every static artifact the kernels, the serve engine and the cost reports
consume: gathered nonzero tiles, per-column reduction term lists (with
block- and plane-level culling), whole-plane masks, VMEM-banded rollout
layouts, the sorted BCSR tile list, and the FPGA cost model attached to
the exact decomposed structure.  :mod:`repro.plan.autotune` closes the
loop: it searches the specialization's schedule space (regime, crossover,
band budget, batch tile, backend) with a calibrated cost model plus
measured-cost feedback, and caches the winner per (plan, hardware).
"""

from repro.plan.autotune import (
    Schedule,
    ScheduleCache,
    TunedSchedule,
    autotune_cache,
    autotune_cache_load,
    autotune_cache_save,
    autotune_rollout,
    candidate_schedules,
    default_schedule,
    plan_fingerprint,
    resolve_backend,
    resolve_schedule,
)
from repro.plan.plan import (
    DEFAULT_VMEM_BUDGET,
    BandedRollout,
    BcsrLayout,
    ExecutionPlan,
    PlanStats,
    RolloutBand,
    plan_cache_stats,
    plan_for,
)
from repro.plan.specialize import (
    DEFAULT_BATCH_TILE,
    RolloutProgram,
    specialize_rollout,
    specialize_summary,
)

__all__ = [
    "DEFAULT_BATCH_TILE",
    "DEFAULT_VMEM_BUDGET",
    "BandedRollout",
    "BcsrLayout",
    "ExecutionPlan",
    "PlanStats",
    "RolloutBand",
    "RolloutProgram",
    "Schedule",
    "ScheduleCache",
    "TunedSchedule",
    "autotune_cache",
    "autotune_cache_load",
    "autotune_cache_save",
    "autotune_rollout",
    "candidate_schedules",
    "default_schedule",
    "plan_cache_stats",
    "plan_fingerprint",
    "plan_for",
    "resolve_backend",
    "resolve_schedule",
    "specialize_rollout",
    "specialize_summary",
]
