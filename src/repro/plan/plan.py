"""ExecutionPlan: compile a FixedMatrix once, execute it everywhere.

The paper's design flow takes the *content* of a fixed matrix and compiles
it to a physical design exactly once — constant propagation culls degenerate
adders, CSD minimizes the remaining logic — and the resulting circuit makes
zero per-step decisions.  This module is the TPU-side analogue of that
synthesis step: :class:`ExecutionPlan` lowers one compiled
:class:`repro.core.sparse.FixedMatrix` into the static artifacts every
consumer needs, so no kernel wrapper re-derives them ad hoc:

* gathered fp32 nonzero tiles + per-column reduction terms (block culling),
* int8 digit-plane tiles + per-column ``(plane, tile, row_block)`` terms
  with plane-level culling on top of block-level culling,
* whole-plane keep masks and MXU-padded signed digits (bitplane gemv),
* the sorted/zero-padded BCSR tile list (bcsr matmul),
* VMEM-banded rollout layouts: output column blocks partitioned into bands
  whose resident weight tiles fit a configurable VMEM budget, so large
  (dim-2048 fp32) rollouts compile instead of overflowing scratch,
* the FPGA cost model evaluated on the exact decomposed structure
  (ones -> LUT/FF/Fmax/power, Eq. 5 latency).

Plans are cached per FixedMatrix instance (``plan_for``): the matrix is
frozen, so the lowering is paid once per process, like place-and-route is
paid once per bitstream.
"""

from __future__ import annotations

import dataclasses
import functools

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import costmodel
from repro.core.sparse import BlockSparse, FixedMatrix

__all__ = [
    "DEFAULT_VMEM_BUDGET",
    "BandedRollout",
    "BcsrLayout",
    "ExecutionPlan",
    "PlanStats",
    "RolloutBand",
    "plan_cache_stats",
    "plan_for",
]

# Default per-band budget for rollout weight tiles resident in VMEM.  A TPU
# core has ~16 MiB of VMEM; half of it is left for state scratch, inputs,
# outputs and double buffering.
DEFAULT_VMEM_BUDGET = 8 * 2**20


def pad_axis(a: np.ndarray, axis: int, size: int) -> np.ndarray:
    """Zero-pad one axis up to ``size`` (shared by the kernel wrappers)."""
    pad = size - a.shape[axis]
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return np.pad(a, widths)


@dataclasses.dataclass(frozen=True)
class PlanStats:
    """What the compile step kept vs culled — the paper's Fig. 5-9 metrics."""

    block: int
    blocks_total: int
    blocks_nnz: int
    width: int                 # digit planes after PN/CSD decomposition
    fp32_terms_kept: int       # == blocks_nnz (one reduction term per tile)
    fp32_terms_culled: int     # zero blocks dropped at compile time
    int8_terms_kept: int       # (plane, block) pairs with any set digit
    int8_terms_culled: int     # vs the dense width x blocks_total structure
    planes_kept: int           # whole planes with any set digit
    planes_culled: int
    ones: int                  # set digit bits, the paper's cost driver

    @property
    def block_density(self) -> float:
        return self.blocks_nnz / max(self.blocks_total, 1)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["block_density"] = self.block_density
        return d


@dataclasses.dataclass(frozen=True)
class BcsrLayout:
    """Sorted/padded tile list for the BCSR matmul kernel.

    Tiles are sorted by (col, row) so each output tile accumulates on
    consecutive grid steps, and every empty output column gets one zero
    tile so initialization covers the whole output.
    """

    shape: tuple[int, int]
    block: int
    rows_pad: int
    cols_pad: int
    data: jnp.ndarray          # (n_tiles, block, block)
    cols: jnp.ndarray          # (n_tiles,) int32
    rows: jnp.ndarray          # (n_tiles,) int32
    n_tiles: int

    @classmethod
    def from_blocks(cls, bs: BlockSparse) -> "BcsrLayout":
        nbr, nbc = bs.mask.shape
        data = np.asarray(bs.data)
        cols = bs.block_cols.astype(np.int32)
        rows = bs.block_rows.astype(np.int32)
        missing = sorted(set(range(nbc)) - set(cols.tolist()))
        if missing:
            zero = np.zeros((len(missing), bs.block, bs.block), data.dtype)
            data = np.concatenate([data, zero], axis=0) if data.size else zero
            cols = np.concatenate([cols, np.asarray(missing, np.int32)])
            rows = np.concatenate([rows, np.zeros(len(missing), np.int32)])
        order = np.lexsort((rows, cols))
        return cls(shape=bs.shape, block=bs.block,
                   rows_pad=nbr * bs.block, cols_pad=nbc * bs.block,
                   data=jnp.asarray(data[order]),
                   cols=jnp.asarray(cols[order]),
                   rows=jnp.asarray(rows[order]),
                   n_tiles=int(data.shape[0]))


@dataclasses.dataclass(frozen=True)
class RolloutBand:
    """One VMEM-resident slice of the rollout reduction.

    ``col_terms`` lists, for each output column block this band owns, the
    static reduction terms ``(slot, shift, row_block)``: ``slot`` indexes
    this band's row of the banded data array, ``shift`` is the digit-plane
    shift (0 in fp32 mode), ``row_block`` selects the state slice.
    """

    index: int
    col_lo: int                # first output column block (inclusive)
    col_hi: int                # last output column block (exclusive)
    col_terms: tuple           # ((ci, ((slot, shift, row_block), ...)), ...)
    n_terms: int
    data_bytes: int            # this band's real tile payload

    @property
    def n_cols(self) -> int:
        return self.col_hi - self.col_lo


@dataclasses.dataclass(frozen=True)
class BandedRollout:
    """Rollout lowering: banded tile data + static per-band term plans."""

    mode: str                  # "fp32" | "int8"
    block: int
    data: jnp.ndarray          # (n_bands, max_terms, block, block)
    bands: tuple               # tuple[RolloutBand, ...]
    max_terms: int
    vmem_budget: int | None    # None: unbanded (single band)

    @property
    def n_bands(self) -> int:
        return len(self.bands)

    @property
    def n_terms(self) -> int:
        return sum(b.n_terms for b in self.bands)

    @property
    def band_data_bytes(self) -> int:
        """Weight-tile bytes resident in VMEM while any band executes
        (bands share one padded block shape, so this is uniform)."""
        itemsize = np.dtype(self.data.dtype).itemsize
        return self.max_terms * self.block * self.block * itemsize

    def band_plans(self) -> tuple:
        """Static nested tuple the kernel unrolls: one entry per band."""
        return tuple(b.col_terms for b in self.bands)


class ExecutionPlan:
    """All static artifacts of one compiled FixedMatrix, derived once.

    Heavyweight artifacts (digit planes, int8 tiles, the BCSR layout) are
    cached properties so an fp32-only consumer never pays for the integer
    lowering and vice versa.
    """

    def __init__(self, fm: FixedMatrix):
        self._fm = fm
        bs = fm.blocks
        self.shape = fm.shape
        self.block = bs.block
        self.nbr, self.nbc = bs.mask.shape
        self.rows_pad = self.nbr * self.block
        self.cols_pad = self.nbc * self.block
        self.mode = fm.mode
        self.weight_bits = fm.weight_bits
        self.scale = fm.scale
        self.element_sparsity = fm.element_sparsity
        self.block_rows = bs.block_rows
        self.block_cols = bs.block_cols
        self.blocks_total = bs.n_blocks_total
        self.blocks_nnz = bs.n_blocks_nnz
        self.block_density = bs.density
        self._layouts: dict = {}

    # -- float lowering -----------------------------------------------------
    @functools.cached_property
    def fp32_tiles(self) -> np.ndarray:
        """(n_nnz, block, block) float32 dequantized nonzero tiles."""
        return np.asarray(self._fm.blocks.data, np.float32)

    # -- integer lowering ---------------------------------------------------
    @functools.cached_property
    def digits(self) -> np.ndarray:
        """(width, rows, cols) int8 signed digits with V = sum 2^w d_w."""
        planes = self._fm.planes
        return planes.pos.astype(np.int8) - planes.neg.astype(np.int8)

    @property
    def width(self) -> int:
        return int(self.digits.shape[0])

    @functools.cached_property
    def plane_mask(self) -> tuple:
        """Whole-plane keep flags (CSD often leaves high planes empty)."""
        return tuple(bool(np.any(self.digits[w])) for w in range(self.width))

    @functools.cached_property
    def int8_tiles(self) -> np.ndarray:
        """(width, n_nnz, block, block) int8 digit tiles over the same
        nonzero-block list as ``fp32_tiles``."""
        bk = self.block
        dig = pad_axis(pad_axis(self.digits, 1, self.rows_pad),
                        2, self.cols_pad)
        tiles = dig.reshape(self.width, self.nbr, bk, self.nbc, bk
                            ).transpose(0, 1, 3, 2, 4)
        return tiles[:, self.block_rows, self.block_cols]

    @functools.cached_property
    def plane_block_mask(self) -> np.ndarray:
        """(width, n_nnz) bool: plane-level culling on top of block-level
        culling — a reduction term exists only where that plane of that
        block has any set digit."""
        return np.any(self.int8_tiles != 0, axis=(2, 3))

    def padded_digits(self, block_r: int = 128, block_c: int = 128) -> jnp.ndarray:
        """Signed digits padded to MXU-aligned multiples for bitplane_gemv."""
        dig = self.digits
        dig = pad_axis(dig, 1, -(-dig.shape[1] // block_r) * block_r)
        dig = pad_axis(dig, 2, -(-dig.shape[2] // block_c) * block_c)
        return jnp.asarray(dig)

    # -- BCSR lowering ------------------------------------------------------
    @functools.cached_property
    def bcsr(self) -> BcsrLayout:
        return BcsrLayout.from_blocks(self._fm.blocks)

    # -- rollout lowering (banded) ------------------------------------------
    def _col_term_descriptors(self, mode: str) -> list:
        """Per output column block, the ordered reduction terms as
        ``(tile_idx, shift, row_block)`` — ascending row order (fp32) /
        (tile, plane) order (int8), matching the reference accumulation."""
        rows, cols = self.block_rows, self.block_cols
        out = []
        for ci in range(self.nbc):
            tiles = np.flatnonzero(cols == ci)
            if mode == "fp32":
                out.append([(int(di), 0, int(rows[di])) for di in tiles])
            else:
                keep = self.plane_block_mask
                out.append([(int(di), w, int(rows[di]))
                            for di in tiles for w in range(self.width)
                            if keep[w, di]])
        return out

    def col_terms(self, mode: str = "fp32") -> tuple:
        """Per output column block, the ordered reduction terms as
        ``(tile_idx, shift, row_block)`` tuples (shift is 0 in fp32 mode).
        Culled blocks — and, in int8 mode, culled plane-blocks — never
        appear."""
        return tuple(tuple(ts) for ts in self._col_term_descriptors(mode))

    def _tile_bytes(self, mode: str) -> int:
        itemsize = 4 if mode == "fp32" else 1
        return self.block * self.block * itemsize

    def _col_term_counts(self, mode: str) -> np.ndarray:
        """Reduction terms per output column block — enough to band without
        gathering any tile data (fp32 never touches the integer lowering)."""
        if mode == "fp32":
            return np.bincount(self.block_cols, minlength=self.nbc)
        counts = np.zeros(self.nbc, np.int64)
        np.add.at(counts, self.block_cols, self.plane_block_mask.sum(axis=0))
        return counts

    def band_partition(self, mode: str = "fp32",
                       vmem_budget: int | None = DEFAULT_VMEM_BUDGET,
                       ) -> tuple:
        """Greedy packing of output column blocks into budget-sized bands.

        Returns ``((col_lo, col_hi, n_terms), ...)`` — the stats/reporting
        view of banding, computed from per-column term *counts* only so
        cost summaries never pay for the tile gather (``rollout_layout``
        reuses the same partition to build the actual banded data).
        ``vmem_budget=None`` yields one unbanded band.
        """
        assert mode in ("fp32", "int8"), mode
        tile_bytes = self._tile_bytes(mode)
        counts = self._col_term_counts(mode)
        spans: list[list[int]] = [[0, 0, 0]]       # [col_lo, col_hi, n_terms]
        for ci in range(self.nbc):
            n = int(counts[ci])
            if vmem_budget is not None and n * tile_bytes > vmem_budget:
                raise ValueError(
                    f"column block {ci} alone needs {n * tile_bytes} B of "
                    f"tiles > vmem_budget={vmem_budget}; raise the budget "
                    f"or compile with a smaller block than {self.block}")
            last = spans[-1]
            if (vmem_budget is not None and last[1] > last[0]
                    and (last[2] + n) * tile_bytes > vmem_budget):
                spans.append([ci, ci, 0])
                last = spans[-1]
            last[1] = ci + 1
            last[2] += n
        return tuple((lo, hi, n) for lo, hi, n in spans)

    def band_summary(self, mode: str = "fp32",
                     vmem_budget: int | None = DEFAULT_VMEM_BUDGET,
                     ) -> tuple:
        """(n_bands, resident_tile_bytes_per_band) — the reporting view of
        banding, no tile data gathered."""
        spans = self.band_partition(mode, vmem_budget)
        return (len(spans),
                max(n for _lo, _hi, n in spans) * self._tile_bytes(mode))

    def rollout_layout(self, mode: str = "fp32",
                       vmem_budget: int | None = DEFAULT_VMEM_BUDGET,
                       ) -> BandedRollout:
        """Lower the recurrent reduction into VMEM-sized bands.

        Output column blocks are packed per :meth:`band_partition`; each
        term's tile is gathered into the band's row of one padded
        ``(n_bands, max_terms, block, block)`` array, so a Pallas BlockSpec
        can stream exactly one band's tiles into VMEM per grid step.
        """
        assert mode in ("fp32", "int8"), mode
        key = (mode, vmem_budget)
        if key in self._layouts:
            return self._layouts[key]
        bk = self.block
        col_terms = self._col_term_descriptors(mode)
        if mode == "fp32":
            source, dtype = self.fp32_tiles, np.float32
            tile_of = lambda di, w: source[di]                    # noqa: E731
        else:
            source, dtype = self.int8_tiles, np.int8
            tile_of = lambda di, w: source[w, di]                 # noqa: E731
        tile_bytes = self._tile_bytes(mode)

        bands: list[RolloutBand] = []
        band_data: list[np.ndarray] = []
        for bi, (lo, hi, _n) in enumerate(
                self.band_partition(mode, vmem_budget)):
            tiles, terms = [], []
            for ci in range(lo, hi):
                slots = []
                for di, w, ri in col_terms[ci]:
                    slots.append((len(tiles), w, ri))
                    tiles.append(tile_of(di, w))
                terms.append((ci, tuple(slots)))
            bands.append(RolloutBand(
                index=bi, col_lo=lo, col_hi=hi,
                col_terms=tuple(terms), n_terms=len(tiles),
                data_bytes=len(tiles) * tile_bytes))
            band_data.append(np.stack(tiles) if tiles
                             else np.zeros((0, bk, bk), dtype))
        max_terms = max(1, max(b.n_terms for b in bands))
        data = np.zeros((len(bands), max_terms, bk, bk), dtype)
        for bi, tiles in enumerate(band_data):
            data[bi, : tiles.shape[0]] = tiles
        layout = BandedRollout(mode=mode, block=bk, data=jnp.asarray(data),
                               bands=tuple(bands), max_terms=max_terms,
                               vmem_budget=vmem_budget)
        self._layouts[key] = layout
        return layout

    # -- cost reporting -----------------------------------------------------
    @functools.cached_property
    def stats(self) -> PlanStats:
        kept = int(self.plane_block_mask.sum())
        width = self.width
        return PlanStats(
            block=self.block,
            blocks_total=self.blocks_total,
            blocks_nnz=self.blocks_nnz,
            width=width,
            fp32_terms_kept=self.blocks_nnz,
            fp32_terms_culled=self.blocks_total - self.blocks_nnz,
            int8_terms_kept=kept,
            int8_terms_culled=width * self.blocks_total - kept,
            planes_kept=sum(self.plane_mask),
            planes_culled=width - sum(self.plane_mask),
            ones=self._fm.ones,
        )

    def specialize_summary_line(
            self, mode: str = "fp32",
            vmem_budget: int | None = DEFAULT_VMEM_BUDGET) -> str:
        """One-line regime report of the specialized rollout program: the
        chosen weight-residency regime, on-chip bytes, and how the terms
        split between folded-tile matmuls and shift-add reductions."""
        from repro.plan.specialize import specialize_summary
        s = specialize_summary(self, mode, vmem_budget=vmem_budget)
        return (f"{s['mode']} {s['regime']} ({s['n_bands']} band(s), "
                f"{s['resident_bytes']} B on-chip), "
                f"{s['n_matmul_terms']} matmul terms + "
                f"{s['n_shiftadd_terms']} shift-add terms "
                f"({s['shiftadd_digits']} digit adds)")

    def fpga_cost(self, input_bits: int = 8) -> costmodel.FPGADesignPoint:
        """The paper's synthesis estimate for this exact structure."""
        return costmodel.design_point(
            rows=self.shape[0], cols=self.shape[1],
            element_sparsity=self.element_sparsity,
            weight_bits=self.weight_bits, input_bits=input_bits,
            mode=self.mode, ones=self._fm.ones)

    def describe(self, input_bits: int = 8,
                 vmem_budget: int | None = DEFAULT_VMEM_BUDGET) -> str:
        """Human-readable compile summary: structure kept/culled + FPGA cost.

        When the autotuner has resolved a schedule for this plan
        (:func:`repro.plan.autotune.resolve_schedule` — every
        ``backend="auto"`` engine construction does), one ``autotuned``
        line per tuning decision reports the chosen backend / band budget /
        crossover / batch tile and the predicted vs measured rollout cost
        behind it.
        """
        s = self.stats
        dp = self.fpga_cost(input_bits)
        # partition only — cost summaries must not pay for the tile gather
        n_bands, band_bytes = self.band_summary("fp32",
                                                vmem_budget=vmem_budget)
        lines = [
            f"ExecutionPlan {self.shape[0]}x{self.shape[1]} block={self.block} "
            f"mode={self.mode} weight_bits={self.weight_bits}",
            f"  blocks: {s.blocks_nnz}/{s.blocks_total} kept "
            f"({s.fp32_terms_culled} culled, density {s.block_density:.2f})",
            f"  int8 plane-terms: {s.int8_terms_kept} kept / "
            f"{s.int8_terms_culled} culled (planes {s.planes_kept}/{s.width})",
            f"  rollout bands (fp32, budget {vmem_budget} B): "
            f"{n_bands} x <= {band_bytes} B tiles",
            "  specialized: " + self.specialize_summary_line(
                "fp32", vmem_budget),
            "  specialized: " + self.specialize_summary_line(
                "int8", vmem_budget),
            f"  FPGA: ones={s.ones}  LUTs={dp.luts:.0f}  FFs={dp.ffs:.0f}  "
            f"Fmax={dp.fmax_hz / 1e6:.0f} MHz",
            f"  Eq.5 latency: {dp.cycles} cycles = {dp.latency_ns:.1f} ns  "
            f"power = {dp.power_w:.1f} W",
        ]
        for (mode, bucket, hw), tuned in sorted(
                getattr(self, "_tuned", {}).items(), key=repr):
            lines.append(f"  autotuned[{mode} b<={bucket} {hw}]: "
                         + tuned.describe())
        return "\n".join(lines)


# plan_for cache telemetry.  The cache itself is the matrix instance (the
# plan rides on ``fm._execution_plan``), so its lifetime is exactly the
# matrix's — a weakref-per-matrix policy with no process-global growth for
# a long-lived multi-tenant server to worry about.  The counters let such
# a server verify that property (and spot a caller accidentally
# re-compiling matrices instead of reusing them).
_PLAN_CACHE_STATS: dict = {"hits": 0, "misses": 0, "tenants": {}}


def plan_cache_stats(reset: bool = False) -> dict:
    """Cumulative plan_for hit/miss counters (``reset=True`` zeroes them).

    ``tenants`` breaks the counters down by the registry model name passed
    through ``plan_for(..., tenant=...)`` — a multi-tenant server can
    verify per model that republishing reuses cached lowerings instead of
    re-planning."""
    out = dict(_PLAN_CACHE_STATS)
    out["tenants"] = {name: dict(c)
                     for name, c in _PLAN_CACHE_STATS["tenants"].items()}
    if reset:
        _PLAN_CACHE_STATS.update(hits=0, misses=0)
        _PLAN_CACHE_STATS["tenants"].clear()
    return out


def plan_for(fm: FixedMatrix, tenant: str | None = None) -> ExecutionPlan:
    """The ExecutionPlan for a compiled matrix, cached per instance.

    FixedMatrix is frozen by construction, so the plan — like the paper's
    place-and-route result — is computed at most once per matrix, and it
    is released exactly when the matrix is: the cache slot lives on the
    instance, never in a process-global table.  ``tenant`` (a registry
    model name) attributes the hit/miss to that tenant's counters in
    :func:`plan_cache_stats`.
    """
    plan = getattr(fm, "_execution_plan", None)
    hit = plan is not None and plan._fm is fm
    if not hit:
        with obs.timed_span("plan.lower", tenant=tenant):
            plan = ExecutionPlan(fm)
        fm._execution_plan = plan
        obs.event("plan_lowering", shape=str(fm.shape), tenant=tenant)
    _PLAN_CACHE_STATS["hits" if hit else "misses"] += 1
    obs.inc("plan_cache_requests_total",
            outcome="hit" if hit else "miss",
            **({} if tenant is None else {"tenant": tenant}))
    if tenant is not None:
        tenants = _PLAN_CACHE_STATS["tenants"]
        c = tenants.setdefault(tenant, {"hits": 0, "misses": 0})
        c["hits" if hit else "misses"] += 1
    return plan
