"""Plan specialization: lower an ExecutionPlan into a rollout *program*.

The paper's design flow does not stop at knowing the matrix structure — it
compiles the structure *into the computation*: constant propagation deletes
work (zero digits cost nothing), CSD logic minimization strength-reduces
what remains, and the matrix stays spatially resident so it is never
re-fetched.  This module is the software synthesis step that buys the
:class:`~repro.plan.plan.ExecutionPlan`'s static knowledge back as speed.
``specialize_rollout`` turns one plan into a :class:`RolloutProgram`:

* **regime selection** — when every kept weight tile fits the VMEM budget
  the program is ``resident``: tiles are hoisted on-chip once and the
  ``(T, B_tiles)`` grid iterates with *zero* per-step weight traffic.
  Otherwise the program is ``pipelined``: output columns are packed into
  bands of at most half the budget, so the Pallas pipeline can prefetch
  band ``k+1`` while band ``k`` reduces (double buffering).
* **constant-propagated CSD folding** (int8 modes) — the per-plane
  ``2^w`` scales and digit signs are trace-time constants, so all planes
  of a block that stay on the matmul path fold into ONE int8 tile
  (``sum_w 2^w d_w`` — exactly the quantized block, by construction):
  one int32 MXU product replaces ``width`` shifted plane products, with
  bit-identical results because int32 accumulation is exact.
* **shift-add strength reduction** — a digit plane of a block whose
  ``ones`` count falls below the plan-computed crossover skips the matmul
  entirely: its few set digits are emitted as static shift-add terms
  (``acc[:, j] += ±(x[:, i] << w)``), the software mirror of the paper's
  synthesized adder trees.
* **batch tiling** — the batch axis splits into tiles of at most
  ``batch_tile_max`` rows, so a batch-64 rollout runs as grid-parallel
  batch tiles instead of one monolithic VMEM block.

Every schedule is arithmetic-order-safe: int8 terms accumulate in exact
int32 (any order gives the same bits) and fp32 terms keep the banded
kernel's ascending-row order — so the specialized program is bit-identical
to the generic banded kernel in every regime (property-tested).
"""

from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.plan.plan import DEFAULT_VMEM_BUDGET, ExecutionPlan

__all__ = [
    "MM",
    "SA",
    "DEFAULT_BATCH_TILE",
    "RolloutProgram",
    "specialize_rollout",
    "specialize_summary",
    "int8_recur_reference",
]

# Term tags in a band schedule (static tuples unrolled at trace time):
#   (MM, slot, shift, row_block)          one tile matmul, then << shift
#   (SA, row_block, ((i, j, sign, w)...)) unrolled shift-add digits
MM = 0
SA = 1

# Default cap on batch-tile rows: one tile's state slab stays well under a
# VMEM bank even at dim 4096 (16 * 4096 * 4 B = 256 KiB), and batch 64
# runs as four grid-parallel tiles instead of one monolithic block.
DEFAULT_BATCH_TILE = 16


def default_crossover(block: int) -> int:
    """Set-digit count below which shift-adds beat a folded tile matmul.

    A folded (block x block) int8 tile costs one MXU pass regardless of
    content; a shift-add plane costs ``ones`` vector adds.  The VPU issues
    ~block lanes per add, so once a plane carries fewer than ~block/2 set
    digits the adds win even against the systolic array — the same
    crossover the paper's synthesizer faces between a carry-save tree and
    bare adders.
    """
    return max(8, block // 2)


@dataclasses.dataclass(frozen=True)
class RolloutProgram:
    """A matrix-specialized rollout: banded folded tiles + static schedule.

    ``schedules`` is the nested static tuple the kernels unroll — one entry
    per band, each listing ``(ci, terms)`` per output column block with
    :data:`MM`/:data:`SA` tagged terms.  ``data`` holds the folded weight
    tiles the MM terms index.
    """

    mode: str                  # "fp32" | "int8"
    block: int
    regime: str                # "resident" | "pipelined"
    data: jnp.ndarray          # (n_bands, max_terms, block, block)
    schedules: tuple
    max_terms: int
    vmem_budget: int | None
    crossover: int
    batch_tile_max: int
    n_matmul_terms: int        # folded-tile matmul terms kept
    n_shiftadd_terms: int      # (block, plane-group) shift-add terms
    shiftadd_digits: int       # unrolled digit adds across all SA terms
    resident_bytes: int        # weight bytes on-chip while executing

    @property
    def n_bands(self) -> int:
        return len(self.schedules)

    def batch_tiling(self, batch: int) -> tuple[int, int, int]:
        """(b_tile, n_tiles, b_padded) for a batch of ``batch`` rows.

        Tiles are balanced (``ceil(B / n_tiles)`` rows each) so padding
        never exceeds ``n_tiles - 1`` rows.
        """
        n_tiles = max(1, -(-batch // self.batch_tile_max))
        b_tile = -(-batch // n_tiles)
        return b_tile, n_tiles, b_tile * n_tiles

    def describe(self) -> str:
        dbl = " x2 (double-buffered)" if self.regime == "pipelined" else ""
        return (f"{self.mode} {self.regime}: {self.n_bands} band(s), "
                f"{self.resident_bytes} B weights on-chip{dbl}, "
                f"{self.n_matmul_terms} matmul terms + "
                f"{self.n_shiftadd_terms} shift-add terms "
                f"({self.shiftadd_digits} digit adds, "
                f"crossover {self.crossover})")


def _int8_block_lowering(plan: ExecutionPlan, di: int, crossover: int):
    """Constant-propagate one block's digit planes.

    Returns ``(mm_tiles, sa_digits)``: ``mm_tiles`` is a list of
    ``(tile_int8, shift)`` — one folded tile (shift 0) when the partial
    fold stays in int8 range, else the unfolded per-plane tiles — and
    ``sa_digits`` the strength-reduced ``(i, j, sign, w)`` terms of the
    planes below the crossover.
    """
    tiles = plan.int8_tiles                      # (width, n_nnz, bk, bk)
    keep = plan.plane_block_mask
    sa_digits: list[tuple] = []
    mm_planes: list[int] = []
    for w in range(plan.width):
        if not keep[w, di]:
            continue                              # culled at compile time
        plane = tiles[w, di]
        ones = int(np.count_nonzero(plane))
        if ones < crossover:
            ii, jj = np.nonzero(plane)
            sa_digits.extend(
                (int(i), int(j), int(plane[i, j]), w)
                for i, j in zip(ii, jj))
        else:
            mm_planes.append(w)
    if not mm_planes:
        return [], tuple(sa_digits)
    folded = sum(tiles[w, di].astype(np.int64) << w for w in mm_planes)
    if np.abs(folded).max() <= 127:
        # the full fold is always the quantized block (|q| <= 127); only a
        # *partial* fold — CSD's 2^width carry digit staying behind — can
        # overflow int8, in which case the planes stay separate.
        return [(folded.astype(np.int8), 0)], tuple(sa_digits)
    return ([(tiles[w, di], w) for w in mm_planes], tuple(sa_digits))


def _column_lowerings(plan: ExecutionPlan, mode: str, crossover: int):
    """Per output column block: ``[(ri, mm_tiles, sa_digits), ...]`` in the
    banded kernel's ascending-tile order."""
    rows, cols = plan.block_rows, plan.block_cols
    out: list[list] = []
    for ci in range(plan.nbc):
        entries = []
        for di in np.flatnonzero(cols == ci):
            ri = int(rows[di])
            if mode == "fp32":
                entries.append((ri, [(plan.fp32_tiles[int(di)], 0)], ()))
            else:
                mm, sa = _int8_block_lowering(plan, int(di), crossover)
                entries.append((ri, mm, sa))
        out.append(entries)
    return out


def _partition(plan: ExecutionPlan, col_mm_counts: np.ndarray,
               tile_bytes: int, vmem_budget: int | None):
    """Regime selection + greedy band packing over folded-term counts.

    Resident when every kept tile fits the budget at once; otherwise bands
    are capped at *half* the budget so two bands fit in flight (the
    prefetch of band ``k+1`` overlaps the reduction of band ``k``).
    """
    total = int(col_mm_counts.sum()) * tile_bytes
    if vmem_budget is None or total <= vmem_budget:
        return "resident", ((0, plan.nbc),)
    cap = vmem_budget // 2
    spans: list[list[int]] = [[0, 0, 0]]          # [lo, hi, n_terms]
    for ci in range(plan.nbc):
        n = int(col_mm_counts[ci])
        if n * tile_bytes > cap:
            raise ValueError(
                f"column block {ci} alone needs {n * tile_bytes} B of folded "
                f"tiles > half the vmem_budget ({cap} B needed for double "
                f"buffering); raise the budget or compile with a smaller "
                f"block than {plan.block}")
        last = spans[-1]
        if last[1] > last[0] and (last[2] + n) * tile_bytes > cap:
            spans.append([ci, ci, 0])
            last = spans[-1]
        last[1] = ci + 1
        last[2] += n
    return "pipelined", tuple((lo, hi) for lo, hi, _n in spans)


def _lowerings(plan: ExecutionPlan, mode: str, crossover: int):
    """Column lowerings cached per ``(mode, crossover)`` on the plan — the
    expensive half of the analysis (digit-plane folding) is independent of
    the band budget and batch tile, so the autotuner prices its whole
    budget x tile candidate grid off one fold per crossover."""
    cache = getattr(plan, "_lowerings", None)
    if cache is None:
        cache = plan._lowerings = {}
    key = (mode, crossover)
    if key not in cache:
        cache[key] = _column_lowerings(plan, mode, crossover)
    return cache[key]


def _analyze(plan: ExecutionPlan, mode: str, crossover: int,
             vmem_budget: int | None) -> dict:
    """The shared schedule analysis both the summary and the full program
    build from: column lowerings, band partition, regime, and every
    derived count — ONE set of formulas, so BENCH_specialize.json can
    never drift from what the kernel actually runs.  Materializes no
    tile data."""
    cols = _lowerings(plan, mode, crossover)
    itemsize = 4 if mode == "fp32" else 1
    tile_bytes = plan.block * plan.block * itemsize
    counts = np.array([sum(len(mm) for _ri, mm, _sa in entries)
                       for entries in cols])
    regime, spans = _partition(plan, counts, tile_bytes, vmem_budget)
    max_terms = max(1, max(int(counts[lo:hi].sum()) for lo, hi in spans))
    return {
        "cols": cols,
        "spans": spans,
        "tile_bytes": tile_bytes,
        "max_terms": max_terms,
        "mode": mode,
        "regime": regime,
        "n_bands": len(spans),
        "n_matmul_terms": int(counts.sum()),
        "n_shiftadd_terms": sum(1 for entries in cols
                                for _ri, _mm, sa in entries if sa),
        "shiftadd_digits": sum(len(sa) for entries in cols
                               for _ri, _mm, sa in entries),
        "resident_bytes": max_terms * tile_bytes * (
            1 if regime == "resident" else 2),
        "crossover": crossover,
        "vmem_budget": vmem_budget,
    }


_SUMMARY_KEYS = ("mode", "regime", "n_bands", "n_matmul_terms",
                 "n_shiftadd_terms", "shiftadd_digits", "resident_bytes",
                 "crossover", "vmem_budget", "batch_tile_max")


def _summary_dict(src) -> dict:
    """Public summary fields from an analysis dict or RolloutProgram."""
    get = src.get if isinstance(src, dict) else lambda k: getattr(src, k)
    return {k: get(k) for k in _SUMMARY_KEYS}


def specialize_summary(plan: ExecutionPlan, mode: str = "fp32",
                       vmem_budget: int | None = DEFAULT_VMEM_BUDGET,
                       crossover: int | None = None,
                       batch_tile_max: int = DEFAULT_BATCH_TILE) -> dict:
    """Counts-level view of the specialization — what ``describe`` reports
    and what the autotuner prices candidates from.

    Keyed on the FULL schedule tuple ``(mode, vmem_budget, crossover,
    batch_tile_max)`` — the same key :func:`specialize_rollout` caches
    programs under, so tuned variants that differ only in batch tiling
    never collide.  Reads the fields off an already-cached
    :class:`RolloutProgram` when one exists for exactly these parameters;
    otherwise runs the shared analysis once — never materializing the
    banded data array — and caches the result on the plan.  Always
    returns a fresh dict (callers may annotate it).
    """
    assert mode in ("fp32", "int8"), mode
    crossover = default_crossover(plan.block) if crossover is None else crossover
    key = (mode, vmem_budget, crossover, batch_tile_max)
    prog = getattr(plan, "_programs", {}).get(key)
    if prog is not None:
        return _summary_dict(prog)
    cache = getattr(plan, "_summaries", None)
    if cache is None:
        cache = plan._summaries = {}
    if key not in cache:
        d = _summary_dict(dict(
            _analyze(plan, mode, crossover, vmem_budget),
            batch_tile_max=batch_tile_max))
        cache[key] = d
    return dict(cache[key])


def specialize_rollout(plan: ExecutionPlan, mode: str = "fp32",
                       vmem_budget: int | None = DEFAULT_VMEM_BUDGET,
                       crossover: int | None = None,
                       batch_tile_max: int = DEFAULT_BATCH_TILE,
                       ) -> RolloutProgram:
    """Lower one plan into a matrix-specialized :class:`RolloutProgram`.

    Cached per ``(mode, vmem_budget, crossover, batch_tile_max)`` on the
    plan — like the plan itself, the specialization is paid once per
    frozen matrix.
    """
    assert mode in ("fp32", "int8"), mode
    crossover = default_crossover(plan.block) if crossover is None else crossover
    key = (mode, vmem_budget, crossover, batch_tile_max)
    cache = getattr(plan, "_programs", None)
    if cache is None:
        cache = plan._programs = {}
    if key in cache:
        return cache[key]

    from repro import obs
    t_spec = time.perf_counter()
    bk = plan.block
    dtype = np.float32 if mode == "fp32" else np.int8
    a = _analyze(plan, mode, crossover, vmem_budget)

    schedules: list[tuple] = []
    band_data: list[list[np.ndarray]] = []
    for lo, hi in a["spans"]:
        tiles: list[np.ndarray] = []
        band_cols = []
        for ci in range(lo, hi):
            terms: list[tuple] = []
            for ri, mm, sa in a["cols"][ci]:
                for tile, shift in mm:
                    terms.append((MM, len(tiles), shift, ri))
                    tiles.append(np.asarray(tile, dtype))
                if sa:
                    terms.append((SA, ri, sa))
            band_cols.append((ci, tuple(terms)))
        schedules.append(tuple(band_cols))
        band_data.append(tiles)

    data = np.zeros((a["n_bands"], a["max_terms"], bk, bk), dtype)
    for bi, tiles in enumerate(band_data):
        if tiles:
            data[bi, : len(tiles)] = np.stack(tiles)
    program = RolloutProgram(
        mode=mode, block=bk, regime=a["regime"], data=jnp.asarray(data),
        schedules=tuple(schedules), max_terms=a["max_terms"],
        vmem_budget=vmem_budget, crossover=crossover,
        batch_tile_max=batch_tile_max,
        n_matmul_terms=a["n_matmul_terms"],
        n_shiftadd_terms=a["n_shiftadd_terms"],
        shiftadd_digits=a["shiftadd_digits"],
        resident_bytes=a["resident_bytes"])
    cache[key] = program
    obs.span("plan.specialize", t_spec, time.perf_counter(), clock="wall",
             mode=mode, regime=a["regime"], n_bands=a["n_bands"])
    obs.event("specialize", mode=mode, regime=a["regime"])
    return program


def int8_recur_reference(program: RolloutProgram, xq: jnp.ndarray,
                         rows_pad: int, out_cols: int) -> jnp.ndarray:
    """Schedule-driven exact integer recurrent product (XLA consumer).

    ``xq``: (..., rows) int32 quantized states -> (..., out_cols) int32 —
    bit-identical to ``FixedMatrix.matvec_int_exact`` because every term
    accumulates in exact int32.  The same schedule the Pallas kernel
    unrolls, expressed in plain jnp for the XLA backend (and for parity
    tests).
    """
    assert program.mode == "int8"
    bk = program.block
    xp = jnp.zeros(xq.shape[:-1] + (rows_pad,), jnp.int32
                   ).at[..., : xq.shape[-1]].set(xq.astype(jnp.int32))
    pieces = []
    for bi, band in enumerate(program.schedules):
        for ci, terms in band:
            acc = jnp.zeros(xq.shape[:-1] + (bk,), jnp.int32)
            for term in terms:
                if term[0] == MM:
                    _tag, slot, shift, ri = term
                    xs = xp[..., ri * bk:(ri + 1) * bk]
                    acc = acc + (
                        (xs @ program.data[bi, slot].astype(jnp.int32))
                        << shift)
                else:
                    _tag, ri, digits = term
                    for i, j, s, w in digits:
                        col = xp[..., ri * bk + i] << w
                        acc = acc.at[..., j].add(col if s > 0 else -col)
            pieces.append(acc)
    return jnp.concatenate(pieces, axis=-1)[..., :out_cols]
