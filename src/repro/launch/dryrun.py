import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

For every (architecture x input-shape x mesh) cell: build the step function,
``.lower().compile()`` it against ShapeDtypeStruct inputs (no allocation),
and record ``memory_analysis()`` / ``cost_analysis()`` / walker-derived
roofline inputs to a per-cell JSON under results/dryrun/.

The XLA_FLAGS line above MUST precede any jax import: jax locks the device
count at first init.  Cells run in subprocesses by default (isolation +
cache-eviction between compiles on a 1-core container); ``--cell`` runs one
cell inline.

Usage:
  python -m repro.launch.dryrun                 # all pending cells, subprocs
  python -m repro.launch.dryrun --cell qwen3-32b train_4k --multi-pod
  python -m repro.launch.dryrun --list          # show cell status
"""

import argparse
import gzip
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def cell_path(arch: str, shape: str, multi_pod: bool,
              variant: str = "") -> Path:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    suffix = f"__{variant}" if variant else ""
    return RESULTS / mesh_name / f"{arch}__{shape}{suffix}.json"


def parse_overrides(pairs):
    out = {}
    for kv in pairs or []:
        k, v = kv.split("=", 1)
        if v.lower() in ("true", "false"):
            out[k] = v.lower() == "true"
        else:
            try:
                out[k] = int(v)
            except ValueError:
                try:
                    out[k] = float(v)
                except ValueError:
                    out[k] = v
    return out


def run_cell_inline(arch: str, shape_name: str, multi_pod: bool,
                    save_hlo: bool = True, overrides: dict | None = None,
                    variant: str = "") -> dict:
    import jax  # deferred: after XLA_FLAGS
    from repro.configs import SHAPES, get_config, supports_shape
    from repro.launch import hlo_cost
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import lower_cell
    from repro.models.transformer import LM

    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    out: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "n_devices": 512 if multi_pod else 256,
                 "variant": variant, "overrides": overrides or {}}
    ok, why = supports_shape(cfg, shape)
    if not ok:
        out["status"] = "skipped"
        out["reason"] = why
        return out

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered, meta = lower_cell(cfg, shape, mesh)
    out["t_lower_s"] = round(time.time() - t0, 1)
    t0 = time.time()
    compiled = lowered.compile()
    out["t_compile_s"] = round(time.time() - t0, 1)
    out.update(meta)

    ma = compiled.memory_analysis()
    out["memory_per_device"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_bytes": int(ma.argument_size_in_bytes + ma.output_size_in_bytes
                          + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
    }
    ca = compiled.cost_analysis() or {}
    out["cost_analysis_raw"] = {
        "flops": float(ca.get("flops", -1)),
        "bytes_accessed": float(ca.get("bytes accessed", -1)),
        "note": "while bodies counted once by XLA; see hlo_walk for "
                "trip-multiplied numbers",
    }

    t0 = time.time()
    hlo_text = compiled.as_text()
    walk = hlo_cost.analyze_hlo(hlo_text)
    out["hlo_walk"] = walk
    out["t_walk_s"] = round(time.time() - t0, 1)
    out["param_count"] = LM(cfg).param_count()
    out["status"] = "ok"

    if save_hlo:
        p = cell_path(arch, shape_name, multi_pod, variant)
        p.parent.mkdir(parents=True, exist_ok=True)
        with gzip.open(p.with_suffix(".hlo.txt.gz"), "wt") as f:
            f.write(hlo_text)
    return out


def all_cells():
    from repro.configs import SHAPES, list_archs
    for arch in list_archs():
        for shape in SHAPES:
            for multi_pod in (False, True):
                yield arch, shape, multi_pod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", nargs=2, metavar=("ARCH", "SHAPE"))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--no-hlo", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--variant", default="")
    ap.add_argument("--override", action="append", default=[],
                    metavar="FIELD=VALUE")
    args = ap.parse_args()

    if args.list:
        for arch, shape, mp in all_cells():
            p = cell_path(arch, shape, mp)
            status = "-"
            if p.exists():
                status = json.loads(p.read_text()).get("status", "?")
            print(f"{arch:22s} {shape:12s} {'2x16x16' if mp else '16x16':8s} {status}")
        return

    if args.cell:
        arch, shape = args.cell
        p = cell_path(arch, shape, args.multi_pod, args.variant)
        if p.exists() and not args.force:
            print(f"cached: {p}")
            return
        try:
            res = run_cell_inline(arch, shape, args.multi_pod,
                                  save_hlo=not args.no_hlo,
                                  overrides=parse_overrides(args.override),
                                  variant=args.variant)
        except Exception as e:
            res = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if args.multi_pod else "16x16",
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(res, indent=2))
        print(json.dumps({k: v for k, v in res.items()
                          if k not in ("traceback",)}, indent=2))
        return

    # driver mode: subprocess per pending cell
    for arch, shape, mp in all_cells():
        p = cell_path(arch, shape, mp)
        if p.exists() and not args.force:
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--cell", arch, shape]
        if mp:
            cmd.append("--multi-pod")
        if args.no_hlo:
            cmd.append("--no-hlo")
        print(f"=== {arch} {shape} {'2x16x16' if mp else '16x16'} ===",
              flush=True)
        t0 = time.time()
        try:
            subprocess.run(cmd, timeout=args.timeout, check=False)
        except subprocess.TimeoutExpired:
            p.parent.mkdir(parents=True, exist_ok=True)
            p.write_text(json.dumps({
                "arch": arch, "shape": shape,
                "mesh": "2x16x16" if mp else "16x16",
                "status": "timeout", "timeout_s": args.timeout}))
        print(f"    ({time.time() - t0:.0f}s)", flush=True)


if __name__ == "__main__":
    main()
