"""Post-partitioning HLO cost walker.

``compiled.cost_analysis()`` on this backend counts each ``while`` body
exactly once, which silently undercounts scanned-layer models by the layer
count.  This walker parses ``compiled.as_text()`` and walks the computation
graph from ENTRY, multiplying costs through ``while`` trip counts (recovered
from the loop condition's comparison constant) and recursing through
fusions/calls/conditionals, to produce:

  * per-device dot FLOPs (2*M*N*K per dot, trip-multiplied)
  * per-device collective bytes by op kind (all-reduce counted twice for the
    ring's reduce+broadcast phases; others once)

Shapes in partitioned HLO are already per-device, so results feed the
roofline terms directly.
"""

from __future__ import annotations

import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\(|\.)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _type_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (tuples summed)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[str]] = {}
        self.entry: str | None = None
        self.shapes: dict[str, str] = {}      # op name -> type string
        self._parse(text)

    _HDR_RE = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\(")

    def _parse(self, text: str):
        cur = None
        for line in text.splitlines():
            stripped = line.strip()
            if not stripped:
                continue
            hm = self._HDR_RE.match(line)
            if hm and stripped.endswith("{"):
                cur = hm.group(2)
                self.computations[cur] = []
                if hm.group(1):
                    self.entry = cur
                # parameter shapes from the signature
                arrow = line.rfind("->")
                sig = line[line.find("(") + 1: arrow if arrow > 0 else len(line)]
                for pm in re.finditer(
                        r"%?([\w\.\-]+):\s*((?:\([^)]*\))|\S+?[\]\}])", sig):
                    self.shapes[pm.group(1)] = pm.group(2)
                continue
            if stripped == "}":
                cur = None
                continue
            if cur is not None:
                self.computations[cur].append(line)
                m = _OP_RE.match(line)
                if m:
                    self.shapes[m.group(1)] = m.group(2)

    # -- trip counts ---------------------------------------------------------
    def trip_count(self, cond_name: str) -> int:
        """Heuristic: largest s32/s64 constant in the loop condition."""
        best = 1
        for line in self.computations.get(cond_name, []):
            for m in re.finditer(r"constant\((\d+)\)", line):
                best = max(best, int(m.group(1)))
        return best

    # -- cost walk -------------------------------------------------------------
    def analyze(self) -> dict:
        flops = defaultdict(float)
        coll = defaultdict(float)
        visited_guard: set = set()

        def walk(comp: str, mult: float):
            if (comp, mult) in visited_guard and mult > 1e12:
                return
            for line in self.computations.get(comp, []):
                m = _OP_RE.match(line)
                if not m:
                    continue
                name, otype, opcode, rest = m.groups()
                if opcode == "while":
                    body = re.search(r"body=%?([\w\.\-]+)", rest)
                    # primary: XLA's own known_trip_count backend_config
                    tc = re.search(r'known_trip_count[^0-9]*(\d+)', rest)
                    if tc:
                        trips = int(tc.group(1))
                    else:  # fallback: comparison constant in the condition
                        cond = re.search(r"condition=%?([\w\.\-]+)", rest)
                        trips = self.trip_count(cond.group(1)) if cond else 1
                    if body:
                        walk(body.group(1), mult * trips)
                elif opcode in ("fusion", "call", "async-start"):
                    cm = re.search(r"(?:calls|to_apply|to)=%?([\w\.\-]+)", rest)
                    if cm:
                        walk(cm.group(1), mult)
                elif opcode == "conditional":
                    for cm in re.finditer(
                            r"(?:true_computation|false_computation|branch_computations=\{)([^,}]+)",
                            rest):
                        walk(cm.group(1).strip().lstrip("%"), mult)
                elif opcode in ("dot", "cudnn-dot"):
                    self._dot_flops(name, otype, rest, mult, flops)
                elif opcode == "convolution":
                    # rough: 2 * output elems * (kernel elems per output)
                    out = _shape_dims(otype)
                    flops["convolution"] += mult * 2 * math.prod(out or [0])
                else:
                    for c in COLLECTIVES:
                        if opcode.startswith(c):
                            factor = 2.0 if c == "all-reduce" else 1.0
                            coll[c] += mult * factor * _type_bytes(otype)
                            break

        def _noop(*a):
            pass

        if self.entry:
            walk(self.entry, 1.0)
        return {
            "dot_flops": float(flops["dot"]),
            "conv_flops": float(flops["convolution"]),
            "collective_bytes": dict(coll),
            "total_collective_bytes": float(sum(coll.values())),
        }

    def _dot_flops(self, name, otype, rest, mult, flops):
        out_elems = math.prod(_shape_dims(otype) or [0])
        # contracted extent from lhs shape + lhs_contracting_dims.  Operands
        # appear either bare (``dot(%p0, %p1)``) or with their type inlined
        # (``dot(f32[16,512]{1,0} %convert.33, ...)``) depending on the HLO
        # printer version; accept both.
        ops = re.match(r"\s*(?:(\S*\[[\d,]*\]\S*)\s+)?%?([\w\.\-]+)", rest)
        k = 1
        cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
        if ops and cm and cm.group(1):
            lhs_type = ops.group(1) or self.shapes.get(ops.group(2), "")
            lhs_shape = _shape_dims(lhs_type)
            for d in cm.group(1).split(","):
                di = int(d)
                if di < len(lhs_shape):
                    k *= lhs_shape[di]
        flops["dot"] += mult * 2.0 * out_elems * k


def analyze_hlo(text: str) -> dict:
    return HloModule(text).analyze()
