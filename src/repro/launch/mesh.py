"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — critical because the dry-run forces 512
host devices while smoke tests must see 1.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The assignment's target: 16x16 = 256 chips/pod; 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Trivial mesh over whatever devices exist (CPU smoke/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_data_mesh(n_shards: int | None = None, devices=None):
    """1-D ``('data',)`` mesh for batch-axis sharded serving.

    The reservoir is frozen and replicated (the paper's premise), so the
    serving mesh carries no model axis — just ``n_shards`` data shards over
    the first ``n_shards`` devices (all of them by default).  ``devices``
    pins an explicit device list, which is how the elastic path builds the
    shrunk mesh from the survivors.
    """
    from jax.sharding import Mesh
    import numpy as np
    if n_shards is not None and n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if devices is None:
        devices = jax.devices()
    if n_shards is not None:
        if len(devices) < n_shards:
            raise ValueError(f"need {n_shards} devices, have {len(devices)}")
        devices = devices[:n_shards]
    return Mesh(np.asarray(devices), ("data",))
