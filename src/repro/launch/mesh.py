"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — critical because the dry-run forces 512
host devices while smoke tests must see 1.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The assignment's target: 16x16 = 256 chips/pod; 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Trivial mesh over whatever devices exist (CPU smoke/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
