"""Launch layer: mesh, steps, dry-run, roofline."""
