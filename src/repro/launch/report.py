"""Generate EXPERIMENTS.md sections from dry-run results (idempotent),
plus the ExecutionPlan compile/cost table used by the serving examples."""

from __future__ import annotations

import json
from pathlib import Path

from repro.launch import roofline

ROOT = Path(__file__).resolve().parents[3]


def plan_table(plans) -> str:
    """Markdown table of ExecutionPlan compile stats + FPGA cost.

    One row per compiled matrix: what the shared lowering kept vs culled,
    how the fp32 rollout bands under the default VMEM budget, and the
    paper's synthesis-model numbers (LUTs ~ ones, Fmax band, Eq. 5
    latency) evaluated on the exact decomposed structure.
    """
    rows = ["| matrix | blocks kept | int8 terms kept/culled | bands "
            "| ones | LUTs | Fmax MHz | Eq.5 ns | W |",
            "|---|---|---|---|---|---|---|---|---|"]
    for plan in plans:
        s = plan.stats
        dp = plan.fpga_cost()
        # partition only: reporting must not gather the banded tile data
        n_bands, band_bytes = plan.band_summary("fp32")
        rows.append(
            f"| {plan.shape[0]}x{plan.shape[1]}/{plan.mode} "
            f"| {s.blocks_nnz}/{s.blocks_total} "
            f"| {s.int8_terms_kept}/{s.int8_terms_culled} "
            f"| {n_bands} x {band_bytes // 1024} KiB "
            f"| {s.ones} | {dp.luts:.0f} | {dp.fmax_hz / 1e6:.0f} "
            f"| {dp.latency_ns:.1f} | {dp.power_w:.1f} |")
    return "\n".join(rows)


def dryrun_table() -> str:
    rows = ["| arch | shape | mesh | status | step | mem/dev GB | peak fits "
            "16GB | dot FLOPs/dev | collective B/dev | compile s |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for mesh_dir, mesh_name in (("pod16x16", "16x16"),
                                ("pod2x16x16", "2x16x16")):
        for rec in roofline.load_all(mesh_dir):
            if rec.get("status") == "ok":
                m = rec["memory_per_device"]
                tot = (m["argument_bytes"] + m["temp_bytes"]) / 2 ** 30
                rows.append(
                    f"| {rec['arch']} | {rec['shape']} | {mesh_name} | ok "
                    f"| {rec.get('step', '')} | {tot:.1f} "
                    f"| {'yes' if tot <= 16 else 'NO'} "
                    f"| {rec['hlo_walk']['dot_flops']:.2e} "
                    f"| {rec['hlo_walk']['total_collective_bytes']:.2e} "
                    f"| {rec.get('t_compile_s', '')} |")
            elif rec.get("status") == "skipped":
                rows.append(f"| {rec['arch']} | {rec['shape']} | {mesh_name} "
                            f"| skipped (documented) | — | — | — | — | — | — |")
            else:
                rows.append(f"| {rec['arch']} | {rec['shape']} | {mesh_name} "
                            f"| **{rec.get('status')}** | — | — | — | — | — | — |")
    return "\n".join(rows)


def roofline_table() -> str:
    recs = roofline.load_all("pod16x16")
    reports = [r for r in (roofline.cell_report(x) for x in recs) if r]
    return roofline.to_markdown(reports)


def main():
    print("== dryrun ==")
    print(dryrun_table())
    print("\n== roofline ==")
    print(roofline_table())


if __name__ == "__main__":
    main()
