"""Generate EXPERIMENTS.md sections from dry-run results (idempotent)."""

from __future__ import annotations

import json
from pathlib import Path

from repro.launch import roofline

ROOT = Path(__file__).resolve().parents[3]


def dryrun_table() -> str:
    rows = ["| arch | shape | mesh | status | step | mem/dev GB | peak fits "
            "16GB | dot FLOPs/dev | collective B/dev | compile s |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for mesh_dir, mesh_name in (("pod16x16", "16x16"),
                                ("pod2x16x16", "2x16x16")):
        for rec in roofline.load_all(mesh_dir):
            if rec.get("status") == "ok":
                m = rec["memory_per_device"]
                tot = (m["argument_bytes"] + m["temp_bytes"]) / 2 ** 30
                rows.append(
                    f"| {rec['arch']} | {rec['shape']} | {mesh_name} | ok "
                    f"| {rec.get('step', '')} | {tot:.1f} "
                    f"| {'yes' if tot <= 16 else 'NO'} "
                    f"| {rec['hlo_walk']['dot_flops']:.2e} "
                    f"| {rec['hlo_walk']['total_collective_bytes']:.2e} "
                    f"| {rec.get('t_compile_s', '')} |")
            elif rec.get("status") == "skipped":
                rows.append(f"| {rec['arch']} | {rec['shape']} | {mesh_name} "
                            f"| skipped (documented) | — | — | — | — | — | — |")
            else:
                rows.append(f"| {rec['arch']} | {rec['shape']} | {mesh_name} "
                            f"| **{rec.get('status')}** | — | — | — | — | — | — |")
    return "\n".join(rows)


def roofline_table() -> str:
    recs = roofline.load_all("pod16x16")
    reports = [r for r in (roofline.cell_report(x) for x in recs) if r]
    return roofline.to_markdown(reports)


def main():
    print("== dryrun ==")
    print(dryrun_table())
    print("\n== roofline ==")
    print(roofline_table())


if __name__ == "__main__":
    main()
