"""ShapeDtypeStruct stand-ins for every model input, with shardings.

``input_specs`` produces the exact pytrees each step function consumes —
weak-type-correct and shardable, with zero device allocation — so the
dry-run can ``.lower().compile()`` any (arch x shape x mesh) cell.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.transformer import LM
from repro.parallel.sharding import (batch_spec, cache_sharding,
                                     data_axis_names, param_shardings)

N_PATCHES = 256  # vlm frontend stub: image tokens prepended to the text


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _bspec(mesh, ndim, batchable=True):
    d = data_axis_names(mesh)
    first = (d if len(d) > 1 else d[0]) if (d and batchable) else None
    return NamedSharding(mesh, P(*(first,) + (None,) * (ndim - 1)))


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, mesh) -> dict:
    """Training/prefill batch structs for one shape cell."""
    b, s = shape.global_batch, shape.seq_len
    from repro.parallel.sharding import _axis_size  # local import
    nd = _axis_size(mesh, data_axis_names(mesh))
    batchable = b % nd == 0 and b >= nd
    out = {}
    if shape.kind == "train":
        out["tokens"] = _sds((b, s + 1), jnp.int32, _bspec(mesh, 2, batchable))
    else:
        out["tokens"] = _sds((b, s), jnp.int32, _bspec(mesh, 2, batchable))
    if cfg.frontend == "vision":
        out["patches"] = _sds((b, N_PATCHES, cfg.d_model), jnp.bfloat16,
                              _bspec(mesh, 3, batchable))
    if cfg.encoder is not None:
        out["frames"] = _sds((b, cfg.encoder.seq_len, cfg.d_model),
                             jnp.bfloat16, _bspec(mesh, 3, batchable))
    return out


def params_specs(lm: LM, mesh, fsdp: bool = True,
                 expert_fsdp: bool | None = None) -> tuple:
    """(param ShapeDtypeStructs with shardings, shardings tree)."""
    pa = jax.eval_shape(lm.init, jax.random.PRNGKey(0))
    ef = lm.cfg.expert_fsdp if expert_fsdp is None else expert_fsdp
    shardings = param_shardings(pa.axes, pa.params, mesh, fsdp=fsdp,
                                use_tp=lm.cfg.use_tp,
                                expert_fsdp=ef)
    structs = jax.tree.map(lambda sds, sh: _sds(sds.shape, sds.dtype, sh),
                           pa.params, shardings)
    return structs, shardings


def opt_state_specs(param_structs, mesh, dtype: str = "float32") -> tuple:
    """AdamW (m, v, step) structs mirroring the parameter shardings."""
    dt = jnp.bfloat16 if dtype == "bfloat16" else jnp.float32
    f32 = lambda sds: _sds(sds.shape, dt, sds.sharding)
    m = jax.tree.map(f32, param_structs)
    v = jax.tree.map(f32, param_structs)
    step = _sds((), jnp.int32, NamedSharding(mesh, P()))
    return {"m": m, "v": v, "step": step}


def _cache_leaf_sharding(path, sds, cfg: ModelConfig, mesh, stacked: bool):
    """Per-leaf cache sharding by structural role (see parallel/sharding)."""
    names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
    name = names[-1] if names else ""
    shape = sds.shape
    off = 1 if (stacked and "groups" in names) else 0
    rank = len(shape)

    def build(**kw):
        inner = cache_sharding(mesh, shape[off:], batch_dim=0, **kw)
        spec = list(inner.spec) + [None] * (rank - off - len(inner.spec))
        return NamedSharding(mesh, P(*([None] * off + spec)))

    if name in ("k", "v") and rank - off == 4:
        return build(n_kv=cfg.n_kv_heads, kv_dim=2, seq_dim=1)
    if name in ("c_kv", "k_rope") and rank - off == 3:
        return build(seq_dim=1)
    if name == "pos":
        return build()
    if name == "enc":
        return build()
    if name in ("h", "conv"):               # rglru state: width over model
        return build(n_kv=cfg.lru_dim, kv_dim=rank - off - 1)
    if name in ("c", "n", "m") and rank - off >= 2:   # xlstm: heads
        return build(n_kv=cfg.n_heads, kv_dim=1)
    return NamedSharding(mesh, P(*([None] * rank)))


def cache_specs(lm: LM, shape: ShapeSpec, mesh) -> Any:
    """Decode caches as ShapeDtypeStructs for a full-length context."""
    cfg = lm.cfg
    b = shape.global_batch
    cache_len = shape.seq_len
    caches = jax.eval_shape(lambda: lm.init_caches(b, cache_len))
    if cfg.encoder is not None:
        enc = _sds((b, cfg.encoder.seq_len, cfg.d_model), jnp.bfloat16)
        caches = dict(caches)
        caches["enc"] = enc

    def leaf(path, sds):
        return _sds(sds.shape, sds.dtype,
                    _cache_leaf_sharding(path, sds, cfg, mesh,
                                         cfg.scan_layers))

    return jax.tree_util.tree_map_with_path(leaf, caches)


def token_spec(shape: ShapeSpec, mesh):
    b = shape.global_batch
    from repro.parallel.sharding import _axis_size
    nd = _axis_size(mesh, data_axis_names(mesh))
    return _sds((b, 1), jnp.int32, _bspec(mesh, 2, b % nd == 0 and b >= nd))
