"""Roofline assembly: three terms per (arch x shape x mesh) cell.

Terms (TPU v5e per chip: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI):

  compute    = dot_FLOPs_per_device / peak_FLOPs
  memory     = HBM_traffic_per_device / HBM_bw
  collective = collective_bytes_per_device / link_bw

``dot_FLOPs`` and ``collective_bytes`` come from the trip-count-corrected
HLO walk of the *compiled* partitioned module (launch/hlo_cost.py — XLA's
flat cost_analysis counts while bodies once, recorded raw alongside).
HBM traffic uses an explicit analytic model (weights / optimizer / KV-cache
/ activation streams; formulas below) because post-fusion byte attribution
is not recoverable from the HLO text.

Also reported per cell: MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D
(inference), the useful-compute ratio MODEL_FLOPS / (HLO dot FLOPs * chips),
the dominant term, and a one-line "what would move it" note.
"""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.configs import SHAPES, get_config, list_archs
from repro.configs.base import ModelConfig, ShapeSpec

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # B/s per chip
LINK_BW = 50e9           # B/s per ICI link

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


# ---------------------------------------------------------------------------
# analytic model inputs
# ---------------------------------------------------------------------------
def expert_params_per_layer(cfg: ModelConfig) -> int:
    if cfg.moe is None:
        return 0
    return 3 * cfg.d_model * cfg.moe.d_expert


def active_params(cfg: ModelConfig, total: int) -> int:
    """Parameters touched per token (MoE: top_k + shared experts only)."""
    if cfg.moe is None:
        return total
    per = expert_params_per_layer(cfg)
    inactive = (cfg.moe.n_experts - cfg.moe.top_k) * per * cfg.n_layers
    return total - inactive


def model_flops(cfg: ModelConfig, shape: ShapeSpec, n_active: int) -> float:
    """6*N*D for training, 2*N*D for inference (D = tokens this step)."""
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: one token


def kv_cache_bytes(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Global KV/state cache bytes at full context."""
    b, s = shape.global_batch, shape.seq_len
    per_layer = 0.0
    for kind in cfg.block_pattern:
        if kind == "attn":
            per_layer += 2 * cfg.n_kv_heads * cfg.head_dim * s * 2.0
        elif kind == "local":
            w = min(cfg.window or s, s)
            per_layer += 2 * cfg.n_kv_heads * cfg.head_dim * w * 2.0
        elif kind == "mla":
            per_layer += (cfg.mla.kv_lora + cfg.mla.rope_dim) * s * 2.0
        elif kind == "rglru":
            per_layer += cfg.lru_dim * 4.0 + (cfg.conv_width - 1) * cfg.lru_dim * 4.0
        elif kind in ("mlstm", "slstm"):
            per_layer += cfg.n_heads * (cfg.head_dim ** 2 + 2 * cfg.head_dim) * 4.0
    n_per_pattern = cfg.n_layers / max(len(cfg.block_pattern), 1)
    return b * per_layer * n_per_pattern


def analytic_hbm_bytes(cfg: ModelConfig, shape: ShapeSpec, n_total: int,
                       n_active: int, n_dev: int,
                       weight_bytes_per_param: float = 2.0) -> float:
    """Per-device HBM traffic per step (documented napkin model).

    train:  weights read fwd+bwd+remat-recompute (3x) + grad write (4B)
            + AdamW m/v read+write (16B) + param write (2B)
            + activation stream ~12 x tokens x d_model x layers x 2B
    prefill: active weights read once + activation stream ~6x + cache write
    decode:  active weights read once (every step!) + full cache read
    """
    toks_dev = shape.global_batch * shape.seq_len / n_dev
    d, nl = cfg.d_model, cfg.n_layers
    if shape.kind == "train":
        p_dev = n_total / n_dev
        w = p_dev * (3 * weight_bytes_per_param + 4 + 16 + 2)
        acts = 12.0 * toks_dev * d * nl * 2.0
        return w + acts
    if shape.kind == "prefill":
        p_dev = n_active / n_dev  # inactive experts untouched per token-block
        acts = 6.0 * toks_dev * d * nl * 2.0
        cache = kv_cache_bytes(cfg, shape) / n_dev
        return p_dev * weight_bytes_per_param + acts + cache
    # decode
    p_dev = n_active / n_dev
    cache = kv_cache_bytes(cfg, shape) / n_dev
    return p_dev * weight_bytes_per_param + cache


# ---------------------------------------------------------------------------
# rollout roofline: the specialized reservoir rollout on the same machine
# ---------------------------------------------------------------------------
def rollout_roofline(summary: dict, block: int, batch: int,
                     steps: int = 1) -> dict:
    """Roofline view of one specialized rollout schedule on the TPU-v5e
    anchor above: compute (folded-tile MACs on the MXU + digit adds on the
    VPU) against memory (the weight stream the regime implies — once if
    resident, per step if pipelined).  The plan autotuner uses this view
    for reporting; its pruning uses the calibrated linear model in
    :mod:`repro.core.costmodel`, which this shares its feature extraction
    with so the two can never disagree about what a schedule *does*.
    """
    from repro.core.costmodel import rollout_cost_features
    f = rollout_cost_features(summary, block, batch, steps)
    # one MAC = 2 FLOPs on the MXU; digit adds run on the VPU at roughly
    # 1/64 of MXU throughput (8x128 lanes vs the 128x128 systolic array)
    t_c = 2.0 * f["matmul_macs"] / PEAK_FLOPS \
        + f["shiftadd_ops"] / (PEAK_FLOPS / 64.0)
    t_m = f["stream_bytes"] / HBM_BW
    terms = {"compute": t_c, "memory": t_m}
    dom = max(terms, key=terms.get)
    if dom == "memory" and summary["regime"] == "pipelined":
        advice = ("pipelined bands re-stream the folded tiles every step: "
                  "raise the VMEM budget toward resident, or lower the "
                  "crossover so more planes strength-reduce to shift-adds")
    elif dom == "memory":
        advice = ("weight fetch dominates even resident: fewer steps "
                  "amortize the one-time hoist, or drop fp32 tiles to int8")
    else:
        advice = ("compute-bound: good; next lever is the shift-add "
                  "crossover (trade MXU passes against VPU adds)")
    return {"compute_s": t_c, "memory_s": t_m, "dominant": dom,
            "bound_s": max(terms.values()), "advice": advice}


# ---------------------------------------------------------------------------
# assembly
# ---------------------------------------------------------------------------
def _advice(dom: str, cfg: ModelConfig, shape: ShapeSpec) -> str:
    if dom == "collective":
        if cfg.moe is not None:
            return ("replicated-dispatch EP psums full activations every MoE "
                    "layer; switch combine to reduce-scatter + seq-sharding")
        return "shard more weights FSDP to turn all-reduces into reduce-scatters"
    if dom == "memory":
        if shape.kind == "decode":
            return ("weights re-read every token: int8/CSD frozen-weight "
                    "serving (paper technique) halves the stream")
        return "raise arithmetic intensity: bigger per-device batch or less remat"
    return "compute-bound: good; next win is overlap of FSDP gathers with matmuls"


def cell_report(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    n_dev = rec["n_devices"]
    n_total = rec["param_count"]
    n_act = active_params(cfg, n_total)

    flops_dev = rec["hlo_walk"]["dot_flops"] + rec["hlo_walk"]["conv_flops"]
    coll_dev = rec["hlo_walk"]["total_collective_bytes"]
    hbm_dev = analytic_hbm_bytes(cfg, shape, n_total, n_act, n_dev)

    t_c = flops_dev / PEAK_FLOPS
    t_m = hbm_dev / HBM_BW
    t_n = coll_dev / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_n}
    dom = max(terms, key=terms.get)
    mf = model_flops(cfg, shape, n_act)
    hlo_global = flops_dev * n_dev
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "dominant": dom,
        "model_flops": mf,
        "useful_ratio": mf / hlo_global if hlo_global else float("nan"),
        "step_s_bound": max(terms.values()),
        "roofline_frac": (terms["compute"] / max(terms.values())
                          if max(terms.values()) > 0 else 0.0),
        "peak_mem_gb": rec["memory_per_device"]["peak_bytes"] / 2**30,
        "advice": _advice(dom, cfg, shape),
    }


def load_all(mesh_dir: str = "pod16x16", variants: bool = False) -> list:
    out = []
    for p in sorted((RESULTS / mesh_dir).glob("*.json")):
        rec = json.loads(p.read_text())
        if bool(rec.get("variant")) != variants:
            continue
        out.append(rec)
    return out


def to_markdown(reports: list) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| 6ND/HLO | roofline frac | mem GB/dev | next lever |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in reports:
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} "
            f"| {r['memory_s']:.2e} | {r['collective_s']:.2e} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['roofline_frac']:.2f} | {r['peak_mem_gb']:.1f} "
            f"| {r['advice']} |")
    return hdr + "\n".join(rows)


def main():
    recs = load_all()
    reports = [r for r in (cell_report(x) for x in recs) if r]
    print(to_markdown(reports))
    out = RESULTS.parent / "roofline.md"
    out.write_text(to_markdown(reports) + "\n")
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
