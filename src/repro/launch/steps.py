"""Step builders: train / prefill / decode, mesh-aware.

Each builder returns (fn, example_args) ready for
``jax.jit(fn).lower(*example_args).compile()`` — the dry-run entry point —
and the same functions drive real training/serving in examples/.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch import specs as specs_lib
from repro.models.transformer import LM, ParallelCtx
from repro.optim import adamw
from repro.parallel.act import activation_mesh
from repro.parallel.sharding import data_axis_names


def make_ctx(mesh, cfg=None) -> ParallelCtx:
    if mesh is None:
        return ParallelCtx()
    daxes = data_axis_names(mesh) or ("data",)
    if cfg is not None and not cfg.use_tp and "model" in mesh.axis_names:
        daxes = daxes + ("model",)  # model axis joins DP/FSDP
    fsdp = cfg.expert_fsdp if cfg is not None else True
    return ParallelCtx(mesh=mesh, data_axes=daxes, fsdp=fsdp)


def _with_act_ctx(fn, mesh, ctx):
    """Run fn under the activation-sharding context so the in-model
    ``shard_batch`` anchors bake constraints into the traced program."""
    if mesh is None:
        return fn

    def wrapped(*args, **kw):
        with activation_mesh(mesh, ctx.data_axes, ctx.model_axis):
            return fn(*args, **kw)

    return wrapped


def make_train_step(lm: LM, mesh, opt_cfg: adamw.AdamWConfig | None = None,
                    grad_shardings=None):
    """grad_shardings: optional pytree of NamedSharding for the gradient
    accumulator (ZeRO: shard grads/optimizer even where the weights are
    kept resident, so per-microbatch reductions become reduce-scatters)."""
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    ctx = make_ctx(mesh, lm.cfg)
    k = max(lm.cfg.microbatches, 1)
    acc_dtype = (jnp.bfloat16 if lm.cfg.opt_dtype == "bfloat16"
                 else jnp.float32)

    def constrain(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_shardings)

    def grads_of(params, batch):
        return jax.value_and_grad(lambda p: lm.loss(p, batch, ctx))(params)

    def train_step(state, batch):
        if k == 1:
            loss, grads = grads_of(state["params"], batch)
        else:
            # gradient accumulation: activations live for one microbatch at
            # a time; the f32 grad accumulator inherits the param shardings.
            def split(x):
                b = x.shape[0]
                return x.reshape((k, b // k) + x.shape[1:])

            micro = jax.tree.map(split, batch)
            params = state["params"]

            def acc_step(carry, mb):
                tot_loss, acc = carry
                loss, grads = grads_of(params, mb)
                acc = constrain(jax.tree.map(
                    lambda a, g: a + g.astype(acc_dtype), acc, grads))
                return (tot_loss + loss, acc), None

            zeros = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params))
            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss / k
            grads = jax.tree.map(lambda g: g / k, grads)
        new_params, new_opt, metrics = adamw.apply_updates(
            state["params"], grads, state["opt"], opt_cfg)
        return ({"params": new_params, "opt": new_opt},
                {"loss": loss, **metrics})

    return _with_act_ctx(train_step, mesh, ctx)


def make_prefill_step(lm: LM, mesh, cache_len: int):
    ctx = make_ctx(mesh, lm.cfg)

    def prefill_step(params, batch):
        return lm.prefill(params, batch, cache_len=cache_len, ctx=ctx)

    return _with_act_ctx(prefill_step, mesh, ctx)


def make_decode_step(lm: LM, mesh):
    ctx = make_ctx(mesh, lm.cfg)

    def decode_step(params, caches, token):
        return lm.decode_step(params, caches, token, ctx=ctx)

    return _with_act_ctx(decode_step, mesh, ctx)


def lower_cell(arch_cfg: ModelConfig, shape: ShapeSpec, mesh,
               donate: bool = True):
    """Build + lower the step for one (arch x shape x mesh) cell.

    Returns (lowered, meta) where meta records what was lowered.
    """
    lm = LM(arch_cfg)
    serving = shape.kind != "train"
    fsdp = arch_cfg.fsdp and (arch_cfg.serving_fsdp if serving else True)
    param_structs, param_shardings = specs_lib.params_specs(lm, mesh,
                                                            fsdp=fsdp)

    if shape.kind != "train" and arch_cfg.frozen_sparse_serving:
        # paper technique: serving weights are frozen -> int8 storage
        from repro.models.quantize import quant_struct_like
        param_structs = quant_struct_like(param_structs)

    if shape.kind == "train":
        grad_sh = None
        opt_base = param_structs
        if not arch_cfg.expert_fsdp:
            # ZeRO: grads + optimizer states fully sharded even though the
            # expert weights stay EP-resident
            _, grad_sh = specs_lib.params_specs(lm, mesh, fsdp=True,
                                                expert_fsdp=True)
            opt_base = jax.tree.map(
                lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                   sharding=sh),
                param_structs, grad_sh)
        opt = specs_lib.opt_state_specs(opt_base, mesh,
                                        dtype=arch_cfg.opt_dtype)
        state = {"params": param_structs, "opt": opt}
        batch = specs_lib.batch_specs(arch_cfg, shape, mesh)
        fn = make_train_step(lm, mesh, grad_shardings=grad_sh)
        jitted = jax.jit(fn, donate_argnums=(0,) if donate else ())
        lowered = jitted.lower(state, batch)
        meta = {"step": "train_step", "donated": "state"}
    elif shape.kind == "prefill":
        batch = specs_lib.batch_specs(arch_cfg, shape, mesh)
        fn = make_prefill_step(lm, mesh, cache_len=shape.seq_len)
        jitted = jax.jit(fn)
        lowered = jitted.lower(param_structs, batch)
        meta = {"step": "prefill_step"}
    else:  # decode
        caches = specs_lib.cache_specs(lm, shape, mesh)
        token = specs_lib.token_spec(shape, mesh)
        fn = make_decode_step(lm, mesh)
        jitted = jax.jit(fn, donate_argnums=(1,) if donate else ())
        lowered = jitted.lower(param_structs, caches, token)
        meta = {"step": "serve_step", "donated": "caches"}
    return lowered, meta
