"""Continuous-batching scheduler: decode-style admission for rollouts.

One-shot ``ReservoirEngine.serve()`` takes a fully-formed request list,
pads it, and blocks until the whole group is rolled.  Under streaming
arrivals that wastes time twice: the batch cannot start until its last
request exists, and every sequence is padded to the group's length bucket.
This module serves the same requests decode-style instead:

* a fixed pool of **batch slots** (the compiled batch dimension never
  changes, so the engine reuses one program for every chunk),
* the engine runs in fixed ``chunk_steps`` segments, and between chunks
  finished sequences **retire** and queued ones are **admitted mid-flight**,
* each live slot's reservoir state is carried across chunks through the
  engine's ``run_segment`` chunk API, so the chunked trajectory is
  bit-identical to a one-shot rollout of the same inputs — the recurrence
  is stateful per sequence, which is exactly what makes reservoir
  continuous batching more than prompt re-padding.

The pool is **multi-tenant**: every slot is tagged with the engine its
request resolved to at admission (via a
:class:`~repro.serve.registry.ModelRegistry`), one FIFO interleaves all
tenants under per-tenant quotas/deadlines, and each chunk issues one
fused call per *active model* at the full pool shape — rows are
independent through the recurrence, so cross-tenant interleaving keeps
every sequence bit-identical to its single-tenant run.

:class:`ContinuousBatcher` owns the slot pool mechanics;
:class:`AsyncReservoirServer` adds the time-stamped arrival queue, the
virtual clock, and queue-wait / time-to-first-prediction / slot-occupancy
telemetry on :class:`~repro.serve.stats.ServeStats` (per tenant too).
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.runtime.faults import TransientFault
from repro.serve.api import (_UNSET, RolloutResult, SubmitSpec,
                             lifecycle_timings, warn_deprecated)
from repro.serve.batching import RolloutRequest
from repro.serve.stats import ServeStats


@dataclasses.dataclass
class QueuedRequest:
    """A :class:`RolloutRequest` plus its arrival time and lifecycle marks.

    The scheduler fills the ``*_time`` fields as the request moves through
    the system (all on the server's clock): ``admit_time`` when it takes a
    slot, ``first_output_time`` when its first chunk of predictions is
    ready, ``finish_time`` when it retires.

    ``deadline`` (absolute, on the same clock) bounds the queue wait: a
    request still queued past it is dropped at the next admission sweep —
    counted in ``ServeStats.timed_out`` — instead of occupying a slot for
    an answer nobody is waiting for anymore.

    ``model`` routes the request to a registry tenant;
    ``pinned_version`` is stamped when the request first seats and sticks
    for its whole life — a live swap never migrates in-flight (or
    shrink-re-admitted) work to the new version.
    """

    request: RolloutRequest
    arrival_time: float = 0.0
    seq: int = 0                         # submission index; FIFO tiebreak
    admit_time: float | None = None
    first_output_time: float | None = None
    finish_time: float | None = None
    deadline: float | None = None
    requeued: bool = False               # back in the queue after a shrink:
    #                                      the next seat is a re-admission
    #                                      and must not double-count stats
    model: str | None = None             # registry tenant (None = default)
    pinned_version: int | None = None    # frozen at first admission
    want_states: bool | None = None      # per-request output contract
    #                                      (None = the pool's default)
    as_result: bool = False              # SubmitSpec submission: answer a
    #                                      RolloutResult, not a bare array
    trace_id: str | None = None          # observability correlation id
    #                                      (threads through every span)

    @property
    def uid(self) -> Any:
        return self.request.uid

    @property
    def length(self) -> int:
        return self.request.length


class _DeviceChunk:
    """One chunk's full (n_slots, cs, O) device output, shared by every
    sequence that rode in it and host-converted at most once — the
    device->host sync happens when the first rider retires, never in the
    chunk loop.  At conversion every rider's reference is compacted to
    its own trimmed row copy, so neither the device buffer nor the
    full-width host array outlives the sync (a long-lived rider would
    otherwise pin pool-width buffers for its whole life)."""

    __slots__ = ("dev",)

    def __init__(self, dev):
        self.dev = dev


class ContinuousBatcher:
    """A fixed pool of batch slots rolled forward ``chunk_steps`` at a time.

    Single-tenant chunks are ONE engine call of the static shape
    ``(n_slots, chunk_steps, input_dim)`` — free slots ride along as zero
    rows — with the pool's reservoir states passed as ``x0`` and the
    post-chunk states carried through ``run_segment``.  Rows are
    independent through the recurrence (the batched matmuls and the
    elementwise epilogue never mix rows), so a sequence's chunked
    trajectory equals its one-shot rollout bit for bit.

    Multi-tenant chunks group the occupied slots by their admission-pinned
    engine and issue one fused call *per active model*, each at the full
    pool shape — the same shape (and therefore the same compiled program
    and the same per-row arithmetic) as the single-tenant chunk, which is
    what keeps cross-tenant interleaving bit-exact.  Post-chunk states
    merge by exact row selection.
    """

    def __init__(self, engine, *, n_slots: int = 8, chunk_steps: int = 16,
                 want_states: bool | None = None,
                 return_states: bool | None = _UNSET,
                 zero_copy: bool | None = None, warm: bool = True,
                 resolver=None):
        assert n_slots >= 1 and chunk_steps >= 1
        self.engine = engine
        self.n_slots = n_slots
        self.chunk_steps = chunk_steps
        if return_states is not _UNSET:
            warn_deprecated(
                "ContinuousBatcher(return_states=...) is deprecated; "
                "pass want_states=...")
            if want_states is None:
                want_states = return_states
        if want_states is None:
            want_states = not engine.has_readout
        self.want_states = want_states
        # admission hook: qreq -> engine (a registry-backed server routes
        # per-tenant here); None pins every slot to the default engine
        self._resolver = resolver
        self._slot_engines = [engine] * n_slots
        # zero-copy chunk serving: request inputs move to the device ONCE
        # at admission (into a resident (n_slots, max_chunks, cs, I)
        # buffer), a single jitted gather assembles each chunk's input
        # on-device, the carried state buffer is donated to each launch,
        # chunk outputs stay device-side, and the only device->host syncs
        # in the hot loop happen at slot retirement (``host_syncs`` counts
        # them).  The hot loop dispatches a constant handful of device
        # ops per chunk, independent of pool size.
        #
        # Default is backend-aware: on an accelerator the elided
        # transfers and deferred syncs are the win; on the CPU backend a
        # "transfer" is a memcpy while every extra dispatch costs real
        # Python/XLA overhead (measured ~2x per-chunk cost), so CPU
        # defaults to the host-assembled path.  Both paths produce
        # identical outputs and both are tested.
        if zero_copy is None:
            zero_copy = jax.default_backend() != "cpu"
        self.zero_copy = zero_copy
        self.host_syncs = 0
        self._in_dim = engine.config.input_dim
        self._dim = engine.config.reservoir_dim
        self._slots: list[QueuedRequest | None] = [None] * n_slots
        self._pos = [0] * n_slots               # steps consumed per slot
        self._chunks: list[list] = [[] for _ in range(n_slots)]
        self._states = jnp.zeros((n_slots, self._dim), jnp.float32)
        self._max_chunks = 4                    # input lanes; doubles on
        #                                         demand (longer requests)
        if zero_copy:
            self._u_dev = jnp.zeros(
                (n_slots, self._max_chunks, chunk_steps, self._in_dim),
                jnp.float32)
            self._gather = jax.jit(
                lambda u_dev, idx:
                u_dev[jnp.arange(u_dev.shape[0]), idx])
            # donated in-place lane write: admission cost stays O(request)
            # on accelerators instead of copying the whole pooled buffer
            self._lane_set = jax.jit(
                lambda buf, slot, lanes: jax.lax.dynamic_update_slice(
                    buf, lanes[None], (slot, 0, 0, 0)),
                donate_argnums=(0,))
        self.last_take: dict = {}               # slot -> steps, last chunk
        self.last_retired_slots: list = []
        self.last_models: dict = {}             # slot -> model, last chunk
        # fault injection (set by the server): transient engine-call
        # failures raised by the plan are retried here with capped
        # exponential backoff; the per-chunk virtual-clock charge and
        # retry count land in last_backoff_s / last_retries for the
        # server to account
        self.fault_plan = None
        self.last_backoff_s = 0.0
        self.last_retries = 0
        if warm:
            self._warm()

    @property
    def return_states(self) -> bool:
        """Deprecated alias of ``want_states`` (kept one release)."""
        return self.want_states

    def _want_of(self, qreq: QueuedRequest) -> bool:
        return (self.want_states if qreq.want_states is None
                else qreq.want_states)

    def _check_dims(self, engine) -> None:
        cfg = engine.config
        if (cfg.input_dim != self._in_dim
                or cfg.reservoir_dim != self._dim):
            raise ValueError(
                f"engine dims (I={cfg.input_dim}, R={cfg.reservoir_dim}) "
                f"do not match the pool's (I={self._in_dim}, "
                f"R={self._dim}): models sharing a slot pool must share "
                "input/reservoir dims — serve differently-sized models "
                "from separate pools")

    def _warm(self) -> None:
        """Pre-compile the pool's exact chunk program + per-slot ops.

        The batcher owns one static shape for its whole life, so every
        program it will ever run can compile at construction: the
        (donated) chunk rollout, the input gather, and admission's
        per-slot state seeding — none of it lands in the measured serving
        makespan.  Bypasses the engine's public API so warmup never
        pollutes ``ServeStats`` or the request telemetry.
        """
        if not self.want_states and not self.engine.has_readout:
            return      # run_chunk will raise the clear "readout not
            #             trained" error; nothing sane to warm
        if self.zero_copy:
            # admission's device ops: one warm call each compiles the
            # program every slot index reuses (the index is an operand)
            self._gather(self._u_dev, jnp.zeros(self.n_slots, jnp.int32))
            row = jnp.zeros((self._dim,), jnp.float32)
            self._states.at[0].set(row)
            self._u_dev = self._lane_set(
                self._u_dev, 0,
                jnp.zeros(self._u_dev.shape[1:], jnp.float32))
        self.warm_engine(self.engine)

    def warm_engine(self, engine, want_states: bool | None = None) -> None:
        """Compile ``engine``'s pool-shaped chunk program(s), off the
        serving clock.

        Used at construction for the default engine, and by
        :meth:`ModelRegistry.publish` to compile a *new model version
        behind live traffic* — the swap cutover then costs the scheduler
        nothing.  On the zero-copy path both chunk variants are warmed:
        the donated single-tenant launch and the non-donated variant that
        mixed (multi-model) chunks use.  Bypasses the engine's public API
        so warmup never pollutes ``ServeStats``.
        """
        self._check_dims(engine)
        if want_states is None:
            want_states = (self.want_states if engine.has_readout
                           else True)
        u = jnp.zeros((self.n_slots, self.chunk_steps, self._in_dim),
                      jnp.float32)
        for donate in ((True, False) if self.zero_copy else (False,)):
            x0 = jnp.zeros((self.n_slots, self._dim), jnp.float32)
            out, _xf = engine._dispatch(u, x0, not want_states, True,
                                        donate)
            jax.block_until_ready(out)

    @property
    def live(self) -> int:
        return sum(s is not None for s in self._slots)

    def has_free_slot(self) -> bool:
        return any(s is None for s in self._slots)

    def _free_slot(self) -> int:
        """Pick the free slot to seat the next request in.  Subclass hook:
        the sharded batcher overrides this with least-loaded-shard
        admission."""
        return self._slots.index(None)

    def shard_of(self, slot: int) -> int | None:
        """Which device shard ``slot`` maps to — ``None`` on the
        single-device pool.  Subclass hook: the sharded batcher answers
        the real shard index, and the observability layer uses it to
        label per-shard queue-wait/latency series."""
        return None

    def admit(self, qreq: QueuedRequest) -> int:
        """Seat a request in a free slot (zero state, or its ``x0``).

        The slot is tagged with the engine the request resolves to —
        through the ``resolver`` (registry routing, which also pins the
        model version on the request) or the pool default — and keeps it
        for the request's whole life.
        """
        eng = (self.engine if self._resolver is None
               else self._resolver(qreq))
        self._check_dims(eng)
        if not self._want_of(qreq) and not eng.has_readout:
            raise ValueError(
                "readout not trained on the serving engine; submit with "
                "want_states=True")
        slot = self._free_slot()
        self._slot_engines[slot] = eng
        self._slots[slot] = qreq
        self._pos[slot] = 0
        self._chunks[slot] = []
        if self.zero_copy:
            # ONE host->device transfer per request: the whole input,
            # pre-cut into chunk_steps segments, lands in the slot's lane
            # of the resident input buffer.  Lanes double when a request
            # is longer than any seen before (shape change -> the gather
            # re-specializes once, then stays cached).
            cs = self.chunk_steps
            seg = np.asarray(qreq.request.inputs, np.float32)
            n_chunks = max(1, -(-seg.shape[0] // cs))
            if n_chunks > self._max_chunks:
                while n_chunks > self._max_chunks:
                    self._max_chunks *= 2
                # one reallocation straight to the final lane count
                self._u_dev = jnp.zeros(
                    (self.n_slots, self._max_chunks, cs, self._in_dim),
                    jnp.float32).at[:, : self._u_dev.shape[1]].set(
                        self._u_dev)
            padded = np.zeros((self._max_chunks * cs,) + seg.shape[1:],
                              np.float32)
            padded[: seg.shape[0]] = seg
            self._u_dev = self._lane_set(
                self._u_dev, slot,
                jnp.asarray(padded.reshape(self._max_chunks, cs, -1)))
        x0 = qreq.request.x0
        row = (jnp.zeros((self._dim,), jnp.float32) if x0 is None
               else jnp.asarray(x0, jnp.float32))
        self._states = self._states.at[slot].set(row)
        return slot

    def run_chunk(self) -> tuple[list[tuple[QueuedRequest, np.ndarray]], int]:
        """Roll every slot ``chunk_steps`` forward.

        Returns ``(retired, real_steps)``: each retiree is
        ``(qreq, output)`` with the full (T_request, O/R) output assembled
        from its chunks, and ``real_steps`` counts the input steps the
        chunk actually consumed (seated slots' remaining lengths, capped
        at ``chunk_steps`` — the occupancy numerator).  Sequences that
        finish inside the chunk stop accumulating output at their real
        length (the recurrence is causal, so the zero-padded tail steps
        cannot reach them).

        Occupied slots are grouped by their admission-pinned
        ``(engine, want_states)`` and the chunk issues one fused
        ``run_segment`` per group, every one at the full pool shape —
        a slot's rows go through exactly the arithmetic they would in a
        single-tenant pool, so interleaving tenants (or running both
        sides of a live swap) is bit-exact.  A single-group chunk is
        byte-for-byte the old fast path: one call, donated carry on the
        zero-copy path.
        """
        cs = self.chunk_steps
        take: dict[int, int] = {}
        if self.zero_copy:
            # ONE jitted gather assembles the (n_slots, cs, I) chunk from
            # the device-resident input buffer — no host->device copy and
            # no per-slot dispatch in the hot loop.  Free slots gather
            # lane 0 (stale or zero rows): their output is discarded and
            # their state is re-seeded at admission, so the rows are
            # inert ballast exactly like the zero rows of the host path.
            idx = np.zeros(self.n_slots, np.int32)
            for i, q in enumerate(self._slots):
                if q is None:
                    continue
                idx[i] = self._pos[i] // cs
                take[i] = min(cs, q.length - self._pos[i])
            u = self._gather(self._u_dev, jnp.asarray(idx))
        else:
            u_host = np.zeros((self.n_slots, cs, self._in_dim), np.float32)
            for i, q in enumerate(self._slots):
                if q is None:
                    continue
                seg = np.asarray(
                    q.request.inputs[self._pos[i]:self._pos[i] + cs],
                    np.float32)
                u_host[i, :len(seg)] = seg
                take[i] = len(seg)
            u = jnp.asarray(u_host)
        # group occupied slots by pinned (engine, contract); slot order
        # inside and across groups is deterministic (dict insertion
        # follows slot index)
        groups: dict = {}
        for i, q in enumerate(self._slots):
            if q is None:
                continue
            eng = self._slot_engines[i]
            want = self._want_of(q)
            groups.setdefault((id(eng), want), (eng, want, []))[2].append(i)
        if not groups:
            # empty pool (direct run_chunk call): keep the old contract of
            # one inert full-pool roll on the default engine
            groups = {None: (self.engine, self.want_states, [])}
        single = len(groups) == 1
        prev = self._states
        new_states = None
        self.last_backoff_s = 0.0
        self.last_retries = 0
        for eng, want, slots in groups.values():
            # zero-copy single group: the carried state buffer is donated
            # to the launch (this batcher owns it and immediately replaces
            # it with xf).  With several groups every call reads ``prev``,
            # so nothing may donate it.  Host syncs stay deferred to
            # retirement either way.  An armed fault plan also disables
            # donation: a failed call must leave the carried state intact
            # for the retry to replay from.
            donate = self.zero_copy and single and self.fault_plan is None
            out, xf = self._faulting_call(
                eng, u, prev, want=want,
                real_steps=sum(take.get(i, 0) for i in slots),
                donate=donate)
            if single:
                new_states = xf
            else:
                # exact row selection: where() copies rows unchanged, so
                # the merge cannot perturb bit-exactness
                sel = np.zeros(self.n_slots, bool)
                sel[slots] = True
                new_states = jnp.where(
                    jnp.asarray(sel)[:, None], xf,
                    prev if new_states is None else new_states)
            if self.zero_copy:
                # the whole device-side chunk buffer is shared by its
                # riders (each remembering its real length); no per-slot
                # device op, no host transfer until a rider retires
                chunk = _DeviceChunk(out)
                for i in slots:
                    self._chunks[i].append((chunk, take[i]))
            else:
                self.host_syncs += 1
                out_h = np.asarray(out)
                for i in slots:
                    self._chunks[i].append(out_h[i, :take[i]].copy())
        self._states = new_states if new_states is not None else prev
        models = {}
        for i, n in take.items():
            self._pos[i] += n
            models[i] = self._slots[i].model
        retired = []
        retired_slots = []
        # retire in a second pass: a retirement materializes the shared
        # chunk buffer (rewriting every rider's entry), so every rider
        # must have its entry before the first retiree triggers that
        for i in take:
            q = self._slots[i]
            if self._pos[i] >= q.length:
                retired.append((q, self._assemble(i)))
                retired_slots.append(i)
                self._slots[i] = None
                self._chunks[i] = []
                self._slot_engines[i] = self.engine
        # per-slot view of the chunk just run, for per-shard/tenant
        # telemetry
        self.last_take = dict(take)
        self.last_retired_slots = retired_slots
        self.last_models = models
        return retired, sum(take.values())

    def _faulting_call(self, eng, u, prev, *, want, real_steps, donate):
        """One fused chunk launch under the (optional) fault plan.

        An injected :class:`~repro.runtime.faults.TransientFault` is
        retried with capped exponential backoff *from the slot's last
        carried state*: ``u`` and ``prev`` are untouched by the failed
        attempt (donation is disabled while a plan is armed), so the
        retry runs the exact same program on the exact same operands —
        a bit-identical replay, not a best-effort one.  The accumulated
        backoff lands in ``last_backoff_s`` for the server to charge to
        its virtual clock.
        """
        fp = self.fault_plan
        if fp is None:
            return eng.run_segment(u, prev, want_states=want,
                                   real_steps=real_steps,
                                   donate_state=donate,
                                   defer_sync=self.zero_copy)
        attempt = 0
        while True:
            try:
                fp.check_call()
                return eng.run_segment(u, prev, want_states=want,
                                       real_steps=real_steps,
                                       donate_state=donate,
                                       defer_sync=self.zero_copy)
            except TransientFault:
                if attempt >= fp.max_attempts:
                    raise
                self.last_backoff_s += fp.backoff_s(attempt)
                self.last_retries += 1
                attempt += 1
                obs.inc("engine_call_retries_total")

    def _materialize(self, chunk: _DeviceChunk) -> None:
        """THE deferred device->host sync point, paid once per chunk
        buffer no matter how many riders retire from it, and only ever
        reached from retirement/snapshot paths.  Every rider's entry is
        rewritten to its own trimmed row copy, so the full-width buffer
        (device AND host) is immediately collectable — a long-lived rider
        never pins pool-width chunk buffers."""
        host = np.asarray(chunk.dev)
        chunk.dev = None
        self.host_syncs += 1
        for s, entries in enumerate(self._chunks):
            for j, (c, n) in enumerate(entries):
                if c is chunk:
                    entries[j] = (host[s, :n].copy(), n)

    def _slot_rows(self, slot: int) -> list:
        """A slot's chunk outputs as trimmed host rows (zero-copy path),
        materializing any still-device-side buffers."""
        entries = self._chunks[slot]
        for idx in range(len(entries)):
            c, _n = entries[idx]
            if isinstance(c, _DeviceChunk):
                self._materialize(c)            # rewrites entries[idx]
        return [row for row, _n in entries]

    def remaining_inputs(self, slot: int) -> np.ndarray:
        """A live slot's not-yet-consumed input steps, (T_left, I) float32.

        On the zero-copy path the device-resident lane is the source of
        truth — the caller's host buffer was free to be reused the moment
        ``admit()`` uploaded it, so the elastic-shrink snapshot must NOT
        re-read it."""
        q = self._slots[slot]
        lo = self._pos[slot]
        if not self.zero_copy:
            return np.asarray(q.request.inputs, np.float32)[lo:]
        cs = self.chunk_steps
        n_chunks = max(1, -(-q.length // cs))
        flat = np.asarray(self._u_dev[slot, :n_chunks]).reshape(
            n_chunks * cs, self._in_dim)
        return flat[lo: q.length]

    def chunk_outputs(self, slot: int) -> list:
        """Host copies of a live slot's chunks so far (syncs; used by the
        elastic-shrink snapshot, not the hot loop)."""
        if self.zero_copy:
            return self._slot_rows(slot)
        return list(self._chunks[slot])

    def _assemble(self, slot: int) -> np.ndarray:
        """Concatenate a retiring slot's chunks into its full output.

        On the zero-copy path the underlying buffers sync (at most once
        each) here — at retirement, never in the chunk loop."""
        if self.zero_copy:
            return np.concatenate(self._slot_rows(slot), axis=0)
        return np.concatenate(self._chunks[slot], axis=0)


class AsyncReservoirServer:
    """Time-stamped request queue in front of a :class:`ContinuousBatcher`.

    ``submit()`` enqueues requests with arrival timestamps;  ``run()``
    (or repeated ``step()`` calls) drains the queue: admit every arrived
    request that fits the pool, roll one chunk, retire finished sequences,
    repeat.  Admission is strictly FIFO in (arrival_time, submission
    order), except that a request held back only by its tenant's
    concurrency quota steps aside for later arrivals (it stays queued and
    is re-considered every sweep).

    Attach a :class:`~repro.serve.registry.ModelRegistry` to serve many
    models from one pool: a :class:`~repro.serve.api.SubmitSpec` with
    ``model="name"`` resolves (and pins) the registry's active version at
    admission, the chunk loop groups slots per model, and per-tenant
    telemetry lands in ``tenant_stats``.  ``registry.publish()`` swaps a
    model live: in-flight slots keep their pinned engine, new admissions
    take the new one.

    The server keeps a virtual clock ``now``: it advances by each chunk's
    measured wall time (or the fixed ``chunk_time`` if given — useful for
    deterministic tests and trace-driven benchmarks) and jumps forward to
    the next arrival when the pool runs empty.  Queue waits,
    time-to-first-prediction and slot occupancy land in ``stats``.
    """

    def __init__(self, engine, *, n_slots: int = 8, chunk_steps: int = 16,
                 want_states: bool | None = None,
                 return_states: bool | None = _UNSET,
                 stats: ServeStats | None = None,
                 chunk_time: float | None = None,
                 batcher: ContinuousBatcher | None = None,
                 zero_copy: bool | None = None,
                 registry=None, admission=None, fault_plan=None):
        if return_states is not _UNSET:
            warn_deprecated(
                "AsyncReservoirServer(return_states=...) is deprecated; "
                "pass want_states=... (or set want_states per request on "
                "SubmitSpec)")
            if want_states is None:
                want_states = return_states
        if batcher is None:
            batcher = ContinuousBatcher(
                engine, n_slots=n_slots, chunk_steps=chunk_steps,
                want_states=want_states, zero_copy=zero_copy,
                resolver=self._resolve_engine)
        elif batcher._resolver is None:
            batcher._resolver = self._resolve_engine
        self.batcher = batcher
        self.stats = stats if stats is not None else engine.stats
        self.chunk_time = chunk_time
        self.now = 0.0
        self.results: dict[Any, Any] = {}
        self._queue: list[tuple[float, int, QueuedRequest]] = []
        self._seq = 0
        self.registry = None
        self.tenant_stats: dict[str, ServeStats] = {}
        # backpressure: an AdmissionPolicy consulted at submit time; None
        # keeps the historical accept-everything FIFO
        self.admission = admission
        # fault injection: the plan is driven by this server's clock and
        # consulted by the batcher's chunk launches
        self.fault_plan = fault_plan
        self.batcher.fault_plan = fault_plan
        if registry is not None:
            registry.attach(self)

    # -- multi-tenant plumbing -----------------------------------------------
    def _tstats(self, model: str | None) -> ServeStats | None:
        if model is None:
            return None
        st = self.tenant_stats.get(model)
        if st is None:
            st = self.tenant_stats[model] = ServeStats()
        return st

    def tenant_summary(self) -> ServeStats:
        """Per-tenant breakdown merged into one view (``.shards`` keyed by
        model name)."""
        names = sorted(self.tenant_stats)
        return ServeStats.merge([self.tenant_stats[n] for n in names],
                                labels=names)

    def _tenant_engine(self, name: str, version: int):
        """Engine for a pinned (model, version) — the seam the sharded
        server overrides to build mesh-mapped engines instead."""
        return self.registry.engine(name, version)

    def _resolve_engine(self, qreq: QueuedRequest):
        """Admission-time routing: pin the model's active version to the
        request (a later ``publish()`` must not migrate it) and return its
        engine."""
        if qreq.model is None or self.registry is None:
            return self.batcher.engine
        if qreq.pinned_version is None:
            qreq.pinned_version = self.registry.active_version(qreq.model)
        return self._tenant_engine(qreq.model, qreq.pinned_version)

    def prewarm_model(self, name: str, version: int):
        """Build + compile a model version against this pool's shapes
        before any request routes to it — ``publish()`` calls this on
        every attached server so cutover never compiles under traffic."""
        eng = self._tenant_engine(name, version)
        self.batcher.warm_engine(eng)
        return eng

    # -- queue ---------------------------------------------------------------
    def submit(self, request, arrival_time: float | None = None,
               deadline: float | None = None) -> QueuedRequest:
        """Enqueue one :class:`SubmitSpec`; ``arrival_time`` defaults to
        ``now``.

        ``deadline`` (or ``spec.deadline``, which wins) is an absolute
        time on the server's clock: a request still waiting in the queue
        past it is dropped (``timed_out`` in stats) rather than seated.
        A request already in a slot always runs to completion.  A spec
        naming a ``model`` routes through the attached registry and
        inherits its per-tenant deadline policy when neither deadline is
        given.

        Passing a bare :class:`RolloutRequest` still works for one
        release (with a DeprecationWarning) and answers with the raw
        output array; specs answer with :class:`RolloutResult`.

        When an :class:`~repro.serve.admission.AdmissionPolicy` is
        attached it is consulted here, before the request joins the
        queue: a refusal answers immediately with a
        ``RolloutResult(status="rejected")`` (reason + ``retry_after_s``
        hint in ``timings``) instead of a :class:`QueuedRequest` —
        bounded backpressure, never silent unbounded queueing.
        """
        at = self.now if arrival_time is None else float(arrival_time)
        if isinstance(request, SubmitSpec):
            spec = request
            if spec.model is not None and self.registry is None:
                raise ValueError(
                    f"SubmitSpec routes to model {spec.model!r} but this "
                    "server has no registry attached")
            uid = spec.uid if spec.uid is not None else f"req{self._seq}"
            dl = spec.deadline if spec.deadline is not None else deadline
            if dl is None and spec.model is not None:
                rel = self.registry.deadline_s(spec.model)
                if rel is not None:
                    dl = at + rel
            qreq = QueuedRequest(
                RolloutRequest(uid, np.asarray(spec.inputs, np.float32),
                               x0=spec.x0),
                arrival_time=at, seq=self._seq,
                deadline=None if dl is None else float(dl),
                model=spec.model, want_states=spec.want_states,
                as_result=True,
                trace_id=spec.trace_id or obs.new_trace_id())
        else:
            warn_deprecated(
                "submit(RolloutRequest, ...) is deprecated; submit a "
                "SubmitSpec (results become RolloutResult — read .output)")
            qreq = QueuedRequest(request, arrival_time=at, seq=self._seq,
                                 deadline=None if deadline is None
                                 else float(deadline),
                                 trace_id=obs.new_trace_id())
        self._seq += 1
        if self.admission is not None:
            verdict = self.admission.admit(self, qreq)
            if verdict is not None:
                return self._reject(qreq, verdict)
        heapq.heappush(self._queue, (at, qreq.seq, qreq))
        self.stats.record_enqueue()
        obs.inc("requests_submitted_total",
                **({} if qreq.model is None else {"model": qreq.model}))
        obs.span("request.enqueue", at, trace_id=qreq.trace_id,
                 clock="server", uid=str(qreq.uid), model=qreq.model)
        ts = self._tstats(qreq.model)
        if ts is not None:
            ts.record_enqueue()
        return qreq

    def _reject(self, qreq: QueuedRequest, verdict) -> RolloutResult:
        """Refuse one submission at the door: count it (``rejected`` or
        ``shed``), emit the obs metric, and answer an explicit
        ``status="rejected"`` result carrying the reason and the
        policy's retry-after hint.  The request never enters the queue
        and never appears in ``enqueued``/``timed_out``."""
        self.stats.record_rejection(shed=verdict.shed)
        labels = {} if qreq.model is None else {"model": qreq.model}
        obs.inc("requests_shed_total" if verdict.shed
                else "requests_rejected_total",
                reason=verdict.reason, **labels)
        obs.span("request.reject", self.now, trace_id=qreq.trace_id,
                 clock="server", uid=str(qreq.uid), reason=verdict.reason)
        ts = self._tstats(qreq.model)
        if ts is not None:
            ts.record_rejection(shed=verdict.shed)
        timings = lifecycle_timings(
            arrival_time=qreq.arrival_time, admit_time=qreq.arrival_time,
            finish_time=qreq.arrival_time, model=qreq.model,
            trace_id=qreq.trace_id)
        timings["reason"] = verdict.reason
        timings["retry_after_s"] = float(verdict.retry_after_s)
        result = RolloutResult(timings=timings, status="rejected")
        self.results[qreq.uid] = result
        return result

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def drained(self) -> bool:
        return not self._queue and self.batcher.live == 0

    def _over_quota(self, qreq: QueuedRequest) -> bool:
        """Would seating this request push its tenant past its registry
        concurrency quota (live slots of the same model)?"""
        if qreq.model is None or self.registry is None:
            return False
        quota = self.registry.quota(qreq.model)
        if quota is None:
            return False
        live = sum(1 for q in self.batcher._slots
                   if q is not None and q.model == qreq.model)
        return live >= quota

    def _timeout(self, qreq: QueuedRequest) -> None:
        """Bookkeeping for one queued request dropped past its deadline."""
        self.stats.record_timeout()
        obs.inc("requests_timed_out_total",
                **({} if qreq.model is None else {"model": qreq.model}))
        obs.span("request.timeout", self.now, trace_id=qreq.trace_id,
                 clock="server", uid=str(qreq.uid))
        ts = self._tstats(qreq.model)
        if ts is not None:
            ts.record_timeout()

    def _drop_expired(self) -> None:
        """Drop every *arrived* queued request whose deadline has passed.

        Called on every clock advance — not only at admission sweeps.
        The sweep in :meth:`_admit_arrived` only examines the queue head
        while slots are free, so a request waiting behind a live head
        (pool full) used to linger past its deadline until a slot freed;
        this catches it the step its deadline passes."""
        expired = [entry for entry in self._queue
                   if (entry[2].deadline is not None
                       and entry[0] <= self.now
                       and self.now > entry[2].deadline)]
        if not expired:
            return
        dropped = {id(entry[2]) for entry in expired}
        self._queue = [entry for entry in self._queue
                       if id(entry[2]) not in dropped]
        heapq.heapify(self._queue)
        for _, _, qreq in expired:
            self._timeout(qreq)

    def _admit_arrived(self) -> None:
        held: list[tuple[float, int, QueuedRequest]] = []
        while self._queue and self._queue[0][0] <= self.now:
            qreq = self._queue[0][2]
            if qreq.deadline is not None and self.now > qreq.deadline:
                # expired while queued: drop it instead of rolling steps
                # nobody is waiting for anymore
                heapq.heappop(self._queue)
                self._timeout(qreq)
                continue
            if not self.batcher.has_free_slot():
                break
            if self._over_quota(qreq):
                # set the request aside for this sweep so tenants under
                # quota seat past it — it rejoins the queue (original
                # FIFO key) for the next sweep
                held.append(heapq.heappop(self._queue))
                self.stats.record_quota_hold()
                obs.inc("quota_holds_total",
                        **({} if qreq.model is None
                           else {"model": qreq.model}))
                ts = self._tstats(qreq.model)
                if ts is not None:
                    ts.record_quota_hold()
                continue
            heapq.heappop(self._queue)
            qreq.admit_time = self.now
            slot = self.batcher.admit(qreq)
            if qreq.requeued:
                qreq.requeued = False
            else:
                wait = self.now - qreq.arrival_time
                self.stats.record_admission(wait)
                obs.observe("queue_wait_seconds", wait,
                            **self._obs_labels(qreq, slot))
                obs.span("request.queued", qreq.arrival_time, self.now,
                         trace_id=qreq.trace_id, clock="server",
                         uid=str(qreq.uid), slot=slot)
                ts = self._tstats(qreq.model)
                if ts is not None:
                    ts.record_admission(wait)
        for entry in held:
            heapq.heappush(self._queue, entry)

    # -- results -------------------------------------------------------------
    def _obs_labels(self, qreq: QueuedRequest, slot: int | None) -> dict:
        """Metric labels for one request: tenant when routed, shard when
        the pool is sharded (nothing otherwise — unlabeled series merge
        naturally)."""
        labels: dict = {}
        if qreq.model is not None:
            labels["model"] = qreq.model
        if slot is not None:
            shard = self.batcher.shard_of(slot)
            if shard is not None:
                labels["shard"] = shard
        return labels

    def _package(self, qreq: QueuedRequest, out) -> Any:
        """Raw array for legacy RolloutRequest submissions, RolloutResult
        for specs.  Timings follow the one documented schema
        (:func:`~repro.serve.api.lifecycle_timings`): ``first_output_time``
        comes straight off the request's lifecycle mark — including marks
        from chunks long before retirement — with retirement as the
        one-chunk-request fallback."""
        if not qreq.as_result:
            return out
        want = self.batcher._want_of(qreq)
        return RolloutResult(preds=None if want else out,
                             states=out if want else None,
                             timings=lifecycle_timings(
                                 arrival_time=qreq.arrival_time,
                                 admit_time=qreq.admit_time,
                                 finish_time=qreq.finish_time,
                                 first_output_time=qreq.first_output_time,
                                 model=qreq.model,
                                 version=qreq.pinned_version,
                                 trace_id=qreq.trace_id))

    # -- event loop ----------------------------------------------------------
    def _handle_faults(self) -> None:
        """Fault-plan hook between clock activation and admission.  The
        base pool has no shards to lose (transient failures are retried
        inside the batcher, straggler windows charged at clock advance);
        the distributed server overrides this to convert activated shard
        deaths into the elastic ``shrink()`` path."""

    def step(self) -> bool:
        """Admit + one chunk + retire.  Returns False once drained."""
        if self.drained:
            return False
        if self.batcher.live == 0 and self._queue:
            # pool idle: fast-forward the clock to the next arrival
            self.now = max(self.now, self._queue[0][0])
        if self.fault_plan is not None:
            self.fault_plan.begin_chunk(self.now)
            self._handle_faults()
        self._admit_arrived()
        if self.batcher.live == 0:
            # everything at the head expired (or only future arrivals are
            # left): no chunk to run this step
            return not self.drained
        t0 = time.perf_counter()
        chunk_start = self.now
        retired, real_steps = self.batcher.run_chunk()
        wall = time.perf_counter() - t0
        dt = wall if self.chunk_time is None else self.chunk_time
        if self.fault_plan is not None:
            # straggler windows inflate the chunk's charge; retry backoff
            # from transient failures is time the requests really waited
            dt = dt * self.fault_plan.slow_factor() \
                + self.batcher.last_backoff_s
            for _ in range(self.batcher.last_retries):
                self.stats.record_retry()
        self.now += dt
        # deadlines are checked on every clock advance, not only at
        # admission sweeps — an expired request must not linger behind a
        # full pool
        self._drop_expired()
        self.stats.record_chunk(
            live_steps=real_steps,
            total_steps=self.batcher.n_slots * self.batcher.chunk_steps)
        obs.span("scheduler.chunk", chunk_start, self.now, clock="server",
                 live_steps=real_steps, retired=len(retired))
        obs.observe("chunk_seconds", wall)
        # per-slot shard labels for this chunk's retirees (run_chunk
        # already freed their slots, so read its per-chunk view)
        retired_slot = dict(zip((q.uid for q, _ in retired),
                                self.batcher.last_retired_slots))
        slot_of = {q.uid: i for i, q in enumerate(self.batcher._slots)
                   if q is not None}
        slot_of.update(retired_slot)
        for qreq, out in retired:
            qreq.finish_time = self.now
            latency = self.now - qreq.arrival_time
            self.results[qreq.uid] = self._package(qreq, out)
            self.stats.record_completion(latency)
            labels = self._obs_labels(qreq, slot_of.get(qreq.uid))
            obs.observe("request_latency_seconds", latency,
                        path="scheduler", **labels)
            obs.inc("requests_completed_total", **labels)
            obs.span("request.serve", qreq.admit_time, self.now,
                     trace_id=qreq.trace_id, clock="server",
                     uid=str(qreq.uid), **labels)
            ts = self._tstats(qreq.model)
            if ts is not None:
                ts.record_completion(latency)
        # first-output marks: every seated-or-just-retired request that has
        # produced output by the end of this chunk
        for qreq in list(self.batcher._slots) + [q for q, _ in retired]:
            if (qreq is not None and qreq.first_output_time is None
                    and qreq.admit_time is not None):
                qreq.first_output_time = self.now
                ttfp = self.now - qreq.arrival_time
                self.stats.record_first_output(ttfp)
                labels = self._obs_labels(qreq, slot_of.get(qreq.uid))
                obs.observe("ttfp_seconds", ttfp, **labels)
                obs.span("request.first_output", self.now,
                         trace_id=qreq.trace_id, clock="server",
                         uid=str(qreq.uid))
                ts = self._tstats(qreq.model)
                if ts is not None:
                    ts.record_first_output(ttfp)
                res = self.results.get(qreq.uid)
                if isinstance(res, RolloutResult):
                    res.timings["first_output_time"] = self.now
                    res.timings["ttfp_s"] = ttfp
        return True

    def run(self) -> dict:
        """Drain the queue; returns ``{uid: RolloutResult}`` (raw arrays
        for legacy RolloutRequest submissions)."""
        while self.step():
            pass
        return self.results


__all__ = ["QueuedRequest", "ContinuousBatcher", "AsyncReservoirServer"]
