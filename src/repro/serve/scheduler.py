"""Continuous-batching scheduler: decode-style admission for rollouts.

One-shot ``ReservoirEngine.serve()`` takes a fully-formed request list,
pads it, and blocks until the whole group is rolled.  Under streaming
arrivals that wastes time twice: the batch cannot start until its last
request exists, and every sequence is padded to the group's length bucket.
This module serves the same requests decode-style instead:

* a fixed pool of **batch slots** (the compiled batch dimension never
  changes, so the engine reuses one program for every chunk),
* the engine runs in fixed ``chunk_steps`` segments, and between chunks
  finished sequences **retire** and queued ones are **admitted mid-flight**,
* each live slot's reservoir state is carried across chunks through the
  engine's ``return_final_state`` chunk API, so the chunked trajectory is
  bit-identical to a one-shot rollout of the same inputs — the recurrence
  is stateful per sequence, which is exactly what makes reservoir
  continuous batching more than prompt re-padding.

:class:`ContinuousBatcher` owns the slot pool mechanics;
:class:`AsyncReservoirServer` adds the time-stamped arrival queue, the
virtual clock, and queue-wait / time-to-first-prediction / slot-occupancy
telemetry on :class:`~repro.serve.stats.ServeStats`.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.batching import RolloutRequest
from repro.serve.stats import ServeStats


@dataclasses.dataclass
class QueuedRequest:
    """A :class:`RolloutRequest` plus its arrival time and lifecycle marks.

    The scheduler fills the ``*_time`` fields as the request moves through
    the system (all on the server's clock): ``admit_time`` when it takes a
    slot, ``first_output_time`` when its first chunk of predictions is
    ready, ``finish_time`` when it retires.

    ``deadline`` (absolute, on the same clock) bounds the queue wait: a
    request still queued past it is dropped at the next admission sweep —
    counted in ``ServeStats.timed_out`` — instead of occupying a slot for
    an answer nobody is waiting for anymore.
    """

    request: RolloutRequest
    arrival_time: float = 0.0
    seq: int = 0                         # submission index; FIFO tiebreak
    admit_time: float | None = None
    first_output_time: float | None = None
    finish_time: float | None = None
    deadline: float | None = None
    requeued: bool = False               # back in the queue after a shrink:
    #                                      the next seat is a re-admission
    #                                      and must not double-count stats

    @property
    def uid(self) -> Any:
        return self.request.uid

    @property
    def length(self) -> int:
        return self.request.length


class _DeviceChunk:
    """One chunk's full (n_slots, cs, O) device output, shared by every
    sequence that rode in it and host-converted at most once — the
    device->host sync happens when the first rider retires, never in the
    chunk loop.  At conversion every rider's reference is compacted to
    its own trimmed row copy, so neither the device buffer nor the
    full-width host array outlives the sync (a long-lived rider would
    otherwise pin pool-width buffers for its whole life)."""

    __slots__ = ("dev",)

    def __init__(self, dev):
        self.dev = dev


class ContinuousBatcher:
    """A fixed pool of batch slots rolled forward ``chunk_steps`` at a time.

    Every chunk is ONE engine call of the static shape
    ``(n_slots, chunk_steps, input_dim)`` — free slots ride along as zero
    rows — with the pool's reservoir states passed as ``x0`` and the
    post-chunk states carried via ``return_final_state``.  Rows are
    independent through the recurrence (the batched matmuls and the
    elementwise epilogue never mix rows), so a sequence's chunked
    trajectory equals its one-shot rollout bit for bit.
    """

    def __init__(self, engine, *, n_slots: int = 8, chunk_steps: int = 16,
                 return_states: bool | None = None,
                 zero_copy: bool | None = None, warm: bool = True):
        assert n_slots >= 1 and chunk_steps >= 1
        self.engine = engine
        self.n_slots = n_slots
        self.chunk_steps = chunk_steps
        if return_states is None:
            return_states = not engine.has_readout
        self.return_states = return_states
        # zero-copy chunk serving: request inputs move to the device ONCE
        # at admission (into a resident (n_slots, max_chunks, cs, I)
        # buffer), a single jitted gather assembles each chunk's input
        # on-device, the carried state buffer is donated to each launch,
        # chunk outputs stay device-side, and the only device->host syncs
        # in the hot loop happen at slot retirement (``host_syncs`` counts
        # them).  The hot loop dispatches a constant handful of device
        # ops per chunk, independent of pool size.
        #
        # Default is backend-aware: on an accelerator the elided
        # transfers and deferred syncs are the win; on the CPU backend a
        # "transfer" is a memcpy while every extra dispatch costs real
        # Python/XLA overhead (measured ~2x per-chunk cost), so CPU
        # defaults to the host-assembled path.  Both paths produce
        # identical outputs and both are tested.
        if zero_copy is None:
            zero_copy = jax.default_backend() != "cpu"
        self.zero_copy = zero_copy
        self.host_syncs = 0
        self._in_dim = engine.config.input_dim
        self._dim = engine.config.reservoir_dim
        self._slots: list[QueuedRequest | None] = [None] * n_slots
        self._pos = [0] * n_slots               # steps consumed per slot
        self._chunks: list[list] = [[] for _ in range(n_slots)]
        self._states = jnp.zeros((n_slots, self._dim), jnp.float32)
        self._max_chunks = 4                    # input lanes; doubles on
        #                                         demand (longer requests)
        if zero_copy:
            self._u_dev = jnp.zeros(
                (n_slots, self._max_chunks, chunk_steps, self._in_dim),
                jnp.float32)
            self._gather = jax.jit(
                lambda u_dev, idx:
                u_dev[jnp.arange(u_dev.shape[0]), idx])
            # donated in-place lane write: admission cost stays O(request)
            # on accelerators instead of copying the whole pooled buffer
            self._lane_set = jax.jit(
                lambda buf, slot, lanes: jax.lax.dynamic_update_slice(
                    buf, lanes[None], (slot, 0, 0, 0)),
                donate_argnums=(0,))
        self.last_take: dict = {}               # slot -> steps, last chunk
        self.last_retired_slots: list = []
        if warm:
            self._warm()

    def _warm(self) -> None:
        """Pre-compile the pool's exact chunk program + per-slot ops.

        The batcher owns one static shape for its whole life, so every
        program it will ever run can compile at construction: the
        (donated) chunk rollout, the input gather, and admission's
        per-slot state seeding — none of it lands in the measured serving
        makespan.  Bypasses the engine's public API so warmup never
        pollutes ``ServeStats`` or the request telemetry.
        """
        if not self.return_states and not self.engine.has_readout:
            return      # run_chunk will raise the clear "readout not
            #             trained" error; nothing sane to warm
        x0 = jnp.zeros((self.n_slots, self._dim), jnp.float32)
        if self.zero_copy:
            u = self._gather(self._u_dev,
                             jnp.zeros(self.n_slots, jnp.int32))
            # admission's device ops: one warm call each compiles the
            # program every slot index reuses (the index is an operand)
            row = jnp.zeros((self._dim,), jnp.float32)
            self._states.at[0].set(row)
            self._u_dev = self._lane_set(
                self._u_dev, 0,
                jnp.zeros(self._u_dev.shape[1:], jnp.float32))
        else:
            u = jnp.zeros((self.n_slots, self.chunk_steps, self._in_dim),
                          jnp.float32)
        out, _xf = self.engine._dispatch(u, x0, not self.return_states,
                                         True, self.zero_copy)
        jax.block_until_ready(out)

    @property
    def live(self) -> int:
        return sum(s is not None for s in self._slots)

    def has_free_slot(self) -> bool:
        return any(s is None for s in self._slots)

    def _free_slot(self) -> int:
        """Pick the free slot to seat the next request in.  Subclass hook:
        the sharded batcher overrides this with least-loaded-shard
        admission."""
        return self._slots.index(None)

    def admit(self, qreq: QueuedRequest) -> int:
        """Seat a request in a free slot (zero state, or its ``x0``)."""
        slot = self._free_slot()
        self._slots[slot] = qreq
        self._pos[slot] = 0
        self._chunks[slot] = []
        if self.zero_copy:
            # ONE host->device transfer per request: the whole input,
            # pre-cut into chunk_steps segments, lands in the slot's lane
            # of the resident input buffer.  Lanes double when a request
            # is longer than any seen before (shape change -> the gather
            # re-specializes once, then stays cached).
            cs = self.chunk_steps
            seg = np.asarray(qreq.request.inputs, np.float32)
            n_chunks = max(1, -(-seg.shape[0] // cs))
            if n_chunks > self._max_chunks:
                while n_chunks > self._max_chunks:
                    self._max_chunks *= 2
                # one reallocation straight to the final lane count
                self._u_dev = jnp.zeros(
                    (self.n_slots, self._max_chunks, cs, self._in_dim),
                    jnp.float32).at[:, : self._u_dev.shape[1]].set(
                        self._u_dev)
            padded = np.zeros((self._max_chunks * cs,) + seg.shape[1:],
                              np.float32)
            padded[: seg.shape[0]] = seg
            self._u_dev = self._lane_set(
                self._u_dev, slot,
                jnp.asarray(padded.reshape(self._max_chunks, cs, -1)))
        x0 = qreq.request.x0
        row = (jnp.zeros((self._dim,), jnp.float32) if x0 is None
               else jnp.asarray(x0, jnp.float32))
        self._states = self._states.at[slot].set(row)
        return slot

    def run_chunk(self) -> tuple[list[tuple[QueuedRequest, np.ndarray]], int]:
        """Roll every slot ``chunk_steps`` forward.

        Returns ``(retired, real_steps)``: each retiree is
        ``(qreq, output)`` with the full (T_request, O/R) output assembled
        from its chunks, and ``real_steps`` counts the input steps the
        chunk actually consumed (seated slots' remaining lengths, capped
        at ``chunk_steps`` — the occupancy numerator).  Sequences that
        finish inside the chunk stop accumulating output at their real
        length (the recurrence is causal, so the zero-padded tail steps
        cannot reach them).
        """
        cs = self.chunk_steps
        take: dict[int, int] = {}
        if self.zero_copy:
            # ONE jitted gather assembles the (n_slots, cs, I) chunk from
            # the device-resident input buffer — no host->device copy and
            # no per-slot dispatch in the hot loop.  Free slots gather
            # lane 0 (stale or zero rows): their output is discarded and
            # their state is re-seeded at admission, so the rows are
            # inert ballast exactly like the zero rows of the host path.
            idx = np.zeros(self.n_slots, np.int32)
            for i, q in enumerate(self._slots):
                if q is None:
                    continue
                idx[i] = self._pos[i] // cs
                take[i] = min(cs, q.length - self._pos[i])
            u = self._gather(self._u_dev, jnp.asarray(idx))
        else:
            u_host = np.zeros((self.n_slots, cs, self._in_dim), np.float32)
            for i, q in enumerate(self._slots):
                if q is None:
                    continue
                seg = np.asarray(
                    q.request.inputs[self._pos[i]:self._pos[i] + cs],
                    np.float32)
                u_host[i, :len(seg)] = seg
                take[i] = len(seg)
            u = jnp.asarray(u_host)
        fn = (self.engine.rollout if self.return_states
              else self.engine.predictions)
        # zero-copy: the carried state buffer is donated to the launch
        # (this batcher owns it and immediately replaces it with xf), and
        # the per-chunk host sync is deferred to retirement
        out, xf = fn(u, x0=self._states, real_steps=sum(take.values()),
                     return_final_state=True, donate_state=self.zero_copy,
                     defer_sync=self.zero_copy)
        if not self.zero_copy:
            self.host_syncs += 1
            out = np.asarray(out)
        self._states = xf
        retired = []
        retired_slots = []
        chunk = _DeviceChunk(out) if self.zero_copy else None
        for i, n in take.items():
            if self.zero_copy:
                # the whole device-side chunk buffer is shared by its
                # riders (each remembering its real length); no per-slot
                # device op, no host transfer until a rider retires
                self._chunks[i].append((chunk, n))
            else:
                self._chunks[i].append(out[i, :n].copy())
            self._pos[i] += n
        # retire in a second pass: a retirement materializes the shared
        # chunk buffer (rewriting every rider's entry), so every rider
        # must have its entry before the first retiree triggers that
        for i in take:
            q = self._slots[i]
            if self._pos[i] >= q.length:
                retired.append((q, self._assemble(i)))
                retired_slots.append(i)
                self._slots[i] = None
                self._chunks[i] = []
        # per-slot view of the chunk just run, for per-shard telemetry
        self.last_take = dict(take)
        self.last_retired_slots = retired_slots
        return retired, sum(take.values())

    def _materialize(self, chunk: _DeviceChunk) -> None:
        """THE deferred device->host sync point, paid once per chunk
        buffer no matter how many riders retire from it, and only ever
        reached from retirement/snapshot paths.  Every rider's entry is
        rewritten to its own trimmed row copy, so the full-width buffer
        (device AND host) is immediately collectable — a long-lived rider
        never pins pool-width chunk buffers."""
        host = np.asarray(chunk.dev)
        chunk.dev = None
        self.host_syncs += 1
        for s, entries in enumerate(self._chunks):
            for j, (c, n) in enumerate(entries):
                if c is chunk:
                    entries[j] = (host[s, :n].copy(), n)

    def _slot_rows(self, slot: int) -> list:
        """A slot's chunk outputs as trimmed host rows (zero-copy path),
        materializing any still-device-side buffers."""
        entries = self._chunks[slot]
        for idx in range(len(entries)):
            c, _n = entries[idx]
            if isinstance(c, _DeviceChunk):
                self._materialize(c)            # rewrites entries[idx]
        return [row for row, _n in entries]

    def remaining_inputs(self, slot: int) -> np.ndarray:
        """A live slot's not-yet-consumed input steps, (T_left, I) float32.

        On the zero-copy path the device-resident lane is the source of
        truth — the caller's host buffer was free to be reused the moment
        ``admit()`` uploaded it, so the elastic-shrink snapshot must NOT
        re-read it."""
        q = self._slots[slot]
        lo = self._pos[slot]
        if not self.zero_copy:
            return np.asarray(q.request.inputs, np.float32)[lo:]
        cs = self.chunk_steps
        n_chunks = max(1, -(-q.length // cs))
        flat = np.asarray(self._u_dev[slot, :n_chunks]).reshape(
            n_chunks * cs, self._in_dim)
        return flat[lo: q.length]

    def chunk_outputs(self, slot: int) -> list:
        """Host copies of a live slot's chunks so far (syncs; used by the
        elastic-shrink snapshot, not the hot loop)."""
        if self.zero_copy:
            return self._slot_rows(slot)
        return list(self._chunks[slot])

    def _assemble(self, slot: int) -> np.ndarray:
        """Concatenate a retiring slot's chunks into its full output.

        On the zero-copy path the underlying buffers sync (at most once
        each) here — at retirement, never in the chunk loop."""
        if self.zero_copy:
            return np.concatenate(self._slot_rows(slot), axis=0)
        return np.concatenate(self._chunks[slot], axis=0)


class AsyncReservoirServer:
    """Time-stamped request queue in front of a :class:`ContinuousBatcher`.

    ``submit()`` enqueues requests with arrival timestamps;  ``run()``
    (or repeated ``step()`` calls) drains the queue: admit every arrived
    request that fits the pool, roll one chunk, retire finished sequences,
    repeat.  Admission is strictly FIFO in (arrival_time, submission
    order).

    The server keeps a virtual clock ``now``: it advances by each chunk's
    measured wall time (or the fixed ``chunk_time`` if given — useful for
    deterministic tests and trace-driven benchmarks) and jumps forward to
    the next arrival when the pool runs empty.  Queue waits,
    time-to-first-prediction and slot occupancy land in ``stats``.
    """

    def __init__(self, engine, *, n_slots: int = 8, chunk_steps: int = 16,
                 return_states: bool | None = None,
                 stats: ServeStats | None = None,
                 chunk_time: float | None = None,
                 batcher: ContinuousBatcher | None = None,
                 zero_copy: bool | None = None):
        self.batcher = batcher if batcher is not None else ContinuousBatcher(
            engine, n_slots=n_slots, chunk_steps=chunk_steps,
            return_states=return_states, zero_copy=zero_copy)
        self.stats = stats if stats is not None else engine.stats
        self.chunk_time = chunk_time
        self.now = 0.0
        self.results: dict[Any, np.ndarray] = {}
        self._queue: list[tuple[float, int, QueuedRequest]] = []
        self._seq = 0

    # -- queue ---------------------------------------------------------------
    def submit(self, request: RolloutRequest,
               arrival_time: float | None = None,
               deadline: float | None = None) -> QueuedRequest:
        """Enqueue one request; ``arrival_time`` defaults to ``now``.

        ``deadline`` is an absolute time on the server's clock: a request
        still waiting in the queue past it is dropped (``timed_out`` in
        stats) rather than seated.  A request already in a slot always
        runs to completion.
        """
        at = self.now if arrival_time is None else float(arrival_time)
        qreq = QueuedRequest(request, arrival_time=at, seq=self._seq,
                             deadline=None if deadline is None
                             else float(deadline))
        self._seq += 1
        heapq.heappush(self._queue, (at, qreq.seq, qreq))
        self.stats.record_enqueue()
        return qreq

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def drained(self) -> bool:
        return not self._queue and self.batcher.live == 0

    def _admit_arrived(self) -> None:
        while self._queue and self._queue[0][0] <= self.now:
            qreq = self._queue[0][2]
            if qreq.deadline is not None and self.now > qreq.deadline:
                # expired while queued: drop it instead of rolling steps
                # nobody is waiting for anymore
                heapq.heappop(self._queue)
                self.stats.record_timeout()
                continue
            if not self.batcher.has_free_slot():
                break
            heapq.heappop(self._queue)
            qreq.admit_time = self.now
            if qreq.requeued:
                qreq.requeued = False
            else:
                self.stats.record_admission(self.now - qreq.arrival_time)
            self.batcher.admit(qreq)

    # -- event loop ----------------------------------------------------------
    def step(self) -> bool:
        """Admit + one chunk + retire.  Returns False once drained."""
        if self.drained:
            return False
        if self.batcher.live == 0 and self._queue:
            # pool idle: fast-forward the clock to the next arrival
            self.now = max(self.now, self._queue[0][0])
        self._admit_arrived()
        if self.batcher.live == 0:
            # everything at the head expired (or only future arrivals are
            # left): no chunk to run this step
            return not self.drained
        t0 = time.perf_counter()
        retired, real_steps = self.batcher.run_chunk()
        self.now += (time.perf_counter() - t0 if self.chunk_time is None
                     else self.chunk_time)
        self.stats.record_chunk(
            live_steps=real_steps,
            total_steps=self.batcher.n_slots * self.batcher.chunk_steps)
        for qreq, out in retired:
            qreq.finish_time = self.now
            self.results[qreq.uid] = out
            self.stats.record_completion()
        # first-output marks: every seated-or-just-retired request that has
        # produced output by the end of this chunk
        for qreq in list(self.batcher._slots) + [q for q, _ in retired]:
            if (qreq is not None and qreq.first_output_time is None
                    and qreq.admit_time is not None):
                qreq.first_output_time = self.now
                self.stats.record_first_output(self.now - qreq.arrival_time)
        return True

    def run(self) -> dict:
        """Drain the queue; returns {uid: (T_request, O or R) output}."""
        while self.step():
            pass
        return self.results


__all__ = ["QueuedRequest", "ContinuousBatcher", "AsyncReservoirServer"]
