"""Request batching with padding buckets.

Variable-length rollout requests are grouped into jit-friendly shapes:
sequence lengths are padded up to a small set of bucket lengths and batches
are padded up to bucket sizes, so the engine compiles one program per
(bucket_len, bucket_batch) pair instead of one per request shape.  Padding
is always at the *end* of the time axis — the reservoir recurrence is
causal, so a request's first T real states are unaffected by padded steps.

The bucketer is deliberately generic over "a sequence of per-step inputs":
the reservoir engine batches (T, input_dim) float sequences, and the LM
serving example reuses the same bucketer for token prompts.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import numpy as np

DEFAULT_LEN_BUCKETS = (16, 32, 64, 128, 256, 512, 1024)
DEFAULT_BATCH_BUCKETS = (1, 2, 4, 8, 16, 32, 64)


@dataclasses.dataclass
class RolloutRequest:
    """One serving request: roll ``inputs`` (T, input_dim) through the ESN.

    ``x0`` optionally seeds the reservoir state for this request (shape
    (reservoir_dim,)); ``None`` means the zero state.  The continuous
    scheduler uses the same field to resume a sequence from its carried
    state mid-stream.
    """

    uid: Any
    inputs: np.ndarray
    x0: np.ndarray | None = None

    @property
    def length(self) -> int:
        return int(self.inputs.shape[0])


@dataclasses.dataclass
class MicroBatch:
    """A padded group of requests sharing one compiled shape."""

    requests: list
    inputs: np.ndarray            # (batch_padded, len_padded, input_dim)
    lengths: list
    pad_value: float = 0.0
    x0: np.ndarray | None = None  # (batch_padded, reservoir_dim) or None

    @property
    def real_steps(self) -> int:
        return int(sum(self.lengths))

    @property
    def padded_steps(self) -> int:
        return int(self.inputs.shape[0] * self.inputs.shape[1])


class PaddingBucketer:
    """Groups requests into padded microbatches over static bucket shapes."""

    def __init__(self,
                 len_buckets: Sequence[int] = DEFAULT_LEN_BUCKETS,
                 batch_buckets: Sequence[int] = DEFAULT_BATCH_BUCKETS):
        assert len_buckets and batch_buckets
        self.len_buckets = tuple(sorted(len_buckets))
        self.batch_buckets = tuple(sorted(batch_buckets))

    def pad_len(self, t: int) -> int:
        for b in self.len_buckets:
            if t <= b:
                return b
        top = self.len_buckets[-1]
        return ((t + top - 1) // top) * top

    def pad_batch(self, b: int) -> int:
        for bb in self.batch_buckets:
            if b <= bb:
                return bb
        # beyond the top bucket: round *up* to a multiple of it (mirrors
        # pad_len) — padding down would hand a direct caller a buffer
        # smaller than the batch.
        top = self.batch_buckets[-1]
        return ((b + top - 1) // top) * top

    @property
    def max_batch(self) -> int:
        return self.batch_buckets[-1]

    def group(self, requests: Sequence[RolloutRequest]) -> list:
        """Sort by length, group by length bucket, chunk by max batch, pad."""
        by_bucket: dict = {}
        for req in sorted(requests, key=lambda r: r.length):
            by_bucket.setdefault(self.pad_len(req.length), []).append(req)
        batches = []
        for tpad, group in sorted(by_bucket.items()):
            for lo in range(0, len(group), self.max_batch):
                chunk = group[lo:lo + self.max_batch]
                bpad = self.pad_batch(len(chunk))
                feat = chunk[0].inputs.shape[1:]
                buf = np.zeros((bpad, tpad) + feat,
                               dtype=np.asarray(chunk[0].inputs).dtype)
                for j, req in enumerate(chunk):
                    buf[j, :req.length] = req.inputs
                x0 = None
                if any(r.x0 is not None for r in chunk):
                    dim = next(np.asarray(r.x0).shape[-1] for r in chunk
                               if r.x0 is not None)
                    x0 = np.zeros((bpad, dim), np.float32)
                    for j, req in enumerate(chunk):
                        if req.x0 is not None:
                            x0[j] = req.x0
                batches.append(MicroBatch(
                    requests=list(chunk), inputs=buf,
                    lengths=[r.length for r in chunk], x0=x0))
        return batches
