"""Multi-tenant model registry with bit-exact live swap.

A :class:`ModelRegistry` owns named, versioned models: each
:class:`ModelVersion` wraps trained :class:`ReservoirParams` plus the
engine configuration (backend / mode / specialization kwargs) it should
serve under.  Engines are built lazily through the bounded
``engine_for`` LRU, keyed on the registry's ``(name, version)`` identity —
so re-registering bit-identical weights under a new version is a distinct
cache entry, and retraining in place never serves stale compilations.

``publish(name, ...)`` is the live-swap path.  The new version's engine is
planned, specialized and compiled *before* cutover — including a prewarm
of the chunk program against every attached
:class:`~repro.serve.scheduler.AsyncReservoirServer`'s pool shapes — then
the active-version pointer flips atomically.  In-flight slots keep the
engine version pinned at their admission and run to completion; only new
admissions see the new version.  The retired version is demoted to the
eviction front of the engine LRU so it falls out once traffic stops
pinning it.  The whole procedure is the serving analogue of the elastic
shrink: :func:`~repro.runtime.elastic.swap_serve_plan` records the action
contract, ``publish`` executes it.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

from repro import obs
from repro.runtime import faults
from repro.serve.api import SubmitSpec
from repro.serve.engine import engine_cache_demote, engine_for
from repro.runtime.elastic import swap_serve_plan

__all__ = ["ModelRegistry", "ModelVersion", "TenantPolicy"]


@dataclasses.dataclass
class TenantPolicy:
    """Per-tenant serving policy.

    ``quota`` caps the tenant's concurrently-seated slots per pool (None =
    unbounded); ``deadline_s`` is a relative queue deadline applied to
    specs that don't carry their own (None = no deadline).
    """

    quota: int | None = None
    deadline_s: float | None = None


@dataclasses.dataclass(frozen=True)
class ModelVersion:
    """One immutable registered (name, version) -> params binding."""

    name: str
    version: int
    params: Any
    # sorted (key, value) tuple so the record stays hashable/frozen
    engine_kwargs: tuple = ()

    @property
    def key(self) -> tuple[str, int]:
        """The ``engine_for``/``plan_for`` tenant identity."""
        return (self.name, self.version)


class ModelRegistry:
    """Named, versioned models behind one serve surface.

    ``backend``/``engine_kwargs`` set registry-wide engine defaults;
    per-model kwargs at ``register``/``publish`` override them.  ``build``
    (signature ``build(params, backend, **kwargs) -> engine``) replaces
    engine construction wholesale — the sharded server uses it to build
    mesh-mapped engines.
    """

    def __init__(self, backend: str = "auto",
                 build: Callable | None = None, **engine_kwargs):
        self.backend = backend
        self._build = build
        self._engine_kwargs = dict(engine_kwargs)
        self._versions: dict[str, dict[int, ModelVersion]] = {}
        self._active: dict[str, int] = {}
        self._policies: dict[str, TenantPolicy] = {}
        self._servers: list = []

    # -- bookkeeping ---------------------------------------------------------
    def attach(self, server) -> None:
        """Wire a server to this registry: its submits route model specs
        here and its pool gets prewarmed on every publish."""
        if server not in self._servers:
            self._servers.append(server)
        server.registry = self

    def detach(self, server) -> None:
        if server in self._servers:
            self._servers.remove(server)
        if getattr(server, "registry", None) is self:
            server.registry = None

    @property
    def models(self) -> list[str]:
        return sorted(self._versions)

    def versions(self, name: str) -> list[int]:
        return sorted(self._versions[name])

    def active_version(self, name: str) -> int:
        if name not in self._active:
            raise KeyError(f"no model named {name!r} registered")
        return self._active[name]

    def get(self, name: str, version: int | None = None) -> ModelVersion:
        v = self.active_version(name) if version is None else version
        try:
            return self._versions[name][v]
        except KeyError:
            raise KeyError(f"model {name!r} has no version {v}") from None

    def quota(self, name: str) -> int | None:
        pol = self._policies.get(name)
        return None if pol is None else pol.quota

    def deadline_s(self, name: str) -> float | None:
        pol = self._policies.get(name)
        return None if pol is None else pol.deadline_s

    def set_policy(self, name: str, *, quota: int | None = None,
                   deadline_s: float | None = None) -> TenantPolicy:
        pol = TenantPolicy(quota=quota, deadline_s=deadline_s)
        self._policies[name] = pol
        return pol

    # -- registration --------------------------------------------------------
    def register(self, name: str, params, *, version: int | None = None,
                 quota: int | None = None, deadline_s: float | None = None,
                 activate: bool = True, **engine_kwargs) -> ModelVersion:
        """Record ``params`` as a version of ``name``.

        ``version`` defaults to (highest registered) + 1, starting at 1.
        ``activate=True`` makes it the version new admissions route to —
        without the prewarm-before-cutover dance of ``publish`` (use
        ``publish`` for models already taking traffic).
        """
        vs = self._versions.setdefault(name, {})
        if version is None:
            version = max(vs, default=0) + 1
        if version in vs:
            raise ValueError(
                f"model {name!r} already has a version {version} — "
                "versions are immutable; publish a new one")
        kw = {**self._engine_kwargs, **engine_kwargs}
        mv = ModelVersion(name=name, version=version, params=params,
                          engine_kwargs=tuple(sorted(kw.items())))
        vs[version] = mv
        if quota is not None or deadline_s is not None:
            self.set_policy(name, quota=quota, deadline_s=deadline_s)
        if activate or name not in self._active:
            self._active[name] = version
        return mv

    # -- engines -------------------------------------------------------------
    def engine(self, name: str, version: int | None = None):
        """The (lazily built, LRU-cached) engine serving
        ``(name, version)``; default the active version."""
        mv = self.get(name, version)
        return engine_for(mv.params, self.backend, tenant=mv.key,
                          build=self._build, **dict(mv.engine_kwargs))

    # -- live swap -----------------------------------------------------------
    def publish(self, name: str, params=None, *, version: int | None = None,
                prewarm: bool = True, **engine_kwargs) -> dict:
        """Swap ``name`` to a new version with zero downtime.

        With ``params``, registers them as a fresh version first; with
        ``version`` alone, re-activates an already-registered one
        (rollback).  Either way the target engine is fully built —
        plan -> specialize -> compile, plus a chunk-program prewarm on
        every attached server — *before* the atomic active-version flip,
        so no request ever waits on a swap compile.  In-flight slots
        finish on their admission-pinned engine; the retired version is
        demoted in the engine LRU.  Returns the executed
        :func:`~repro.runtime.elastic.swap_serve_plan` with timing
        attached.
        """
        old = self._active.get(name)
        if params is not None:
            mv = self.register(name, params, version=version,
                               activate=False, **engine_kwargs)
        elif version is not None:
            mv = self.get(name, version)
        else:
            raise ValueError("publish() needs params (new version) or "
                             "version= (rollback)")
        t0 = time.perf_counter()
        if prewarm:
            if self._servers:
                # each server prewarms its own engine form (the sharded
                # server builds mesh-mapped siblings, not engine_for ones)
                for srv in self._servers:
                    srv.prewarm_model(name, mv.version)
            else:
                self.engine(name, mv.version)
        prewarm_s = time.perf_counter() - t0
        # fault-injection seam: an installed FaultPlan may abort the swap
        # at the worst moment — after the prewarm spend, before the
        # cutover.  The active version is untouched (the one dict write
        # below never happened) and the prewarmed version stays
        # registered inactive, so a retry publishes it without
        # recompiling.  In-flight and future traffic keep serving the old
        # version with zero drops.
        fault_plan = faults.active()
        if fault_plan is not None and fault_plan.take_publish_abort():
            obs.event("publish_abort", model=name, old_version=old,
                      staged_version=mv.version, prewarm_s=prewarm_s)
            obs.inc("publish_aborts_total", model=name)
            raise faults.PublishAborted(
                f"injected abort publishing {name!r} v{mv.version}: "
                f"active version stays {old!r}")
        # atomic cutover: one dict write — admissions resolve the active
        # version at a single point (_resolve_engine), so a request sees
        # wholly-old or wholly-new, never a mix
        self._active[name] = mv.version
        if old is not None and old != mv.version:
            engine_cache_demote((name, old))
        obs.event("publish", model=name, old_version=old,
                  new_version=mv.version, prewarm_s=prewarm_s)
        obs.inc("publishes_total", model=name)
        obs.span("registry.publish", t0, t0 + prewarm_s, clock="wall",
                 model=name, version=mv.version)
        plan = swap_serve_plan(name, old, mv.version)
        plan["prewarm_s"] = prewarm_s
        return plan

    # -- convenience ---------------------------------------------------------
    def submit(self, spec: SubmitSpec):
        """One-shot synchronous rollout of ``spec`` on its model's active
        engine (no pool, no queue) — handy for smoke tests."""
        if spec.model is None:
            raise ValueError("registry.submit() needs spec.model")
        eng = self.engine(spec.model)
        return eng.submit(dataclasses.replace(spec, model=None))
