"""Serving telemetry: throughput, latency and padding-efficiency counters.

The unit of account is the *reservoir step* (one Eq.-1 update for one
sequence) — the figure the paper's latency numbers are quoted in.  Padded
steps (bucket padding in time, batch padding to the bucket size) are
tracked separately so the engine can report how much of its raw throughput
is doing useful work.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class ServeStats:
    calls: int = 0                 # engine invocations (microbatches)
    sequences: int = 0             # sequences rolled (incl. padding rows)
    steps_real: int = 0            # steps requested by callers
    steps_padded: int = 0          # steps actually executed
    seconds: float = 0.0           # wall time spent in rollouts
    latency_ewma_s: float = 0.0    # smoothed per-call latency
    _EWMA_ALPHA = 0.2

    def record_call(self, *, batch: int, steps: int, seconds: float,
                    real_steps: int | None = None) -> None:
        """Account one rollout call of ``batch`` sequences x ``steps``."""
        padded = batch * steps
        self.calls += 1
        self.sequences += batch
        self.steps_padded += padded
        self.steps_real += padded if real_steps is None else real_steps
        self.seconds += seconds
        if self.calls == 1:
            self.latency_ewma_s = seconds
        else:
            a = self._EWMA_ALPHA
            self.latency_ewma_s = a * seconds + (1 - a) * self.latency_ewma_s

    @property
    def steps_per_sec(self) -> float:
        """Raw executed-step throughput (includes padding work)."""
        return self.steps_padded / self.seconds if self.seconds > 0 else 0.0

    @property
    def goodput_steps_per_sec(self) -> float:
        """Useful-step throughput (padding excluded)."""
        return self.steps_real / self.seconds if self.seconds > 0 else 0.0

    @property
    def padding_efficiency(self) -> float:
        """Fraction of executed steps that served real requests."""
        if self.steps_padded == 0:
            return 1.0
        return self.steps_real / self.steps_padded

    def summary(self) -> dict:
        return {
            "calls": self.calls,
            "sequences": self.sequences,
            "steps_real": self.steps_real,
            "steps_padded": self.steps_padded,
            "seconds": self.seconds,
            "steps_per_sec": self.steps_per_sec,
            "goodput_steps_per_sec": self.goodput_steps_per_sec,
            "padding_efficiency": self.padding_efficiency,
            "latency_ewma_ms": self.latency_ewma_s * 1e3,
        }

    def render(self) -> str:
        s = self.summary()
        return (f"{s['calls']} calls, {s['sequences']} seqs, "
                f"{s['steps_real']} steps "
                f"({s['padding_efficiency']:.0%} of executed work useful), "
                f"{s['steps_per_sec']:.0f} steps/s raw, "
                f"{s['goodput_steps_per_sec']:.0f} steps/s goodput, "
                f"p-call latency {s['latency_ewma_ms']:.2f} ms (ewma)")
