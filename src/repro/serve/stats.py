"""Serving telemetry: throughput, latency and padding-efficiency counters.

The unit of account is the *reservoir step* (one Eq.-1 update for one
sequence) — the figure the paper's latency numbers are quoted in.  Padded
steps (bucket padding in time, batch padding to the bucket size) are
tracked separately so the engine can report how much of its raw throughput
is doing useful work.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass
class ServeStats:
    calls: int = 0                 # engine invocations (microbatches)
    deferred_calls: int = 0        # calls timed dispatch-side only (the
    #                                zero-copy serve loop defers the host
    #                                sync to retirement, so ``seconds``
    #                                under-counts for these — read
    #                                throughput from the scheduler clock)
    sequences: int = 0             # sequences rolled (incl. padding rows)
    steps_real: int = 0            # steps requested by callers
    steps_padded: int = 0          # steps actually executed
    seconds: float = 0.0           # wall time spent in rollouts
    latency_ewma_s: float = 0.0    # smoothed per-call latency
    # continuous-batching telemetry (AsyncReservoirServer), all on the
    # server's clock: queue waits, time-to-first-prediction, and how full
    # the slot pool ran.
    enqueued: int = 0              # requests submitted to the queue
    admitted: int = 0              # requests seated in a slot
    completed: int = 0             # requests fully served
    timed_out: int = 0             # queued requests dropped past deadline
    rejected: int = 0              # submissions refused outright by the
    #                                admission policy (bounded queue /
    #                                fairness) — never entered the queue
    shed: int = 0                  # submissions refused because the
    #                                estimated queue delay already blows
    #                                the request's deadline
    retries: int = 0               # transient engine-call failures
    #                                replayed from the slot's carried
    #                                state (fault-injection recovery)
    quota_held: int = 0            # admission deferrals: a free slot
    #                                existed but the request's tenant was
    #                                at its concurrency quota (counted per
    #                                sweep the request sat out)
    chunks: int = 0                # scheduler chunks executed
    queue_wait_s: float = 0.0      # summed arrival -> admission wait
    queue_wait_max_s: float = 0.0
    first_outputs: int = 0         # requests whose first prediction landed
    ttfp_s: float = 0.0            # summed arrival -> first prediction
    ttfp_max_s: float = 0.0
    slot_steps_live: int = 0       # chunk steps that consumed real input
    slot_steps_total: int = 0      # chunk steps across the whole pool
    # arrival -> completion latencies (seconds), one per completed request
    # that reported one — the tail-latency record the p99 gates read
    latencies: list = dataclasses.field(default_factory=list, repr=False)
    # per-shard / per-tenant breakdown attached by merge(); None on a
    # plain instance
    shards: dict | None = dataclasses.field(default=None, repr=False)
    _EWMA_ALPHA = 0.2

    # additive counters merge() sums across shards; the *_max_s fields are
    # maxed and latency_ewma_s is calls-weighted instead.
    _SUM_FIELDS = ("calls", "deferred_calls", "sequences", "steps_real",
                   "steps_padded",
                   "seconds", "enqueued", "admitted", "completed",
                   "timed_out", "rejected", "shed", "retries",
                   "quota_held", "chunks", "queue_wait_s",
                   "first_outputs",
                   "ttfp_s", "slot_steps_live", "slot_steps_total")

    @staticmethod
    def merge(parts: "Sequence[ServeStats]",
              labels: Sequence[str] | None = None) -> "ServeStats":
        """Aggregate per-shard stats into one view.

        Additive counters are summed (``seconds`` becomes aggregate
        device-seconds — shards run concurrently, so throughput across a
        wall-clock window should be computed from the window, not from the
        merged ``seconds``), the ``*_max_s`` fields take the worst shard,
        and the latency EWMA is the calls-weighted mean.  The parts land on
        ``merged.shards`` keyed by ``labels`` (default ``shard0..N``) and
        show up as a per-shard breakdown in ``summary()``/``render()``.
        """
        parts = list(parts)
        if labels is None:
            labels = [f"shard{i}" for i in range(len(parts))]
        merged = ServeStats()
        for f in ServeStats._SUM_FIELDS:
            setattr(merged, f, sum(getattr(p, f) for p in parts))
        merged.queue_wait_max_s = max(
            (p.queue_wait_max_s for p in parts), default=0.0)
        merged.ttfp_max_s = max((p.ttfp_max_s for p in parts), default=0.0)
        if merged.calls:
            merged.latency_ewma_s = sum(
                p.latency_ewma_s * p.calls for p in parts) / merged.calls
        for p in parts:
            merged.latencies.extend(p.latencies)
        merged.shards = dict(zip(labels, parts))
        return merged

    def record_call(self, *, batch: int, steps: int, seconds: float,
                    real_steps: int | None = None,
                    deferred: bool = False) -> None:
        """Account one rollout call of ``batch`` sequences x ``steps``.

        ``deferred=True`` marks a call whose ``seconds`` covers dispatch
        only (no host sync) — tracked so readers know when the timing
        columns are dispatch-side."""
        padded = batch * steps
        self.calls += 1
        self.deferred_calls += deferred
        self.sequences += batch
        self.steps_padded += padded
        self.steps_real += padded if real_steps is None else real_steps
        self.seconds += seconds
        if self.calls == 1:
            self.latency_ewma_s = seconds
        else:
            a = self._EWMA_ALPHA
            self.latency_ewma_s = a * seconds + (1 - a) * self.latency_ewma_s

    # -- continuous-batching accounting --------------------------------------
    def record_enqueue(self) -> None:
        self.enqueued += 1

    def record_admission(self, wait_s: float) -> None:
        """One request seated; ``wait_s`` is its arrival -> admit wait."""
        self.admitted += 1
        self.queue_wait_s += wait_s
        self.queue_wait_max_s = max(self.queue_wait_max_s, wait_s)

    def record_first_output(self, ttfp_s: float) -> None:
        """First chunk of output ready, ``ttfp_s`` after the arrival."""
        self.first_outputs += 1
        self.ttfp_s += ttfp_s
        self.ttfp_max_s = max(self.ttfp_max_s, ttfp_s)

    def record_completion(self, latency_s: float | None = None) -> None:
        """One request fully served; ``latency_s`` (arrival -> finish on
        the server's clock) feeds the tail-latency percentiles."""
        self.completed += 1
        if latency_s is not None:
            self.latencies.append(float(latency_s))

    def record_timeout(self) -> None:
        """One queued request dropped because its deadline passed before a
        slot freed up (it never occupied one)."""
        self.timed_out += 1

    def record_rejection(self, *, shed: bool = False) -> None:
        """One submission refused at the door by the admission policy.

        ``shed=True`` marks a deadline shed (the delay estimate said the
        deadline cannot be met); otherwise it is a hard rejection
        (bounded queue depth / tenant fairness).  Rejected requests never
        enter the queue, so they appear in neither ``enqueued`` nor
        ``timed_out``."""
        if shed:
            self.shed += 1
        else:
            self.rejected += 1

    def record_retry(self) -> None:
        """One transient engine-call failure replayed (bit-identically)
        from the slot's last carried state."""
        self.retries += 1

    def record_quota_hold(self) -> None:
        """One admission sweep skipped a request whose tenant was at its
        concurrency quota (the request stays queued, other tenants seat
        past it — quota never head-of-line blocks the FIFO)."""
        self.quota_held += 1

    def record_chunk(self, *, live_steps: int, total_steps: int) -> None:
        """One scheduler chunk: ``live_steps`` of the pool's
        ``total_steps`` executed steps consumed real request input (a
        retiring sequence's zero-padded tail does not count)."""
        self.chunks += 1
        self.slot_steps_live += live_steps
        self.slot_steps_total += total_steps

    @property
    def steps_per_sec(self) -> float:
        """Raw executed-step throughput (includes padding work)."""
        return self.steps_padded / self.seconds if self.seconds > 0 else 0.0

    @property
    def goodput_steps_per_sec(self) -> float:
        """Useful-step throughput (padding excluded)."""
        return self.steps_real / self.seconds if self.seconds > 0 else 0.0

    @property
    def padding_efficiency(self) -> float:
        """Fraction of executed steps that served real requests."""
        if self.steps_padded == 0:
            return 1.0
        return self.steps_real / self.steps_padded

    @property
    def mean_queue_wait_s(self) -> float:
        """Mean arrival -> admission wait across admitted requests."""
        return self.queue_wait_s / self.admitted if self.admitted else 0.0

    @property
    def mean_ttfp_s(self) -> float:
        """Mean arrival -> first-prediction latency, over the requests
        that actually produced output — admitted-but-still-silent requests
        (and the zero-completions case, e.g. every request timed out in
        the queue) don't skew or crash the mean."""
        return self.ttfp_s / self.first_outputs if self.first_outputs else 0.0

    def latency_percentile(self, p: float) -> float:
        """The ``p``-th percentile (0..100, nearest-rank) of recorded
        arrival -> completion latencies; 0.0 when none were recorded."""
        if not self.latencies:
            return 0.0
        ordered = sorted(self.latencies)
        rank = max(0, min(len(ordered) - 1,
                          int(round(p / 100.0 * len(ordered))) - 1))
        return ordered[rank]

    @property
    def p99_latency_s(self) -> float:
        return self.latency_percentile(99.0)

    @property
    def slot_occupancy(self) -> float:
        """Fraction of pool chunk-steps that consumed real request input."""
        if self.slot_steps_total == 0:
            return 1.0
        return self.slot_steps_live / self.slot_steps_total

    def summary(self) -> dict:
        out = {
            "calls": self.calls,
            "sequences": self.sequences,
            "steps_real": self.steps_real,
            "steps_padded": self.steps_padded,
            "seconds": self.seconds,
            "steps_per_sec": self.steps_per_sec,
            "goodput_steps_per_sec": self.goodput_steps_per_sec,
            "padding_efficiency": self.padding_efficiency,
            "latency_ewma_ms": self.latency_ewma_s * 1e3,
        }
        if self.deferred_calls:
            # timing columns are dispatch-side for these calls; makespan
            # clocks (AsyncReservoirServer.now) carry the honest number
            out["deferred_calls"] = self.deferred_calls
        if self.enqueued:
            out.update({
                "enqueued": self.enqueued,
                "admitted": self.admitted,
                "completed": self.completed,
                "timed_out": self.timed_out,
                "chunks": self.chunks,
                "mean_queue_wait_ms": self.mean_queue_wait_s * 1e3,
                "max_queue_wait_ms": self.queue_wait_max_s * 1e3,
                "mean_ttfp_ms": self.mean_ttfp_s * 1e3,
                "max_ttfp_ms": self.ttfp_max_s * 1e3,
                "slot_occupancy": self.slot_occupancy,
            })
            if self.quota_held:
                out["quota_held"] = self.quota_held
            if self.rejected or self.shed:
                out["rejected"] = self.rejected
                out["shed"] = self.shed
            if self.retries:
                out["retries"] = self.retries
            if self.latencies:
                out["p50_latency_ms"] = self.latency_percentile(50.0) * 1e3
                out["p99_latency_ms"] = self.p99_latency_s * 1e3
        if self.shards is not None:
            out["shards"] = {label: part.summary()
                             for label, part in self.shards.items()}
        return out

    def render(self) -> str:
        s = self.summary()
        line = (f"{s['calls']} calls, {s['sequences']} seqs, "
                f"{s['steps_real']} steps "
                f"({s['padding_efficiency']:.0%} of executed work useful), "
                f"{s['steps_per_sec']:.0f} steps/s raw, "
                f"{s['goodput_steps_per_sec']:.0f} steps/s goodput, "
                f"p-call latency {s['latency_ewma_ms']:.2f} ms (ewma)")
        if self.enqueued:
            line += (f"; queue: {s['completed']}/{s['enqueued']} done in "
                     f"{s['chunks']} chunks, "
                     f"wait {s['mean_queue_wait_ms']:.2f} ms mean / "
                     f"{s['max_queue_wait_ms']:.2f} ms max, "
                     f"ttfp {s['mean_ttfp_ms']:.2f} ms mean, "
                     f"occupancy {s['slot_occupancy']:.0%}")
            # drops and holds are SLO facts: always rendered (zero
            # included), so a dashboard line never hides them
            line += f", {self.timed_out} timed out"
            line += f", {self.rejected} rejected"
            line += f", {self.shed} shed"
            line += f", {self.quota_held} quota held"
        if self.shards is not None:
            for label, p in self.shards.items():
                line += (f"\n  {label}: {p.admitted} admitted, "
                         f"{p.completed} done, "
                         f"{p.slot_steps_live} live steps, "
                         f"occupancy {p.slot_occupancy:.0%}")
        return line
