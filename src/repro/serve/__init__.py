"""Serving layer: fused batched reservoir rollouts behind request batching.

- ``engine``    — ReservoirEngine: fused rollout (xla scan / pallas kernel)
- ``batching``  — padding-bucket request batching
- ``scheduler`` — continuous batching: slot pool + time-stamped queue,
  chunked rollouts with per-slot reservoir-state carry
- ``stats``     — throughput / latency / padding / queue telemetry
"""

from repro.serve.batching import (MicroBatch, PaddingBucketer,  # noqa: F401
                                  RolloutRequest)
from repro.serve.engine import (ReservoirEngine, engine_cache_clear,  # noqa: F401,E501
                                engine_cache_stats, engine_for)
from repro.serve.scheduler import (AsyncReservoirServer,  # noqa: F401
                                   ContinuousBatcher, QueuedRequest)
from repro.serve.stats import ServeStats  # noqa: F401

__all__ = ["ReservoirEngine", "engine_for", "engine_cache_clear",
           "engine_cache_stats", "ServeStats", "PaddingBucketer",
           "RolloutRequest", "MicroBatch", "AsyncReservoirServer",
           "ContinuousBatcher", "QueuedRequest"]
