"""Serving layer: fused batched reservoir rollouts behind request batching.

- ``api``       — SubmitSpec / RolloutResult, the one request/response
  contract shared by every entry point
- ``engine``    — ReservoirEngine: fused rollout (xla scan / pallas kernel)
- ``batching``  — padding-bucket request batching
- ``scheduler`` — continuous batching: slot pool + time-stamped queue,
  chunked rollouts with per-slot reservoir-state carry (multi-tenant:
  slots pin engines, chunks group by model)
- ``registry``  — named/versioned models with bit-exact live swap
- ``admission`` — backpressure: pluggable admission policies (bounded
  queue, deadline shedding, weighted tenant fairness) with explicit
  ``status="rejected"`` results instead of silent unbounded queueing
- ``stats``     — throughput / latency / padding / queue telemetry
"""

from repro.serve.admission import (AdmissionPolicy,  # noqa: F401
                                   BoundedQueuePolicy, CompositePolicy,
                                   DeadlineShedPolicy, Rejection,
                                   TenantFairnessPolicy, default_policy)
from repro.serve.api import RolloutResult, SubmitSpec  # noqa: F401
from repro.serve.batching import (MicroBatch, PaddingBucketer,  # noqa: F401
                                  RolloutRequest)
from repro.serve.engine import (ReservoirEngine, engine_cache_clear,  # noqa: F401,E501
                                engine_cache_demote, engine_cache_stats,
                                engine_for)
from repro.serve.registry import (ModelRegistry, ModelVersion,  # noqa: F401
                                  TenantPolicy)
from repro.serve.scheduler import (AsyncReservoirServer,  # noqa: F401
                                   ContinuousBatcher, QueuedRequest)
from repro.serve.stats import ServeStats  # noqa: F401

__all__ = ["SubmitSpec", "RolloutResult", "ReservoirEngine", "engine_for",
           "engine_cache_clear", "engine_cache_demote", "engine_cache_stats",
           "ServeStats", "PaddingBucketer", "RolloutRequest", "MicroBatch",
           "AsyncReservoirServer", "ContinuousBatcher", "QueuedRequest",
           "ModelRegistry", "ModelVersion", "TenantPolicy",
           "AdmissionPolicy", "BoundedQueuePolicy", "DeadlineShedPolicy",
           "TenantFairnessPolicy", "CompositePolicy", "Rejection",
           "default_policy"]
