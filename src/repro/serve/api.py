"""The one serve request/response contract.

Three entry points grew three slightly different surfaces: the engine's
``rollout``/``predictions`` boolean twins (``return_final_state=True``
changes the return arity), ``serve(..., return_states=True)``, and the
scheduler's ``submit(request, arrival_time, deadline)``.  This module
collapses them: every caller builds a :class:`SubmitSpec`, every path
answers with a :class:`RolloutResult`, and the booleans become one
``want_states`` field.  :class:`~repro.serve.engine.ReservoirEngine`,
:class:`~repro.serve.scheduler.AsyncReservoirServer` and
:class:`~repro.dist.scheduler.DistributedReservoirServer` accept the spec
identically; the old kwargs survive one release as warning shims.

The module is dependency-free on purpose (no jax, no engine imports) so
every serve module can import it without cycles.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any


class _Unset:
    """Sentinel distinguishing "kwarg not passed" from an explicit value
    on the deprecated-kwarg shims (``None``/``False`` are legal values)."""

    _instance = None

    def __new__(cls):
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return "<unset>"

    def __bool__(self) -> bool:
        return False


_UNSET = _Unset()


def warn_deprecated(message: str, *, stacklevel: int = 3) -> None:
    """One-liner for the shim paths; always points past the shim frame."""
    warnings.warn(message, DeprecationWarning, stacklevel=stacklevel)


@dataclasses.dataclass(frozen=True)
class SubmitSpec:
    """One serving request, identical across every entry point.

    ``inputs`` is the (T, input_dim) step sequence (engines also accept a
    pre-batched (B, T, input_dim) array on the one-shot path).  Everything
    else is keyword-only:

    * ``model``        — registry model name to route to (multi-tenant
      servers resolve it through their :class:`ModelRegistry`; the bare
      single-model engine rejects it).
    * ``x0``           — optional (reservoir_dim,) initial state.
    * ``deadline``     — absolute time on the server's clock; a spec still
      queued past it is dropped (``timed_out``).  ``None`` falls back to
      the model's registry deadline policy, if any.
    * ``want_states``  — ``True``: answer with the (T, R) state
      trajectory; ``False``: answer with (T, O) predictions; ``None``
      (default): predictions when the serving engine has a trained
      readout, states otherwise.
    * ``uid``          — result key; servers assign ``req<N>`` when None.
    """

    inputs: Any
    _: dataclasses.KW_ONLY
    model: str | None = None
    x0: Any | None = None
    deadline: float | None = None
    want_states: bool | None = None
    uid: Any | None = None

    @property
    def length(self) -> int:
        return int(self.inputs.shape[0])


@dataclasses.dataclass(frozen=True)
class RolloutResult:
    """What every serve path answers with.

    Exactly one of ``preds``/``states`` is set (by ``want_states``);
    ``output`` is the one that is.  ``final_state`` is x(T) on the
    one-shot engine paths (the carry a chunked caller resumes from
    bit-identically); scheduler paths answer ``None`` — a pooled chunk
    rolls past a retiring sequence's real length, so the pool row is not
    x(T).  ``timings`` is a plain mutable dict: engines record
    ``seconds``; servers record the request lifecycle (``arrival_time``,
    ``admit_time``, ``finish_time``, ``queue_wait_s``, ``ttfp_s``,
    ``latency_s``) plus ``model``/``version`` when routed by a registry.
    """

    preds: Any | None = None
    states: Any | None = None
    final_state: Any | None = None
    timings: dict = dataclasses.field(default_factory=dict)

    @property
    def output(self) -> Any:
        """The requested payload: predictions, or states under
        ``want_states=True``."""
        return self.states if self.preds is None else self.preds


__all__ = ["SubmitSpec", "RolloutResult", "warn_deprecated", "_UNSET"]
