"""The one serve request/response contract.

Three entry points grew three slightly different surfaces: the engine's
``rollout``/``predictions`` boolean twins (``return_final_state=True``
changes the return arity), ``serve(..., return_states=True)``, and the
scheduler's ``submit(request, arrival_time, deadline)``.  This module
collapses them: every caller builds a :class:`SubmitSpec`, every path
answers with a :class:`RolloutResult`, and the booleans become one
``want_states`` field.  :class:`~repro.serve.engine.ReservoirEngine`,
:class:`~repro.serve.scheduler.AsyncReservoirServer` and
:class:`~repro.dist.scheduler.DistributedReservoirServer` accept the spec
identically; the old kwargs survive one release as warning shims.

The module is dependency-free on purpose (no jax, no engine imports) so
every serve module can import it without cycles.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any


class _Unset:
    """Sentinel distinguishing "kwarg not passed" from an explicit value
    on the deprecated-kwarg shims (``None``/``False`` are legal values)."""

    _instance = None

    def __new__(cls):
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return "<unset>"

    def __bool__(self) -> bool:
        return False


_UNSET = _Unset()


def warn_deprecated(message: str, *, stacklevel: int = 3) -> None:
    """One-liner for the shim paths; always points past the shim frame."""
    warnings.warn(message, DeprecationWarning, stacklevel=stacklevel)


@dataclasses.dataclass(frozen=True)
class SubmitSpec:
    """One serving request, identical across every entry point.

    ``inputs`` is the (T, input_dim) step sequence (engines also accept a
    pre-batched (B, T, input_dim) array on the one-shot path).  Everything
    else is keyword-only:

    * ``model``        — registry model name to route to (multi-tenant
      servers resolve it through their :class:`ModelRegistry`; the bare
      single-model engine rejects it).
    * ``x0``           — optional (reservoir_dim,) initial state.
    * ``deadline``     — absolute time on the server's clock; a spec still
      queued past it is dropped (``timed_out``).  ``None`` falls back to
      the model's registry deadline policy, if any.
    * ``want_states``  — ``True``: answer with the (T, R) state
      trajectory; ``False``: answer with (T, O) predictions; ``None``
      (default): predictions when the serving engine has a trained
      readout, states otherwise.
    * ``uid``          — result key; servers assign ``req<N>`` when None.
    * ``trace_id``     — observability correlation id threaded through
      every span this request touches and echoed in
      ``RolloutResult.timings``; when ``None`` and tracing is enabled
      (``repro.obs.configure()``), servers assign one at submit.
    """

    inputs: Any
    _: dataclasses.KW_ONLY
    model: str | None = None
    x0: Any | None = None
    deadline: float | None = None
    want_states: bool | None = None
    uid: Any | None = None
    trace_id: str | None = None

    @property
    def length(self) -> int:
        return int(self.inputs.shape[0])


@dataclasses.dataclass(frozen=True)
class RolloutResult:
    """What every serve path answers with.

    Exactly one of ``preds``/``states`` is set (by ``want_states``);
    ``output`` is the one that is.  ``final_state`` is x(T) on the
    one-shot engine paths (the carry a chunked caller resumes from
    bit-identically); scheduler paths answer ``None`` — a pooled chunk
    rolls past a retiring sequence's real length, so the pool row is not
    x(T).

    ``timings`` is a plain mutable dict following ONE schema on every
    path (one-shot engine calls and queued scheduler serving alike —
    built by :func:`lifecycle_timings`).  All times are seconds on the
    path's serving clock: ``time.perf_counter`` for direct engine calls,
    the server's virtual clock for scheduled requests.

    Always present:

    * ``arrival_time``      — when the request entered the system (a
      direct engine call "arrives" when it is made);
    * ``admit_time``        — when work started (equals ``arrival_time``
      on direct calls: there is no queue to wait in);
    * ``finish_time``       — when the result was complete;
    * ``first_output_time`` — when the first chunk of output was ready
      (equals ``finish_time`` on one-shot calls);
    * ``queue_wait_s``      — ``admit_time - arrival_time``;
    * ``ttfp_s``            — ``first_output_time - arrival_time``
      (time to first prediction);
    * ``latency_s``         — ``finish_time - arrival_time``;
    * ``seconds``           — time spent actually serving: the fused
      rollout wall time on engine paths, ``finish_time - admit_time``
      (slot residency) on scheduler paths.

    Present when applicable:

    * ``model`` / ``version`` — the registry tenant and pinned version a
      routed request was served by;
    * ``trace_id``           — the observability correlation id (set
      when ``repro.obs`` tracing is enabled or the spec carried one).

    ``status`` is ``"ok"`` on every served result.  A server whose
    admission policy refuses a submission answers immediately with
    ``status="rejected"`` — no payload, and ``timings`` carrying
    ``reason`` (``"queue_full"`` / ``"deadline_unmeetable"`` /
    ``"tenant_over_share"``) plus ``retry_after_s``, the policy's
    estimate of when resubmitting could succeed.
    """

    preds: Any | None = None
    states: Any | None = None
    final_state: Any | None = None
    timings: dict = dataclasses.field(default_factory=dict)
    status: str = "ok"

    @property
    def rejected(self) -> bool:
        """True when admission control refused this submission."""
        return self.status == "rejected"

    @property
    def output(self) -> Any:
        """The requested payload: predictions, or states under
        ``want_states=True``."""
        return self.states if self.preds is None else self.preds


def lifecycle_timings(*, arrival_time: float, admit_time: float,
                      finish_time: float,
                      first_output_time: float | None = None,
                      seconds: float | None = None,
                      model: str | None = None,
                      version: int | None = None,
                      trace_id: str | None = None) -> dict:
    """Build the one documented ``RolloutResult.timings`` schema.

    Every serve path calls this so the key set can never drift between
    the one-shot engine paths and the scheduler paths (see
    :class:`RolloutResult` for the key meanings).  ``first_output_time``
    defaults to ``finish_time`` (one-shot: the whole output lands at
    once); ``seconds`` defaults to ``finish_time - admit_time``.
    """
    if first_output_time is None:
        first_output_time = finish_time
    t = {
        "arrival_time": arrival_time,
        "admit_time": admit_time,
        "first_output_time": first_output_time,
        "finish_time": finish_time,
        "queue_wait_s": admit_time - arrival_time,
        "ttfp_s": first_output_time - arrival_time,
        "latency_s": finish_time - arrival_time,
        "seconds": (finish_time - admit_time if seconds is None
                    else seconds),
    }
    if model is not None:
        t["model"] = model
        t["version"] = version
    if trace_id is not None:
        t["trace_id"] = trace_id
    return t


__all__ = ["SubmitSpec", "RolloutResult", "lifecycle_timings",
           "warn_deprecated", "_UNSET"]
