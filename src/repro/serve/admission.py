"""Backpressure and admission control for the serving queue.

The paper's pitch is *bounded* latency — a spatially compiled multiplier
whose per-step cost is static and predictable.  An unbounded FIFO throws
that away at the front door: under overload the queue (and therefore
queue-wait) grows without limit while the engine itself keeps its
promise.  This module closes the gap with a pluggable
:class:`AdmissionPolicy` consulted by both servers at ``submit()`` time:

* :class:`BoundedQueuePolicy` — reject when the queue is already
  ``max_depth`` deep (classic backpressure);
* :class:`DeadlineShedPolicy` — shed a request whose deadline the
  *estimated* queue delay already blows, so it never burns a slot (or a
  queue position) on an answer nobody will wait for.  The delay estimate
  reuses the PR-7 calibrated cost model's per-chunk prediction when the
  server has no measured chunk cost yet;
* :class:`TenantFairnessPolicy` — weighted per-tenant share of the
  in-system work, on top of the registry's concurrency quota (quota
  bounds *seated* slots; fairness bounds a tenant's claim on the whole
  queue under contention);
* :class:`CompositePolicy` — chain; first rejection wins.

A refused submission never enters the queue: the server answers
immediately with ``RolloutResult(status="rejected")`` carrying the
rejection ``reason`` and a ``retry_after_s`` hint in ``timings``, and
counts it in ``ServeStats.rejected`` / ``.shed`` and the
``requests_rejected_total`` / ``requests_shed_total`` obs metrics.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class Rejection:
    """An admission policy's verdict on one submission.

    ``reason`` names the rule that fired (``"queue_full"`` /
    ``"deadline_unmeetable"`` / ``"tenant_over_share"``);
    ``retry_after_s`` is the policy's estimate of how long until a
    resubmission could succeed; ``shed=True`` marks a deadline shed
    (counted separately from hard rejections — shedding is the policy
    *keeping* the latency promise, not refusing service).
    """

    reason: str
    retry_after_s: float
    shed: bool = False


class AdmissionPolicy:
    """Decide, at submit time, whether a request may join the queue.

    ``admit(server, qreq)`` answers ``None`` to accept or a
    :class:`Rejection` to refuse.  The policy sees the live server
    (queue depth, pool occupancy, stats, registry) and the fully-built
    :class:`~repro.serve.scheduler.QueuedRequest`, so custom policies
    can weigh anything those expose.  The base class accepts everything.
    """

    def admit(self, server, qreq) -> Rejection | None:
        return None


def estimate_chunk_seconds(server) -> float:
    """Best available per-chunk cost estimate for ``server``'s pool.

    Preference order: the fixed virtual-clock ``chunk_time`` when set
    (it *is* the chunk cost by definition), the measured per-call EWMA
    once chunks have run, then the PR-7 calibrated cost model's analytic
    prediction for the pool shape (``n_slots`` x ``chunk_steps`` under
    the engine's resolved schedule) — so admission decisions are
    cost-aware from the very first submit, before anything has been
    measured.
    """
    if server.chunk_time is not None:
        return float(server.chunk_time)
    st = server.stats
    if st.chunks and st.latency_ewma_s > 0:
        return float(st.latency_ewma_s)
    eng = server.batcher.engine
    try:
        from repro.plan.autotune import Schedule, predict_cost
        sched = eng.schedule
        if sched is None:
            sched = Schedule(
                "int8" if eng.config.mode.startswith("int8") else "fp32",
                eng.backend, eng.vmem_budget, eng.crossover,
                eng.batch_tile_max)
        est = predict_cost(eng.plan, sched, server.batcher.n_slots,
                           server.batcher.chunk_steps)
        return max(float(est), 1e-6)
    except Exception:
        # cost model unavailable for this engine/backend combination:
        # fall back to a small constant so policies stay functional
        return 1e-3


def estimate_queue_delay(server) -> float:
    """Estimated wait before a request submitted *now* would seat.

    Work-conserving estimate: every step still owed to seated slots plus
    every queued request's full length must drain through the pool at
    ``n_slots * chunk_steps`` steps per chunk before a new arrival is
    guaranteed a seat; each chunk costs :func:`estimate_chunk_seconds`.
    This is an upper-ish bound under FIFO (a request may seat earlier
    when a short slot retires), which is the right bias for shedding:
    never promise a deadline the queue cannot keep.
    """
    b = server.batcher
    live_steps = sum(q.length - b._pos[i]
                     for i, q in enumerate(b._slots) if q is not None)
    queued_steps = sum(entry[2].length for entry in server._queue)
    backlog = live_steps + queued_steps
    if backlog <= 0:
        return 0.0
    per_chunk_steps = b.n_slots * b.chunk_steps
    chunks = math.ceil(backlog / per_chunk_steps)
    return chunks * estimate_chunk_seconds(server)


@dataclasses.dataclass
class BoundedQueuePolicy(AdmissionPolicy):
    """Reject when the queue already holds ``max_depth`` requests.

    The retry hint is the time for one queue position to drain
    (total estimated delay spread over the queued requests), floored at
    one chunk.
    """

    max_depth: int = 64

    def admit(self, server, qreq) -> Rejection | None:
        depth = server.pending
        if depth < self.max_depth:
            return None
        retry = max(estimate_chunk_seconds(server),
                    estimate_queue_delay(server) / max(1, depth))
        return Rejection("queue_full", retry_after_s=retry)


@dataclasses.dataclass
class DeadlineShedPolicy(AdmissionPolicy):
    """Shed a request whose deadline the queue-delay estimate already
    blows — it would only be dropped (``timed_out``) later anyway, after
    holding a queue position the whole time.

    ``slack`` scales the estimate (>1.0 sheds more conservatively).
    Requests without a deadline always pass.
    """

    slack: float = 1.0

    def admit(self, server, qreq) -> Rejection | None:
        if qreq.deadline is None:
            return None
        est = estimate_queue_delay(server) * self.slack
        budget = qreq.deadline - qreq.arrival_time
        if est <= budget:
            return None
        return Rejection("deadline_unmeetable",
                         retry_after_s=max(0.0, est - budget), shed=True)


@dataclasses.dataclass
class TenantFairnessPolicy(AdmissionPolicy):
    """Weighted fair share of the *in-system* work per tenant.

    Under contention (seated + queued >= pool size) a tenant may hold at
    most ``ceil(w_i / W * in_system)`` of the in-system requests, where
    ``W`` sums the weights of the tenants currently present (plus the
    candidate's).  With equal weights this is plain proportional
    fairness; weights tilt the split.  Below contention the policy never
    fires — fairness is about dividing scarcity, not idle capacity.
    Complements the registry quota, which bounds only *seated* slots.
    """

    weights: dict = dataclasses.field(default_factory=dict)
    default_weight: float = 1.0

    def _weight(self, model) -> float:
        return float(self.weights.get(model, self.default_weight))

    def admit(self, server, qreq) -> Rejection | None:
        b = server.batcher
        counts: dict = {}
        for q in b._slots:
            if q is not None:
                counts[q.model] = counts.get(q.model, 0) + 1
        for entry in server._queue:
            m = entry[2].model
            counts[m] = counts.get(m, 0) + 1
        in_system = sum(counts.values()) + 1          # incl. the candidate
        if in_system <= b.n_slots:
            return None
        tenants = set(counts) | {qreq.model}
        total_w = sum(self._weight(m) for m in tenants)
        share = self._weight(qreq.model) / total_w if total_w > 0 else 0.0
        cap = max(1, math.ceil(share * in_system))
        mine = counts.get(qreq.model, 0) + 1
        if mine <= cap:
            return None
        return Rejection("tenant_over_share",
                         retry_after_s=estimate_chunk_seconds(server))


class CompositePolicy(AdmissionPolicy):
    """Chain policies; the first rejection wins, acceptance needs all."""

    def __init__(self, *policies: AdmissionPolicy):
        self.policies = list(policies)

    def admit(self, server, qreq) -> Rejection | None:
        for p in self.policies:
            verdict = p.admit(server, qreq)
            if verdict is not None:
                return verdict
        return None


def default_policy(*, max_depth: int = 64,
                   weights: dict | None = None) -> CompositePolicy:
    """The production default: bounded queue, deadline shedding, and
    (when ``weights`` given, or unconditionally with equal weights)
    tenant fairness — in that order."""
    return CompositePolicy(
        BoundedQueuePolicy(max_depth=max_depth),
        DeadlineShedPolicy(),
        TenantFairnessPolicy(weights=weights or {}))


__all__ = ["Rejection", "AdmissionPolicy", "BoundedQueuePolicy",
           "DeadlineShedPolicy", "TenantFairnessPolicy", "CompositePolicy",
           "default_policy", "estimate_chunk_seconds",
           "estimate_queue_delay"]
