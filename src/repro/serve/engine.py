"""Batched reservoir-rollout engine — the serving face of the paper.

The paper's win is specializing the *recurrent* multiply of a frozen
reservoir; serving-side, the unit of work is therefore the whole rollout
``x(n) = f(W_in u(n) + W x(n-1))`` over a request batch, not a single gemv.
Every backend builds from the one shared :class:`repro.plan.ExecutionPlan`
lowering of the reservoir matrix (the TPU analogue of the paper's
compile-to-bitstream step) and fronts two fused implementations:

* ``xla``    — a jitted ``lax.scan`` whose body does the *batched*
  recurrent multiply natively (dense or block-culled, dispatched on the
  plan's block density) with the input projection hoisted into a single
  (B*T, I) x (I, R) gemm before the scan.  The fast path on CPU/GPU.
* ``pallas`` — the ``reservoir_rollout`` Pallas kernel fed by the plan's
  VMEM-banded layout: T steps fused in one launch, state resident in VMEM,
  one band of weight tiles streamed per grid step.  The TPU path
  (``interpret=True`` elsewhere).

With a trained readout the engine serves *predictions*: ``W_out`` is fused
into the rollout epilogue (per-step ``y = x @ W_out`` inside the scan body
/ Pallas launch), so the state trajectory is never materialized on the
prediction path.  ``serve(..., return_states=True)`` keeps the old
states contract.
"""

from __future__ import annotations

import collections
import time
import warnings
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.esn import ESNParams
from repro.kernels.reservoir_rollout.ops import FusedRollout
from repro.kernels.reservoir_rollout.specialized import SpecializedRollout
from repro.plan import DEFAULT_VMEM_BUDGET, plan_for, specialize_rollout
from repro.plan.specialize import int8_recur_reference
from repro.serve.batching import MicroBatch, PaddingBucketer, RolloutRequest
from repro.serve.stats import ServeStats

# Buffer donation is a no-op on the CPU backend; jax warns about it on
# every donated dispatch, which would swamp the zero-copy serve loop's
# output.  The filter wraps OUR donated dispatches only — never globally,
# so user code's own donation warnings still surface.
_DONATION_WARNING = "Some donated buffers were not usable"


def donated_call(fn, u, x0b):
    """Invoke a donated rollout with the no-op-donation warning muted
    (shared by the single-device and sharded dispatch paths)."""
    with warnings.catch_warnings():
        warnings.filterwarnings("ignore", message=_DONATION_WARNING)
        return fn(u, x0b)

# Below this nonzero-block density the culled block loop beats one dense
# (B, R) x (R, R) product; above it the MXU/gemm wins.  Reservoirs at the
# paper's element sparsities (0.75-0.9) have dense *block* structure at
# block 128, so they take the dense path; block-structured matrices (and
# the paper's 0.98+ regimes at small blocks) take the culled loop.
DENSE_DISPATCH_DENSITY = 0.5


class ReservoirEngine:
    """Fused batched rollout (and readout) for one frozen ESN."""

    def __init__(self, params: ESNParams, *, backend: str = "auto",
                 interpret: bool = True, stats: ServeStats | None = None,
                 dense_dispatch_density: float = DENSE_DISPATCH_DENSITY,
                 vmem_budget: int | None = DEFAULT_VMEM_BUDGET,
                 specialize: bool = True):
        assert backend in ("auto", "xla", "pallas"), backend
        self.params = params
        self.config = params.config
        self.backend = "xla" if backend == "auto" else backend
        self.stats = stats if stats is not None else ServeStats()
        self.plan = plan_for(params.w)
        self.vmem_budget = vmem_budget
        self.specialize = specialize
        self._int8 = self.config.mode.startswith("int8")
        # Readout captured at construction; engine_for invalidates the
        # cached engine when params.w_out is replaced (fit_readout).
        self._w_out = params.w_out
        # plan.block_density (not plan.stats) keeps the fp32 path from
        # paying for the integer lowering just to make a dispatch decision
        self._dense_density = dense_dispatch_density
        self.uses_dense = (not self._int8 and
                           self.plan.block_density >= dense_dispatch_density)
        # specialized int8: block-dense matrices take one folded int32
        # gemm (the whole digit-plane fold), block-sparse ones the
        # program's culled folded/shift-add schedule
        self._int8_dense = (self._int8 and specialize and
                            self.plan.block_density >= dense_dispatch_density)
        # trace-time tick per compiled rollout: the recompilation guard
        # (N chunks must trace once per shape/regime, never per chunk)
        self._xla_traces: collections.Counter = collections.Counter()
        if self.backend == "pallas":
            cls = SpecializedRollout if specialize else FusedRollout
            self._fused = cls(
                self.plan, params.w_in, leak=self.config.leak,
                mode="int8" if self._int8 else "fp32",
                state_bits=self.config.state_bits, interpret=interpret,
                w_out=self._w_out, vmem_budget=vmem_budget)
        else:
            # jitted rollouts keyed on (with_readout, with_final, donated);
            # built lazily except the plain states path every caller hits
            # first.
            self._xla_fns = {
                (False, False, False): self._build_xla_fn(False, False)}

    def _xla(self, with_readout: bool, with_final: bool,
             donate: bool = False):
        key = (with_readout, with_final, donate)
        fn = self._xla_fns.get(key)
        if fn is None:
            fn = self._xla_fns[key] = self._build_xla_fn(
                with_readout, with_final, donate)
        return fn

    # -- fused XLA rollout ---------------------------------------------------
    def _build_xla_fn(self, with_readout: bool, with_final: bool,
                      donate: bool = False):
        params, cfg = self.params, self.config
        w, w_in = params.w, params.w_in
        int8 = self._int8
        leak = cfg.leak
        smax = (1 << (cfg.state_bits - 1)) - 1
        dim = cfg.reservoir_dim
        plan = self.plan
        w_out = jnp.asarray(self._w_out, jnp.float32) if with_readout else None
        traces = self._xla_traces
        # The engine may be constructed lazily inside someone else's jit
        # trace (run_reservoir under jax.jit); the dense closure constant
        # must be materialized eagerly or it leaks that trace.
        with jax.ensure_compile_time_eval():
            w_dense = w.dense_f32() if self.uses_dense else None
            # Specialized int8: constant-propagate the 2^w plane scales
            # and signs at build time.  Block-dense matrices fold ALL
            # planes into the quantized matrix — one int32 gemm replaces
            # the width shifted pos/neg plane products, bit-identically
            # (int32 accumulation is exact).  Block-sparse ones run the
            # program's culled folded/shift-add schedule.
            q_folded = w.q.astype(jnp.int32) if self._int8_dense else None
            program = None
            if int8 and self.specialize and not self._int8_dense:
                program = specialize_rollout(
                    plan, "int8", vmem_budget=self.vmem_budget)
        schedule = self.xla_schedule

        def rollout(u_bt: jnp.ndarray, x0: jnp.ndarray) -> jnp.ndarray:
            # trace-time side effect: the recompilation-guard counter
            # (donate is part of the key — the donated variant is a
            # legitimately distinct program, not a recompile)
            traces[(u_bt.shape, with_readout, with_final, donate,
                    schedule)] += 1
            # One gemm projects every input of every step before the scan.
            uproj = u_bt.astype(jnp.float32) @ w_in          # (B, T, R)
            uproj_t = jnp.swapaxes(uproj, 0, 1)              # (T, B, R)

            def body(x, up):
                if int8:
                    xq = jnp.clip(jnp.round(x * smax), -smax - 1,
                                  smax).astype(jnp.int32)
                    if q_folded is not None:
                        ri = xq @ q_folded
                    elif program is not None:
                        ri = int8_recur_reference(
                            program, xq, plan.rows_pad, dim)
                    else:
                        ri = w.matvec_int_exact(xq)
                    recur = ri.astype(jnp.float32) * (w.scale / smax)
                elif w_dense is not None:
                    recur = x @ w_dense
                else:
                    recur = w.matmul(x)
                nxt = jnp.tanh(up + recur)
                nxt = (1.0 - leak) * x + leak * nxt
                return nxt, nxt

            xf, states = jax.lax.scan(body, x0, uproj_t)
            out = jnp.swapaxes(states, 0, 1)                 # (B, T, R)
            if with_readout:
                # Fused readout: W_out applied inside the same compiled
                # program — one dispatch, predictions only leave the device,
                # and the result is the exact predict(states) contraction.
                out = out @ w_out                            # (B, T, O)
            if with_final:
                # xf is the scan carry — exactly x(T), so chunked rollouts
                # that resume from it reproduce the one-shot trajectory
                # bit for bit.
                return out, xf
            return out

        # Donating x0 lets XLA reuse the carried-state buffer for the
        # emitted final state — the zero-copy half of the chunk API.
        return jax.jit(rollout, donate_argnums=(1,) if donate else ())

    # -- backend dispatch ----------------------------------------------------
    @property
    def xla_schedule(self) -> str:
        """Which specialized XLA recurrence this engine compiled."""
        if not self._int8:
            return "fp32-dense" if self.uses_dense else "fp32-culled"
        if self._int8_dense:
            return "int8-folded-dense"
        if self.specialize:
            return "int8-folded-culled"
        return "int8-planes"

    @property
    def program(self):
        """The pallas backend's :class:`~repro.plan.RolloutProgram` (None
        on the XLA backend or with ``specialize=False``)."""
        return getattr(getattr(self, "_fused", None), "program", None)

    @property
    def trace_counts(self) -> collections.Counter:
        """Rollout traces per (shape, outputs, regime/schedule) — the
        recompilation guard: rolling N chunks of one shape must leave
        every count at exactly 1."""
        fused = getattr(self, "_fused", None)
        if fused is not None and hasattr(fused, "trace_counts"):
            return self._xla_traces + fused.trace_counts
        return collections.Counter(self._xla_traces)

    def _local_rollout(self, with_readout: bool, with_final: bool,
                       donate: bool = False):
        """The pure ``(B, T, I), (B, R) -> (B, T, *)`` rollout callable.

        Batch rows are independent through it (the recurrence never mixes
        rows), which is the property the sharded engine relies on: the same
        callable is the ``shard_map`` body in :mod:`repro.dist`, one
        replica per data shard over the batch axis.
        """
        if self.backend == "pallas":
            fused = self._fused
            kw = {"donate_state": donate} if isinstance(
                fused, SpecializedRollout) else {}

            def fn(u_bt, x0):
                out = fused(jnp.swapaxes(u_bt, 0, 1), x0,
                            return_states=not with_readout,
                            return_preds=with_readout,
                            return_final=with_final, **kw)
                y, xf = out if with_final else (out, None)
                y = jnp.swapaxes(y, 0, 1)
                return (y, xf) if with_final else y

            return fn
        return self._xla(with_readout, with_final, donate)

    def _dispatch(self, u, x0b, with_readout: bool, with_final: bool,
                  donate: bool = False):
        """One fused rollout call -> ``(out, final_state_or_None)``."""
        fn = self._local_rollout(with_readout, with_final, donate)
        out = donated_call(fn, u, x0b) if donate else fn(u, x0b)
        return out if with_final else (out, None)

    # -- public API ----------------------------------------------------------
    @property
    def has_readout(self) -> bool:
        """Whether a trained ``W_out`` is baked into this engine (serving
        defaults to predictions when True, states otherwise)."""
        return self._w_out is not None

    def _prepare(self, inputs, x0):
        u = jnp.asarray(inputs)
        single = u.ndim == 2
        if single:
            u = u[None]
        b = u.shape[0]
        dim = self.config.reservoir_dim
        if x0 is None:
            x0b = jnp.zeros((b, dim), jnp.float32)
        else:
            x0b = jnp.asarray(x0, jnp.float32)
            if x0b.ndim == 1:
                x0b = jnp.broadcast_to(x0b, (b, dim))
        return u, x0b, single

    def _record(self, out, batch, steps, t0, real_steps, defer=False):
        # Under an outer jit/vmap/grad trace the inputs are tracers: still
        # composable (the jitted fn nests), but timing/stats are meaningless
        # there — skip them instead of calling block_until_ready on a tracer.
        if not isinstance(out, jax.core.Tracer):
            if not defer:
                out.block_until_ready()
            # defer=True is the zero-copy serve loop: no host sync per
            # chunk — the recorded time is dispatch-side only (the
            # device->host wait lands at slot retirement), so the call is
            # flagged in the stats and throughput should be read from the
            # scheduler's makespan clock, not ServeStats.seconds.
            self.stats.record_call(batch=batch, steps=steps,
                                   seconds=time.perf_counter() - t0,
                                   real_steps=real_steps, deferred=defer)
        return out

    def rollout(self, inputs: jnp.ndarray,
                x0: jnp.ndarray | None = None,
                real_steps: int | None = None,
                return_final_state: bool = False, *,
                donate_state: bool = False,
                defer_sync: bool = False):
        """Roll the reservoir: (T, I) -> (T, R) or (B, T, I) -> (B, T, R).

        With ``return_final_state=True`` also returns x(T) — (R,) / (B, R)
        — the carry a later chunked call resumes from bit-identically.
        ``donate_state=True`` donates the ``x0`` buffer to the launch (the
        caller must not reuse it; the chunked scheduler owns its carry) and
        ``defer_sync=True`` skips the per-call host sync so the serve loop
        only waits for the device at retirement.
        """
        u, x0b, single = self._prepare(inputs, x0)
        b, t, _ = u.shape
        t0 = time.perf_counter()
        states, xf = self._dispatch(u, x0b, False, return_final_state,
                                    donate_state and return_final_state)
        self._record(states, b, t, t0, real_steps, defer=defer_sync)
        if return_final_state:
            return (states[0], xf[0]) if single else (states, xf)
        return states[0] if single else states

    def predictions(self, inputs: jnp.ndarray,
                    x0: jnp.ndarray | None = None,
                    real_steps: int | None = None,
                    return_final_state: bool = False, *,
                    donate_state: bool = False,
                    defer_sync: bool = False):
        """Fused-readout rollout: (B, T, I) -> (B, T, O) predictions.

        ``W_out`` is applied inside the rollout (scan body / Pallas
        epilogue), so the (B, T, R) state trajectory is never materialized.
        ``return_final_state=True`` additionally returns x(T), letting the
        continuous scheduler serve predictions chunk by chunk while
        carrying reservoir state between chunks.  ``donate_state`` /
        ``defer_sync`` are the zero-copy chunk-serving knobs (see
        :meth:`rollout`).
        """
        if self._w_out is None:
            raise ValueError("readout not trained; call fit_readout first "
                             "(or serve with return_states=True)")
        u, x0b, single = self._prepare(inputs, x0)
        b, t, _ = u.shape
        t0 = time.perf_counter()
        preds, xf = self._dispatch(u, x0b, True, return_final_state,
                                   donate_state and return_final_state)
        self._record(preds, b, t, t0, real_steps, defer=defer_sync)
        if return_final_state:
            return (preds[0], xf[0]) if single else (preds, xf)
        return preds[0] if single else preds

    def serve(self, requests: Sequence[RolloutRequest],
              bucketer: PaddingBucketer | None = None,
              return_states: bool | None = None) -> dict:
        """Batch, pad and roll a set of variable-length requests.

        With a trained readout (the default once ``fit_readout`` ran) this
        returns predictions — {uid: (T_request, O)} — via the fused readout
        epilogue.  ``return_states=True`` preserves the old contract and
        returns {uid: (T_request, R)} states; it is also the fallback when
        no readout is attached.  Padding overhead lands in ``self.stats``.

        Requests carrying an ``x0`` seed their slot of the batch with that
        initial state (rows without one start from zero).
        """
        if return_states is None:
            return_states = not self.has_readout
        fn = self.rollout if return_states else self.predictions
        bucketer = bucketer or PaddingBucketer()
        results = {}
        for mb in bucketer.group(list(requests)):
            out = fn(jnp.asarray(mb.inputs), x0=mb.x0,
                     real_steps=mb.real_steps)
            for j, req in enumerate(mb.requests):
                results[req.uid] = out[j, :req.length]
        return results


# -- bounded engine cache ----------------------------------------------------
# A long-lived multi-tenant server cycles through many reservoirs; an
# unbounded per-process cache of compiled engines would grow without limit.
# The cache is a module-level LRU keyed by (id(params), backend).  A cached
# engine holds its params alive, so a live entry's id can never be reused
# by a different object; after eviction an id *can* recur, which the
# identity staleness check below catches before serving a wrong engine.
ENGINE_CACHE_MAX = 32
_engine_cache: "collections.OrderedDict[tuple, tuple]" = \
    collections.OrderedDict()
_engine_cache_stats = {"hits": 0, "misses": 0, "evictions": 0}


def engine_cache_stats(reset: bool = False) -> dict:
    """Hit/miss/eviction counters of the ``engine_for`` LRU (plus current
    size); ``reset=True`` zeroes the counters."""
    out = dict(_engine_cache_stats, size=len(_engine_cache))
    if reset:
        _engine_cache_stats.update(hits=0, misses=0, evictions=0)
    return out


def engine_cache_clear() -> None:
    _engine_cache.clear()


def engine_for(params: ESNParams, backend: str = "auto",
               **kwargs) -> ReservoirEngine:
    """Engine accessor with a bounded LRU cache (reservoirs are frozen).

    Cached per (params, backend) so repeated ``run_reservoir`` calls reuse
    the compiled rollout instead of rebuilding plan + jit each time.  The
    entry is invalidated by everything the engine bakes in at construction
    — the reservoir matrix, the *readout* (so a stale compiled rollout is
    never served after ``fit_readout`` replaces ``w_out``), and the
    leak/mode/precision config.  At most :data:`ENGINE_CACHE_MAX` engines
    stay resident (least recently used evicted first), so a multi-tenant
    server's memory is bounded — ``engine_cache_stats()`` exposes the
    hit/miss/eviction counters.  NOTE: a cached engine holds its params
    (and compiled programs) alive until it is evicted or
    ``engine_cache_clear()`` runs — the cache trades bounded pinning for
    compile reuse.  Non-default kwargs (stats, interpret, specialize,
    ...) bypass the cache — construct :class:`ReservoirEngine` directly
    for those.
    """
    key = (id(params), "xla" if backend == "auto" else backend)
    eng = _engine_cache.get(key)
    cfg = params.config
    stale = (eng is None or eng.params is not params
             or eng._w_out is not params.w_out
             or eng.params.w is not params.w
             or (eng.config.leak, eng.config.mode, eng.config.state_bits)
             != (cfg.leak, cfg.mode, cfg.state_bits))
    if stale or kwargs:
        eng = ReservoirEngine(params, backend=backend, **kwargs)
        if not kwargs:
            _engine_cache[key] = eng
            _engine_cache.move_to_end(key)
            while len(_engine_cache) > ENGINE_CACHE_MAX:
                _engine_cache.popitem(last=False)
                _engine_cache_stats["evictions"] += 1
            _engine_cache_stats["misses"] += 1
    else:
        _engine_cache.move_to_end(key)
        _engine_cache_stats["hits"] += 1
    return eng


__all__ = ["ENGINE_CACHE_MAX", "ReservoirEngine", "engine_for",
           "engine_cache_clear", "engine_cache_stats", "ServeStats",
           "PaddingBucketer", "RolloutRequest", "MicroBatch"]
