"""Batched reservoir-rollout engine — the serving face of the paper.

The paper's win is specializing the *recurrent* multiply of a frozen
reservoir; serving-side, the unit of work is therefore the whole rollout
``x(n) = f(W_in u(n) + W x(n-1))`` over a request batch, not a single gemv.
The engine fronts two fused implementations behind one interface:

* ``xla``    — a jitted ``lax.scan`` whose body does the *batched*
  recurrent multiply natively (one (B, R) x (R, R) product per step, dense
  or block-culled depending on the compiled matrix's block density) with
  the input projection hoisted into a single (B*T, I) x (I, R) gemm before
  the scan.  This is the fast path on CPU/GPU backends.
* ``pallas`` — the ``reservoir_rollout`` Pallas kernel: T steps fused in
  one launch, state resident in VMEM, zero blocks culled at trace time.
  This is the TPU path (``interpret=True`` elsewhere).

Both preserve the per-step state requantization of the int8 digit-plane
mode exactly.  ``run_reservoir`` dispatches here by default; the legacy
per-step scan survives as ``engine="scan"`` and is the benchmark baseline.
"""

from __future__ import annotations

import time
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.esn import ESNParams
from repro.kernels.reservoir_rollout.ops import FusedRollout
from repro.serve.batching import MicroBatch, PaddingBucketer, RolloutRequest
from repro.serve.stats import ServeStats

# Below this nonzero-block density the culled block loop beats one dense
# (B, R) x (R, R) product; above it the MXU/gemm wins.  Reservoirs at the
# paper's element sparsities (0.75-0.9) have dense *block* structure at
# block 128, so they take the dense path; block-structured matrices (and
# the paper's 0.98+ regimes at small blocks) take the culled loop.
DENSE_DISPATCH_DENSITY = 0.5


class ReservoirEngine:
    """Fused batched rollout for one frozen ESN."""

    def __init__(self, params: ESNParams, *, backend: str = "auto",
                 interpret: bool = True, stats: ServeStats | None = None,
                 dense_dispatch_density: float = DENSE_DISPATCH_DENSITY):
        assert backend in ("auto", "xla", "pallas"), backend
        self.params = params
        self.config = params.config
        self.backend = "xla" if backend == "auto" else backend
        self.stats = stats if stats is not None else ServeStats()
        self._int8 = self.config.mode.startswith("int8")
        self.uses_dense = (not self._int8 and
                           params.w.blocks.density >= dense_dispatch_density)
        if self.backend == "pallas":
            self._fused = FusedRollout(
                params.w, params.w_in, leak=self.config.leak,
                mode="int8" if self._int8 else "fp32",
                state_bits=self.config.state_bits, interpret=interpret)
        else:
            self._xla_fn = self._build_xla_fn()

    # -- fused XLA rollout ---------------------------------------------------
    def _build_xla_fn(self):
        params, cfg = self.params, self.config
        w, w_in = params.w, params.w_in
        int8 = self._int8
        leak = cfg.leak
        smax = (1 << (cfg.state_bits - 1)) - 1
        # The engine may be constructed lazily inside someone else's jit
        # trace (run_reservoir under jax.jit); the dense closure constant
        # must be materialized eagerly or it leaks that trace.
        with jax.ensure_compile_time_eval():
            w_dense = w.dense_f32() if self.uses_dense else None

        def rollout(u_bt: jnp.ndarray, x0: jnp.ndarray) -> jnp.ndarray:
            # One gemm projects every input of every step before the scan.
            uproj = u_bt.astype(jnp.float32) @ w_in          # (B, T, R)
            uproj_t = jnp.swapaxes(uproj, 0, 1)              # (T, B, R)

            def body(x, up):
                if int8:
                    xq = jnp.clip(jnp.round(x * smax), -smax - 1,
                                  smax).astype(jnp.int32)
                    recur = w.matvec_int_exact(xq).astype(jnp.float32)
                    recur = recur * (w.scale / smax)
                elif w_dense is not None:
                    recur = x @ w_dense
                else:
                    recur = w.matmul(x)
                nxt = jnp.tanh(up + recur)
                nxt = (1.0 - leak) * x + leak * nxt
                return nxt, nxt

            _, states = jax.lax.scan(body, x0, uproj_t)
            return jnp.swapaxes(states, 0, 1)                # (B, T, R)

        return jax.jit(rollout)

    # -- public API ----------------------------------------------------------
    def rollout(self, inputs: jnp.ndarray,
                x0: jnp.ndarray | None = None,
                real_steps: int | None = None) -> jnp.ndarray:
        """Roll the reservoir: (T, I) -> (T, R) or (B, T, I) -> (B, T, R)."""
        u = jnp.asarray(inputs)
        single = u.ndim == 2
        if single:
            u = u[None]
        b, t, _ = u.shape
        dim = self.config.reservoir_dim
        if x0 is None:
            x0b = jnp.zeros((b, dim), jnp.float32)
        else:
            x0b = jnp.asarray(x0, jnp.float32)
            if x0b.ndim == 1:
                x0b = jnp.broadcast_to(x0b, (b, dim))
        # Under an outer jit/vmap/grad trace the inputs are tracers: still
        # composable (the jitted fn nests), but timing/stats are meaningless
        # there — skip them instead of calling block_until_ready on a tracer.
        tracing = isinstance(u, jax.core.Tracer)
        t0 = time.perf_counter()
        if self.backend == "pallas":
            states = self._fused(jnp.swapaxes(u, 0, 1), x0b)
            states = jnp.swapaxes(states, 0, 1)
        else:
            states = self._xla_fn(u, x0b)
        if not tracing:
            states.block_until_ready()
            self.stats.record_call(batch=b, steps=t,
                                   seconds=time.perf_counter() - t0,
                                   real_steps=real_steps)
        return states[0] if single else states

    def serve(self, requests: Sequence[RolloutRequest],
              bucketer: PaddingBucketer | None = None) -> dict:
        """Batch, pad and roll a set of variable-length requests.

        Returns {uid: (T_request, R) states}, each sliced back to its real
        length.  Padding overhead lands in ``self.stats``.
        """
        bucketer = bucketer or PaddingBucketer()
        results = {}
        for mb in bucketer.group(list(requests)):
            states = self.rollout(jnp.asarray(mb.inputs),
                                  real_steps=mb.real_steps)
            for j, req in enumerate(mb.requests):
                results[req.uid] = states[j, :req.length]
        return results


def engine_for(params: ESNParams, backend: str = "auto",
               **kwargs) -> ReservoirEngine:
    """Engine accessor with a per-params cache (reservoirs are frozen).

    Cached per backend so repeated ``run_reservoir(engine="pallas")`` calls
    reuse the compiled rollout instead of rebuilding plan + jit each time.
    Non-default kwargs (stats, interpret, ...) bypass the cache — construct
    :class:`ReservoirEngine` directly for those.
    """
    key = "xla" if backend == "auto" else backend
    cache = getattr(params, "_serve_engines", None)
    if cache is None:
        cache = params._serve_engines = {}
    eng = cache.get(key)
    if eng is None or eng.params is not params or kwargs:
        eng = ReservoirEngine(params, backend=backend, **kwargs)
        if not kwargs:
            cache[key] = eng
    return eng


__all__ = ["ReservoirEngine", "engine_for", "ServeStats", "PaddingBucketer",
           "RolloutRequest", "MicroBatch"]
