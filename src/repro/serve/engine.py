"""Batched reservoir-rollout engine — the serving face of the paper.

The paper's win is specializing the *recurrent* multiply of a frozen
reservoir; serving-side, the unit of work is therefore the whole rollout
``x(n) = f(W_in u(n) + W x(n-1))`` over a request batch, not a single gemv.
Every backend builds from the one shared :class:`repro.plan.ExecutionPlan`
lowering of the reservoir matrix (the TPU analogue of the paper's
compile-to-bitstream step) and fronts two fused implementations:

* ``xla``    — a jitted ``lax.scan`` whose body does the *batched*
  recurrent multiply natively (dense or block-culled, dispatched on the
  plan's block density) with the input projection hoisted into a single
  (B*T, I) x (I, R) gemm before the scan.  The fast path on CPU/GPU.
* ``pallas`` — the ``reservoir_rollout`` Pallas kernel fed by the plan's
  VMEM-banded layout: T steps fused in one launch, state resident in VMEM,
  one band of weight tiles streamed per grid step.  The TPU path
  (``interpret=True`` elsewhere).

With a trained readout the engine serves *predictions*: ``W_out`` is fused
into the rollout epilogue (per-step ``y = x @ W_out`` inside the scan body
/ Pallas launch), so the state trajectory is never materialized on the
prediction path.  The request/response surface is the unified
:class:`~repro.serve.api.SubmitSpec` -> :class:`~repro.serve.api.RolloutResult`
contract (``submit`` / ``submit_many``); ``want_states=True`` on the spec
keeps the states contract, and the chunked schedulers drive
:meth:`ReservoirEngine.run_segment` directly.
"""

from __future__ import annotations

import collections
import time
import warnings
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.esn import ESNParams
from repro.kernels.reservoir_rollout.ops import FusedRollout
from repro.kernels.reservoir_rollout.specialized import SpecializedRollout
from repro.plan import (DEFAULT_BATCH_TILE, DEFAULT_VMEM_BUDGET, plan_for,
                        specialize_rollout)
from repro.plan.autotune import resolve_backend, resolve_schedule
from repro.plan.specialize import int8_recur_reference
from repro.serve.api import (_UNSET, RolloutResult, SubmitSpec,
                             lifecycle_timings, warn_deprecated)
from repro.serve.batching import MicroBatch, PaddingBucketer, RolloutRequest
from repro.serve.stats import ServeStats

# Buffer donation is a no-op on the CPU backend; jax warns about it on
# every donated dispatch, which would swamp the zero-copy serve loop's
# output.  The filter wraps OUR donated dispatches only — never globally,
# so user code's own donation warnings still surface.
_DONATION_WARNING = "Some donated buffers were not usable"

# one process-wide warning for deadline-bearing specs on the one-shot
# path (the result still records timings["deadline_ignored"] every time)
_WARNED_DEADLINE = False


def donated_call(fn, u, x0b):
    """Invoke a donated rollout with the no-op-donation warning muted
    (shared by the single-device and sharded dispatch paths)."""
    with warnings.catch_warnings():
        warnings.filterwarnings("ignore", message=_DONATION_WARNING)
        return fn(u, x0b)

# Below this nonzero-block density the culled block loop beats one dense
# (B, R) x (R, R) product; above it the MXU/gemm wins.  Reservoirs at the
# paper's element sparsities (0.75-0.9) have dense *block* structure at
# block 128, so they take the dense path; block-structured matrices (and
# the paper's 0.98+ regimes at small blocks) take the culled loop.
DENSE_DISPATCH_DENSITY = 0.5


class ReservoirEngine:
    """Fused batched rollout (and readout) for one frozen ESN."""

    def __init__(self, params: ESNParams, *, backend: str = "auto",
                 interpret: bool = True, stats: ServeStats | None = None,
                 dense_dispatch_density: float = DENSE_DISPATCH_DENSITY,
                 vmem_budget: int | None = _UNSET,
                 specialize: bool = True, tenant: str | None = None,
                 crossover: int | None = None,
                 batch_tile_max: int | None = None, schedule=None):
        assert backend in ("auto", "xla", "pallas"), backend
        self.params = params
        self.config = params.config
        self.stats = stats if stats is not None else ServeStats()
        # registry model name this engine serves (None outside a
        # registry); threads through to the plan-cache tenant counters
        self.tenant = tenant
        self.plan = plan_for(params.w, tenant=tenant)
        self.specialize = specialize
        self._int8 = self.config.mode.startswith("int8")
        # backend="auto" resolves through the plan autotuner: a persisted
        # tuning cache replays the measured winner, a cold cache falls
        # back to the analytic cost model's pick — never a hardcoded
        # backend.  The tuned schedule fills every knob the caller left
        # unset; explicit kwargs always win (a caller pinning the budget
        # keeps it).  ``schedule`` accepts a Schedule or TunedSchedule to
        # bypass resolution entirely (the bench harness injects measured
        # winners this way).
        self.requested_backend = backend
        if schedule is None and backend == "auto" and specialize:
            schedule = resolve_schedule(
                self.plan, "int8" if self._int8 else "fp32")
        sched = getattr(schedule, "schedule", schedule)
        self.schedule = sched
        if sched is not None:
            self.backend = sched.backend if backend == "auto" else backend
            if vmem_budget is _UNSET:
                vmem_budget = sched.vmem_budget
            if crossover is None:
                crossover = sched.crossover
            if batch_tile_max is None:
                batch_tile_max = sched.batch_tile_max
        else:
            self.backend = "xla" if backend == "auto" else backend
        self.vmem_budget = DEFAULT_VMEM_BUDGET if vmem_budget is _UNSET \
            else vmem_budget
        self.crossover = crossover
        self.batch_tile_max = batch_tile_max
        # Readout captured at construction; engine_for invalidates the
        # cached engine when params.w_out is replaced (fit_readout).
        self._w_out = params.w_out
        # plan.block_density (not plan.stats) keeps the fp32 path from
        # paying for the integer lowering just to make a dispatch decision
        self._dense_density = dense_dispatch_density
        self.uses_dense = (not self._int8 and
                           self.plan.block_density >= dense_dispatch_density)
        # specialized int8: block-dense matrices take one folded int32
        # gemm (the whole digit-plane fold), block-sparse ones the
        # program's culled folded/shift-add schedule
        self._int8_dense = (self._int8 and specialize and
                            self.plan.block_density >= dense_dispatch_density)
        # trace-time tick per compiled rollout: the recompilation guard
        # (N chunks must trace once per shape/regime, never per chunk)
        self._xla_traces: collections.Counter = collections.Counter()
        obs.event("engine_build", backend=self.backend, tenant=tenant,
                  schedule=str(self.schedule))
        obs.inc("engine_builds_total", backend=self.backend)
        if self.backend == "pallas":
            kw = {}
            if specialize:
                # the schedule knobs are a specialization concept; the
                # generic banded FusedRollout has no crossover/tiling
                kw = {"crossover": self.crossover,
                      "batch_tile_max": self.batch_tile_max
                      or DEFAULT_BATCH_TILE}
            cls = SpecializedRollout if specialize else FusedRollout
            self._fused = cls(
                self.plan, params.w_in, leak=self.config.leak,
                mode="int8" if self._int8 else "fp32",
                state_bits=self.config.state_bits, interpret=interpret,
                w_out=self._w_out, vmem_budget=self.vmem_budget, **kw)
        else:
            # jitted rollouts keyed on (with_readout, with_final, donated);
            # built lazily except the plain states path every caller hits
            # first.
            self._xla_fns = {
                (False, False, False): self._build_xla_fn(False, False)}

    def _xla(self, with_readout: bool, with_final: bool,
             donate: bool = False):
        key = (with_readout, with_final, donate)
        fn = self._xla_fns.get(key)
        if fn is None:
            fn = self._xla_fns[key] = self._build_xla_fn(
                with_readout, with_final, donate)
        return fn

    # -- fused XLA rollout ---------------------------------------------------
    def _build_xla_fn(self, with_readout: bool, with_final: bool,
                      donate: bool = False):
        params, cfg = self.params, self.config
        w, w_in = params.w, params.w_in
        int8 = self._int8
        leak = cfg.leak
        smax = (1 << (cfg.state_bits - 1)) - 1
        dim = cfg.reservoir_dim
        plan = self.plan
        w_out = jnp.asarray(self._w_out, jnp.float32) if with_readout else None
        traces = self._xla_traces
        # The engine may be constructed lazily inside someone else's jit
        # trace (run_reservoir under jax.jit); the dense closure constant
        # must be materialized eagerly or it leaks that trace.
        with jax.ensure_compile_time_eval():
            w_dense = w.dense_f32() if self.uses_dense else None
            # Specialized int8: constant-propagate the 2^w plane scales
            # and signs at build time.  Block-dense matrices fold ALL
            # planes into the quantized matrix — one int32 gemm replaces
            # the width shifted pos/neg plane products, bit-identically
            # (int32 accumulation is exact).  Block-sparse ones run the
            # program's culled folded/shift-add schedule.
            q_folded = w.q.astype(jnp.int32) if self._int8_dense else None
            program = None
            if int8 and self.specialize and not self._int8_dense:
                program = specialize_rollout(
                    plan, "int8", vmem_budget=self.vmem_budget,
                    crossover=self.crossover,
                    batch_tile_max=self.batch_tile_max
                    or DEFAULT_BATCH_TILE)
        schedule = self.xla_schedule

        def rollout(u_bt: jnp.ndarray, x0: jnp.ndarray) -> jnp.ndarray:
            # trace-time side effect: the recompilation-guard counter
            # (donate is part of the key — the donated variant is a
            # legitimately distinct program, not a recompile)
            key = (u_bt.shape, with_readout, with_final, donate, schedule)
            traces[key] += 1
            n = traces[key]
            obs.event("xla_trace" if n == 1 else "retrace",
                      backend="xla", shape=str(u_bt.shape),
                      schedule=schedule, count=n)
            obs.inc("retrace_total" if n > 1 else "compile_traces_total",
                    backend="xla")
            # One gemm projects every input of every step before the scan.
            uproj = u_bt.astype(jnp.float32) @ w_in          # (B, T, R)
            uproj_t = jnp.swapaxes(uproj, 0, 1)              # (T, B, R)

            def body(x, up):
                if int8:
                    xq = jnp.clip(jnp.round(x * smax), -smax - 1,
                                  smax).astype(jnp.int32)
                    if q_folded is not None:
                        ri = xq @ q_folded
                    elif program is not None:
                        ri = int8_recur_reference(
                            program, xq, plan.rows_pad, dim)
                    else:
                        ri = w.matvec_int_exact(xq)
                    recur = ri.astype(jnp.float32) * (w.scale / smax)
                elif w_dense is not None:
                    recur = x @ w_dense
                else:
                    recur = w.matmul(x)
                nxt = jnp.tanh(up + recur)
                nxt = (1.0 - leak) * x + leak * nxt
                return nxt, nxt

            xf, states = jax.lax.scan(body, x0, uproj_t)
            out = jnp.swapaxes(states, 0, 1)                 # (B, T, R)
            if with_readout:
                # Fused readout: W_out applied inside the same compiled
                # program — one dispatch, predictions only leave the device,
                # and the result is the exact predict(states) contraction.
                out = out @ w_out                            # (B, T, O)
            if with_final:
                # xf is the scan carry — exactly x(T), so chunked rollouts
                # that resume from it reproduce the one-shot trajectory
                # bit for bit.
                return out, xf
            return out

        # Donating x0 lets XLA reuse the carried-state buffer for the
        # emitted final state — the zero-copy half of the chunk API.
        return jax.jit(rollout, donate_argnums=(1,) if donate else ())

    # -- backend dispatch ----------------------------------------------------
    @property
    def xla_schedule(self) -> str:
        """Which specialized XLA recurrence this engine compiled."""
        if not self._int8:
            return "fp32-dense" if self.uses_dense else "fp32-culled"
        if self._int8_dense:
            return "int8-folded-dense"
        if self.specialize:
            return "int8-folded-culled"
        return "int8-planes"

    @property
    def program(self):
        """The pallas backend's :class:`~repro.plan.RolloutProgram` (None
        on the XLA backend or with ``specialize=False``)."""
        return getattr(getattr(self, "_fused", None), "program", None)

    @property
    def trace_counts(self) -> collections.Counter:
        """Rollout traces per (shape, outputs, regime/schedule) — the
        recompilation guard: rolling N chunks of one shape must leave
        every count at exactly 1."""
        fused = getattr(self, "_fused", None)
        if fused is not None and hasattr(fused, "trace_counts"):
            return self._xla_traces + fused.trace_counts
        return collections.Counter(self._xla_traces)

    def _local_rollout(self, with_readout: bool, with_final: bool,
                       donate: bool = False):
        """The pure ``(B, T, I), (B, R) -> (B, T, *)`` rollout callable.

        Batch rows are independent through it (the recurrence never mixes
        rows), which is the property the sharded engine relies on: the same
        callable is the ``shard_map`` body in :mod:`repro.dist`, one
        replica per data shard over the batch axis.
        """
        if self.backend == "pallas":
            fused = self._fused
            kw = {"donate_state": donate} if isinstance(
                fused, SpecializedRollout) else {}

            def fn(u_bt, x0):
                out = fused(jnp.swapaxes(u_bt, 0, 1), x0,
                            want_states=not with_readout,
                            want_preds=with_readout,
                            want_final=with_final, **kw)
                y, xf = out if with_final else (out, None)
                y = jnp.swapaxes(y, 0, 1)
                return (y, xf) if with_final else y

            return fn
        return self._xla(with_readout, with_final, donate)

    def _dispatch(self, u, x0b, with_readout: bool, with_final: bool,
                  donate: bool = False):
        """One fused rollout call -> ``(out, final_state_or_None)``."""
        fn = self._local_rollout(with_readout, with_final, donate)
        out = donated_call(fn, u, x0b) if donate else fn(u, x0b)
        return out if with_final else (out, None)

    # -- public API ----------------------------------------------------------
    @property
    def has_readout(self) -> bool:
        """Whether a trained ``W_out`` is baked into this engine (serving
        defaults to predictions when True, states otherwise)."""
        return self._w_out is not None

    def _prepare(self, inputs, x0):
        u = jnp.asarray(inputs)
        single = u.ndim == 2
        if single:
            u = u[None]
        b = u.shape[0]
        dim = self.config.reservoir_dim
        if x0 is None:
            x0b = jnp.zeros((b, dim), jnp.float32)
        else:
            x0b = jnp.asarray(x0, jnp.float32)
            if x0b.ndim == 1:
                x0b = jnp.broadcast_to(x0b, (b, dim))
        return u, x0b, single

    def _record(self, out, batch, steps, t0, real_steps, defer=False):
        # Under an outer jit/vmap/grad trace the inputs are tracers: still
        # composable (the jitted fn nests), but timing/stats are meaningless
        # there — skip them instead of calling block_until_ready on a tracer.
        if not isinstance(out, jax.core.Tracer):
            if not defer:
                out.block_until_ready()
            # defer=True is the zero-copy serve loop: no host sync per
            # chunk — the recorded time is dispatch-side only (the
            # device->host wait lands at slot retirement), so the call is
            # flagged in the stats and throughput should be read from the
            # scheduler's makespan clock, not ServeStats.seconds.
            seconds = time.perf_counter() - t0
            self.stats.record_call(batch=batch, steps=steps,
                                   seconds=seconds,
                                   real_steps=real_steps, deferred=defer)
            # deferred calls timed dispatch only; synced calls include the
            # device wait — two different span names so the trace never
            # conflates the two measurements.
            obs.span("engine.dispatch" if defer else "engine.rollout",
                     t0, t0 + seconds, backend=self.backend,
                     batch=batch, steps=steps, deferred=defer)
            obs.observe("engine_rollout_seconds", seconds,
                        backend=self.backend)
        return out

    def _resolve_want(self, want_states: bool | None) -> bool:
        want = (not self.has_readout) if want_states is None \
            else bool(want_states)
        if not want and self._w_out is None:
            raise ValueError("readout not trained; call fit_readout first "
                             "(or submit with want_states=True)")
        return want

    def run_segment(self, inputs, x0, *, want_states: bool = False,
                    real_steps: int | None = None,
                    donate_state: bool = False,
                    defer_sync: bool = False):
        """The chunk-serving primitive: ``(B, T, I), (B, R) -> (out, x_end)``.

        One fused rollout of a batch segment from the carried states,
        ALWAYS returning the post-segment states — the carry the next
        segment resumes from bit-identically.  ``donate_state=True``
        donates the ``x0`` buffer to the launch (the caller must not reuse
        it; the chunked scheduler owns its carry) and ``defer_sync=True``
        skips the per-call host sync so the serve loop only waits for the
        device at slot retirement.  Strictly batched: no 2D single-sequence
        convenience — that is :meth:`submit`'s job.
        """
        if not want_states and self._w_out is None:
            raise ValueError("readout not trained; call fit_readout first "
                             "(or run the segment with want_states=True)")
        u = jnp.asarray(inputs)
        x0b = jnp.asarray(x0, jnp.float32)
        b, t = u.shape[0], u.shape[1]
        t0 = time.perf_counter()
        out, xf = self._dispatch(u, x0b, not want_states, True, donate_state)
        self._record(out, b, t, t0, real_steps, defer=defer_sync)
        return out, xf

    def submit(self, spec: SubmitSpec) -> RolloutResult:
        """One-shot serve of a single :class:`SubmitSpec`.

        ``inputs`` may be (T, I) or pre-batched (B, T, I); the result's
        ``preds``/``states``/``final_state`` match that leading shape.
        ``final_state`` is exactly x(T) — the chunk-resume carry.
        ``spec.deadline`` cannot be enforced here (no queue to wait in,
        and the fused rollout is not preemptible): a spec carrying one
        warns once per process and the result records
        ``timings["deadline_ignored"] = True`` so callers can tell the
        contract was not honored — deadline-bearing work belongs on a
        server.  Routed ``spec.model`` requests belong on a
        registry-backed server or :meth:`ModelRegistry.submit`.
        """
        if spec.model is not None:
            raise ValueError(
                f"spec routes to model {spec.model!r} but this is a bare "
                "single-model engine; submit through a registry-backed "
                "server (or ModelRegistry.submit)")
        deadline_ignored = spec.deadline is not None
        if deadline_ignored:
            global _WARNED_DEADLINE
            if not _WARNED_DEADLINE:
                _WARNED_DEADLINE = True
                warnings.warn(
                    "SubmitSpec.deadline is ignored by one-shot "
                    "ReservoirEngine.submit (there is no queue to wait "
                    "in); submit through AsyncReservoirServer to get "
                    "deadline enforcement", UserWarning, stacklevel=2)
        want = self._resolve_want(spec.want_states)
        u, x0b, single = self._prepare(spec.inputs, spec.x0)
        b, t, _ = u.shape
        trace_id = spec.trace_id or obs.new_trace_id()
        t0 = time.perf_counter()
        out, xf = self._dispatch(u, x0b, not want, True, False)
        self._record(out, b, t, t0, None)
        finish = time.perf_counter()
        obs.span("request.serve", t0, finish, trace_id=trace_id,
                 clock="wall", batch=b, steps=t)
        obs.observe("request_latency_seconds", finish - t0, path="engine")
        if single:
            out, xf = out[0], xf[0]
        timings = lifecycle_timings(arrival_time=t0, admit_time=t0,
                                    finish_time=finish,
                                    seconds=finish - t0,
                                    trace_id=trace_id)
        if deadline_ignored:
            timings["deadline_ignored"] = True
        return RolloutResult(preds=None if want else out,
                             states=out if want else None,
                             final_state=xf,
                             timings=timings)

    def submit_many(self, specs: Sequence[SubmitSpec],
                    bucketer: PaddingBucketer | None = None) -> dict:
        """Batch, pad and roll a set of variable-length specs.

        Returns ``{uid: RolloutResult}`` (specs without a ``uid`` get
        ``req<position>``).  Specs sharing a resolved ``want_states`` ride
        the same padded microbatches; padding overhead lands in
        ``self.stats``.  ``final_state`` is ``None`` on this path: the
        padded batch rolls past each request's real length, so the
        microbatch carry is not any request's x(T) — use :meth:`submit`
        when the resume carry matters.  A spec's ``x0`` seeds its row of
        the padded batch (rows without one start from zero).
        """
        bucketer = bucketer or PaddingBucketer()
        groups: dict[bool, list] = {}
        tids: dict = {}
        for i, spec in enumerate(specs):
            if spec.model is not None:
                raise ValueError(
                    f"spec routes to model {spec.model!r}; submit through "
                    "a registry-backed server")
            want = self._resolve_want(spec.want_states)
            uid = spec.uid if spec.uid is not None else f"req{i}"
            tids[uid] = spec.trace_id or obs.new_trace_id()
            groups.setdefault(want, []).append(
                RolloutRequest(uid=uid, inputs=np.asarray(spec.inputs),
                               x0=spec.x0))
        results: dict = {}
        dim = self.config.reservoir_dim
        arrival = time.perf_counter()
        for want, reqs in groups.items():
            for mb in bucketer.group(reqs):
                u = jnp.asarray(mb.inputs)
                b, t = u.shape[0], u.shape[1]
                x0b = (jnp.zeros((b, dim), jnp.float32) if mb.x0 is None
                       else jnp.asarray(mb.x0, jnp.float32))
                t0 = time.perf_counter()
                out, _xf = self._dispatch(u, x0b, not want, True, False)
                self._record(out, b, t, t0, mb.real_steps)
                finish = time.perf_counter()
                seconds = finish - t0
                for j, req in enumerate(mb.requests):
                    row = out[j, :req.length]
                    tid = tids[req.uid]
                    obs.span("request.serve", t0, finish, trace_id=tid,
                             clock="wall", batch=b, steps=t)
                    obs.observe("request_latency_seconds", finish - arrival,
                                path="engine")
                    results[req.uid] = RolloutResult(
                        preds=None if want else row,
                        states=row if want else None,
                        timings=lifecycle_timings(
                            arrival_time=arrival, admit_time=t0,
                            finish_time=finish, seconds=seconds,
                            trace_id=tid))
        return results

    # -- deprecated boolean-twin shims (one release) -------------------------
    def rollout(self, inputs: jnp.ndarray,
                x0: jnp.ndarray | None = None,
                real_steps: int | None = None,
                return_final_state: bool = _UNSET, *,
                donate_state: bool = False,
                defer_sync: bool = False):
        """Roll the reservoir: (T, I) -> (T, R) or (B, T, I) -> (B, T, R).

        Passing the deprecated boolean twin (``True`` changes the return
        arity to ``(states, x(T))``) warns: chunked callers belong on
        :meth:`run_segment`, one-shot callers needing the carry on
        :meth:`submit` (``RolloutResult.final_state``).
        """
        with_final = False
        if return_final_state is not _UNSET:
            warn_deprecated(
                "rollout(return_final_state=...) is deprecated: use "
                "run_segment() for chunked serving or "
                "submit(SubmitSpec(...)).final_state for the one-shot "
                "carry")
            with_final = bool(return_final_state)
        u, x0b, single = self._prepare(inputs, x0)
        b, t, _ = u.shape
        t0 = time.perf_counter()
        states, xf = self._dispatch(u, x0b, False, with_final,
                                    donate_state and with_final)
        self._record(states, b, t, t0, real_steps, defer=defer_sync)
        if with_final:
            return (states[0], xf[0]) if single else (states, xf)
        return states[0] if single else states

    def predictions(self, inputs: jnp.ndarray,
                    x0: jnp.ndarray | None = None,
                    real_steps: int | None = None,
                    return_final_state: bool = _UNSET, *,
                    donate_state: bool = False,
                    defer_sync: bool = False):
        """Fused-readout rollout: (B, T, I) -> (B, T, O) predictions.

        ``W_out`` is applied inside the rollout (scan body / Pallas
        epilogue), so the (B, T, R) state trajectory is never materialized.
        The deprecated ``return_final_state`` twin warns exactly like
        :meth:`rollout`'s.
        """
        if self._w_out is None:
            raise ValueError("readout not trained; call fit_readout first "
                             "(or submit with want_states=True)")
        with_final = False
        if return_final_state is not _UNSET:
            warn_deprecated(
                "predictions(return_final_state=...) is deprecated: use "
                "run_segment() for chunked serving or "
                "submit(SubmitSpec(...)).final_state for the one-shot "
                "carry")
            with_final = bool(return_final_state)
        u, x0b, single = self._prepare(inputs, x0)
        b, t, _ = u.shape
        t0 = time.perf_counter()
        preds, xf = self._dispatch(u, x0b, True, with_final,
                                   donate_state and with_final)
        self._record(preds, b, t, t0, real_steps, defer=defer_sync)
        if with_final:
            return (preds[0], xf[0]) if single else (preds, xf)
        return preds[0] if single else preds

    def serve(self, requests: Sequence[RolloutRequest],
              bucketer: PaddingBucketer | None = None,
              return_states: bool | None = _UNSET) -> dict:
        """Deprecated-surface batch serve: {uid: bare ndarray}.

        :meth:`submit_many` is the current contract (same batching, but
        answering ``RolloutResult``); this shim survives one release for
        callers holding ``RolloutRequest`` lists.  Without a trained
        readout it falls back to states; the deprecated ``return_states``
        twin forces the states contract with a warning.
        """
        if return_states is _UNSET:
            return_states = None
        else:
            warn_deprecated(
                "serve(return_states=...) is deprecated: use "
                "submit_many([SubmitSpec(..., want_states=True)]) — "
                "results carry .states/.preds explicitly")
        if return_states is None:
            return_states = not self.has_readout
        specs = [SubmitSpec(req.inputs, x0=req.x0, uid=req.uid,
                            want_states=return_states)
                 for req in requests]
        return {uid: res.output
                for uid, res in self.submit_many(specs, bucketer).items()}


# -- bounded engine cache ----------------------------------------------------
# A long-lived multi-tenant server cycles through many reservoirs; an
# unbounded per-process cache of compiled engines would grow without limit.
# The cache is a module-level LRU with two key regimes:
#
# * registry identity ``((name, version), backend)`` — the multi-tenant
#   contract.  (name, version) is stable across process lifetime, so a
#   republished readout with value-equal arrays can NEVER alias the old
#   version's compiled engine: the version number differs, and the entry's
#   staleness check still guards params/readout identity on top.
# * legacy ``(id(params), backend)`` — the single-model accessor
#   (run_reservoir etc.).  A cached engine holds its params alive, so a
#   live entry's id can never be reused by a different object; after
#   eviction an id *can* recur, which the identity staleness check
#   catches before serving a wrong engine.
#
# Entries are (engine, kwargs-signature) tuples; per-tenant hit/miss
# counters land under ``engine_cache_stats()["tenants"]``.
ENGINE_CACHE_MAX = 32
_engine_cache: "collections.OrderedDict[tuple, tuple]" = \
    collections.OrderedDict()
_engine_cache_stats: dict = {"hits": 0, "misses": 0, "evictions": 0,
                             "tenants": {}}


def _tenant_counters(name) -> dict:
    tenants = _engine_cache_stats["tenants"]
    d = tenants.get(name)
    if d is None:
        d = tenants[name] = {"hits": 0, "misses": 0}
    return d


def engine_cache_stats(reset: bool = False) -> dict:
    """Hit/miss/eviction counters of the ``engine_for`` LRU (plus current
    size and the per-tenant breakdown); ``reset=True`` zeroes them."""
    out = dict(_engine_cache_stats, size=len(_engine_cache))
    out["tenants"] = {name: dict(c)
                      for name, c in _engine_cache_stats["tenants"].items()}
    if reset:
        _engine_cache_stats.update(hits=0, misses=0, evictions=0)
        _engine_cache_stats["tenants"].clear()
    return out


def engine_cache_clear() -> None:
    _engine_cache.clear()


def engine_cache_demote(tenant) -> int:
    """Move every cache entry of ``tenant`` — a registry ``(name,
    version)`` — to the eviction front of the LRU, so a just-retired model
    version is the first thing churn reclaims.  Returns the number of
    entries demoted (the engine stays usable until actually evicted:
    in-flight slots pinned to it finish unaffected)."""
    demoted = 0
    for key in list(_engine_cache):
        if key[0] == tenant:
            _engine_cache.move_to_end(key, last=False)
            demoted += 1
    return demoted


def _cache_put(key: tuple, eng: "ReservoirEngine", sig: tuple) -> None:
    _engine_cache[key] = (eng, sig)
    _engine_cache.move_to_end(key)
    while len(_engine_cache) > ENGINE_CACHE_MAX:
        _engine_cache.popitem(last=False)
        _engine_cache_stats["evictions"] += 1
    _engine_cache_stats["misses"] += 1
    obs.event("engine_cache_miss", key=str(key))
    obs.inc("engine_cache_requests_total", outcome="miss")


def _params_stale(eng: "ReservoirEngine", params: ESNParams) -> bool:
    cfg = params.config
    return (eng.params is not params
            or eng._w_out is not params.w_out
            or eng.params.w is not params.w
            or (eng.config.leak, eng.config.mode, eng.config.state_bits)
            != (cfg.leak, cfg.mode, cfg.state_bits))


def engine_for(params: ESNParams, backend: str = "auto", *,
               tenant=None, build=None, **kwargs) -> ReservoirEngine:
    """Engine accessor with a bounded LRU cache (reservoirs are frozen).

    Without ``tenant`` the key is (id(params), backend) — the
    ``run_reservoir`` fast path — and non-default kwargs bypass the cache.
    With ``tenant`` (a registry ``(name, version)`` tuple) the key is the
    *registry identity*: stable across republishes, so an equal-valued
    readout under a new version can never alias the retired engine, and
    hashable kwargs become part of the cached entry (a config change
    rebuilds).  ``build`` overrides the constructor (the registry passes a
    sharded-engine factory on multi-device servers).

    Every entry is invalidated by what the engine bakes in at construction
    — the reservoir matrix, the *readout* (so a stale compiled rollout is
    never served after ``fit_readout`` replaces ``w_out``), and the
    leak/mode/precision config.  At most :data:`ENGINE_CACHE_MAX` engines
    stay resident (least recently used evicted first), so a multi-tenant
    server's memory is bounded — ``engine_cache_stats()`` exposes the
    hit/miss/eviction counters, globally and per tenant.  NOTE: a cached
    engine holds its params (and compiled programs) alive until it is
    evicted or ``engine_cache_clear()`` runs — the cache trades bounded
    pinning for compile reuse.

    ``backend="auto"`` keys the cache on the backend the plan autotuner
    resolves for these params — the SAME resolution the constructor runs,
    so the cache key and the built engine's backend always agree (resolution
    is deterministic and cached on the plan; it used to be hardcoded
    ``"xla"`` for the key while the constructor got the raw string).
    """
    if backend != "auto":
        bk = backend
    elif kwargs.get("schedule") is not None:
        sched = kwargs["schedule"]
        bk = getattr(sched, "schedule", sched).backend
    elif not kwargs.get("specialize", True):
        bk = "xla"  # unspecialized engines have no schedule space to tune
    else:
        bk = resolve_backend(params, backend)
    if tenant is None:
        key = (id(params), bk)
        ent = _engine_cache.get(key)
        eng = ent[0] if ent is not None else None
        if eng is None or kwargs or _params_stale(eng, params):
            eng = (build or ReservoirEngine)(params, backend=backend,
                                            **kwargs)
            if not kwargs and build is None:
                _cache_put(key, eng, ())
        else:
            _engine_cache.move_to_end(key)
            _engine_cache_stats["hits"] += 1
            obs.inc("engine_cache_requests_total", outcome="hit")
        return eng

    name = tenant[0] if isinstance(tenant, tuple) else tenant
    counters = _tenant_counters(name)
    try:
        sig = tuple(sorted(kwargs.items()))
        hash(sig)
    except TypeError as e:
        raise TypeError(
            "engine_for(tenant=...) caches on the kwargs signature, so "
            f"every kwarg must be hashable: {kwargs}") from e
    key = (tenant, bk)
    ent = _engine_cache.get(key)
    if (ent is not None and ent[1] == sig
            and not _params_stale(ent[0], params)):
        _engine_cache.move_to_end(key)
        _engine_cache_stats["hits"] += 1
        counters["hits"] += 1
        obs.inc("engine_cache_requests_total", outcome="hit", tenant=name)
        return ent[0]
    if build is not None:
        eng = build(params, backend=backend, **kwargs)
    else:
        eng = ReservoirEngine(params, backend=backend, tenant=name, **kwargs)
    _cache_put(key, eng, sig)
    counters["misses"] += 1
    return eng


__all__ = ["ENGINE_CACHE_MAX", "ReservoirEngine", "engine_for",
           "engine_cache_clear", "engine_cache_demote",
           "engine_cache_stats", "ServeStats",
           "PaddingBucketer", "RolloutRequest", "MicroBatch",
           "SubmitSpec", "RolloutResult"]
