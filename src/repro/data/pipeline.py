"""Data pipelines: deterministic synthetic LM stream + reservoir tasks.

The LM stream is a stateless function of (seed, step, shard) so any worker
can reproduce any batch — the property that makes checkpoint-resume and
elastic re-sharding exact: no data iterator state needs saving, and a
re-planned mesh re-slices the same global batch ids.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["LMStreamConfig", "lm_batch", "mackey_glass", "narma10",
           "channel_equalization", "memory_capacity_task"]


@dataclasses.dataclass(frozen=True)
class LMStreamConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # markov-ish synthetic text: token_{t+1} = f(token_t) + noise, so models
    # can actually reduce loss below ln(V) (pure uniform noise cannot).
    structure: float = 0.8


def lm_batch(cfg: LMStreamConfig, step: int, shard: int = 0,
             n_shards: int = 1) -> dict:
    """Batch for ``step``; ``shard``/``n_shards`` slice the global batch."""
    assert cfg.global_batch % n_shards == 0
    per = cfg.global_batch // n_shards
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, shard]))
    b, s, v = per, cfg.seq_len, cfg.vocab_size
    toks = np.empty((b, s + 1), np.int32)
    toks[:, 0] = rng.integers(0, v, b)
    mult = 6364136223846793005 % v
    structured = rng.random((b, s)) < cfg.structure
    noise = rng.integers(0, v, (b, s))
    for t in range(s):
        nxt = (toks[:, t].astype(np.int64) * mult + 12345) % v
        toks[:, t + 1] = np.where(structured[:, t], nxt, noise[:, t])
    return {"tokens": toks}


# ---------------------------------------------------------------------------
# Reservoir-computing tasks (paper Sec. II workloads)
# ---------------------------------------------------------------------------
def mackey_glass(n: int, tau: int = 17, seed: int = 0, beta=0.2, gamma=0.1,
                 p=10.0, dt=1.0, washout: int = 500) -> np.ndarray:
    """Mackey-Glass delay differential equation (RK4), the canonical ESN
    chaotic-series benchmark."""
    rng = np.random.default_rng(seed)
    hist = 1.2 + 0.2 * (rng.random(tau + 1) - 0.5)
    x = list(hist)

    def f(xt, xd):
        return beta * xd / (1.0 + xd ** p) - gamma * xt

    for _ in range(n + washout):
        xt, xd = x[-1], x[-1 - tau]
        k1 = f(xt, xd)
        k2 = f(xt + 0.5 * dt * k1, xd)
        k3 = f(xt + 0.5 * dt * k2, xd)
        k4 = f(xt + dt * k3, xd)
        x.append(xt + dt / 6.0 * (k1 + 2 * k2 + 2 * k3 + k4))
    return np.asarray(x[tau + 1 + washout:], np.float32)


def narma10(n: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """NARMA-10 nonlinear autoregressive benchmark: (input u, target y)."""
    rng = np.random.default_rng(seed)
    u = rng.uniform(0, 0.5, n + 10).astype(np.float32)
    y = np.zeros(n + 10, np.float32)
    for t in range(9, n + 9):
        y[t + 1] = (0.3 * y[t] + 0.05 * y[t] * y[t - 9:t + 1].sum()
                    + 1.5 * u[t - 9] * u[t] + 0.1)
    return u[10:], y[10:]


def channel_equalization(n: int, seed: int = 0, snr_db: float = 28.0
                         ) -> tuple[np.ndarray, np.ndarray]:
    """Nonlinear channel equalization (paper [3]'s online-learning task).

    A 4-PAM symbol stream d(t) passes through a linear multipath filter and
    a memoryless nonlinearity plus noise; the task is to recover d(t - 2).
    """
    rng = np.random.default_rng(seed)
    pad = 10
    d = rng.choice([-3.0, -1.0, 1.0, 3.0], size=n + 2 * pad).astype(np.float32)
    # Jaeger's nonlinear channel (the formulation [3] equalizes):
    #   q(t) = 0.08 d(t+2) - 0.12 d(t+1) + d(t) + 0.18 d(t-1) - 0.1 d(t-2)
    #          + 0.09 d(t-3) - 0.05 d(t-4) + 0.04 d(t-5) + 0.03 d(t-6)
    #          + 0.01 d(t-7)
    #   u(t) = q + 0.036 q^2 - 0.011 q^3 + noise;  recover d(t) from u.
    taps = [(2, 0.08), (1, -0.12), (0, 1.0), (-1, 0.18), (-2, -0.1),
            (-3, 0.09), (-4, -0.05), (-5, 0.04), (-6, 0.03), (-7, 0.01)]
    idx = np.arange(pad, pad + n)
    q = sum(c * d[idx + k] for k, c in taps)
    q = q + 0.036 * q ** 2 - 0.011 * q ** 3
    sigma = np.sqrt(np.mean(q ** 2) / (10 ** (snr_db / 10)))
    u = (q + rng.normal(0, sigma, q.shape)).astype(np.float32)
    return u, d[idx]


def memory_capacity_task(n: int, max_delay: int = 40, seed: int = 0
                         ) -> tuple[np.ndarray, np.ndarray]:
    """Inputs u(t) ~ U(-1,1); targets y_k(t) = u(t-k) for k=1..max_delay."""
    rng = np.random.default_rng(seed)
    u = rng.uniform(-1, 1, n + max_delay).astype(np.float32)
    ys = np.stack([u[max_delay - k: n + max_delay - k]
                   for k in range(1, max_delay + 1)], axis=1)
    return u[max_delay:], ys
