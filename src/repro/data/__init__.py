"""Data pipelines."""
