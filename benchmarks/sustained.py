"""Sustained-load SLO harness: long traces, faults, and hard gates.

The production-hardening acceptance run (ROADMAP 4d): drive the serving
stack with long arrival traces on the deterministic virtual clock —
steady Poisson, bursty, overload, and a chaos trace with an injected
fault schedule — and report the SLO surface:

* p50 / p99 / p999 request latency and queue wait, read back from the
  obs histograms the servers already feed (the same fixed-bucket
  series production scrapes);
* shed / rejection rate under the admission policy;
* recovery time after an injected shard death (chaos trace, 8 virtual
  devices: loss -> shrink -> autoscale grow-back);
* the three hard gates CI asserts on a shortened trace:

  1. **zero lost admitted requests** — every request that entered the
     queue is either completed or an accounted timeout, through
     transients, stragglers, and shard death (``lost == 0``);
  2. **bounded p99 under overload with backpressure on** — a bounded
     queue caps queue wait at ~``max_depth`` chunk times, while the
     same trace without admission control diverges (p99 grows with
     the trace length, not the pool);
  3. **bit-exactness under chaos** — every completed request's output
     equals the undisturbed no-fault run of the same admitted set,
     bit for bit.

Standalone: ``python -m benchmarks.sustained [--fast]`` writes
``BENCH_sustained.json``; the ``serve_sustained`` family in
``benchmarks/run.py`` embeds the same measurements in the bench suite
(the chaos trace respawns under 8 virtual host devices there).
"""

from __future__ import annotations

import argparse
import json
import sys

import numpy as np

sys.path.insert(0, "src")  # allow `python -m benchmarks.sustained`

SUSTAINED_OUT = "BENCH_sustained.json"

# pool geometry shared by every scenario (chunk cost is measured, the
# clock is virtual, so the geometry — not the host — sets the SLOs)
N_SLOTS = 8
CHUNK_STEPS = 16
MAX_DEPTH = 16          # BoundedQueuePolicy depth for the overload gate


def _params(dim, seed=0, out_dim=4):
    """Frozen reservoir sized for trace runs (4-dim inputs, fixed
    readout; no spectral rescale — it doesn't affect scheduling)."""
    import jax.numpy as jnp
    from repro.core.esn import ESNConfig, ESNParams
    from repro.core.sparse import FixedMatrix, random_sparse_matrix
    rng = np.random.default_rng(seed)
    w = random_sparse_matrix(dim, dim, 0.9, rng) * 0.05
    fm = FixedMatrix.compile(w, weight_bits=8, mode="csd", block=128,
                             rng=rng)
    cfg = ESNConfig(reservoir_dim=dim, input_dim=4, mode="fp32", block=128,
                    seed=seed)
    w_in = jnp.asarray(rng.uniform(-0.5, 0.5, (4, dim)), jnp.float32)
    w_out = jnp.asarray(rng.uniform(-0.1, 0.1, (dim, out_dim)), jnp.float32)
    return ESNParams(w=fm, w_in=w_in, w_out=w_out, config=cfg)


def _trace(n_req, mean_gap, seed, *, bursty=False, deadline_frac=0.0,
           deadline_budget=0.0):
    """A reproducible arrival trace: specs + arrival times (+deadlines).

    ``bursty`` clusters arrivals in bursts of 8 separated by quiet gaps
    of the same total mass, so the instantaneous rate swings ~8x around
    the same mean.
    """
    from repro.serve import SubmitSpec
    rng = np.random.default_rng(seed)
    lengths = rng.integers(8, 65, n_req)
    if bursty:
        n_bursts = max(1, n_req // 8)
        starts = np.cumsum(rng.exponential(8 * mean_gap, n_bursts))
        at = np.sort(rng.choice(starts, n_req)
                     + rng.exponential(0.1 * mean_gap, n_req))
        at -= at[0]
    else:
        gaps = rng.exponential(mean_gap, n_req)
        at = np.cumsum(gaps) - gaps[0]
    specs = []
    for i, t in enumerate(lengths):
        dl = None
        if deadline_frac and rng.random() < deadline_frac:
            dl = float(at[i]) + deadline_budget
        specs.append(SubmitSpec(
            rng.standard_normal((int(t), 4)).astype(np.float32),
            uid=i, deadline=dl))
    return specs, at, int(lengths.sum())


def _measure_chunk_time(params, dim):
    """One pool chunk's measured cost — the virtual clock's tick."""
    import time

    import jax
    import jax.numpy as jnp
    from repro.serve import ReservoirEngine
    eng = ReservoirEngine(params, backend="xla")
    u = jnp.asarray(np.random.default_rng(0).standard_normal(
        (N_SLOTS, CHUNK_STEPS, 4)), jnp.float32)
    x0 = jnp.zeros((N_SLOTS, dim), jnp.float32)
    jax.block_until_ready(eng.run_segment(u, x0)[0])      # compile
    t0 = time.perf_counter()
    for _ in range(3):
        jax.block_until_ready(eng.run_segment(u, x0)[0])
    return (time.perf_counter() - t0) / 3


def _percentiles(name="request_latency_seconds"):
    from repro import obs
    fam = obs.metrics().get(name)
    if fam is None:
        return {"p50": 0.0, "p99": 0.0, "p999": 0.0}
    d = fam.data()
    return {"p50": d.percentile(50.0), "p99": d.percentile(99.0),
            "p999": d.percentile(99.9)}


def _drive(srv, specs, arrivals):
    """Play the trace against the virtual clock: a request is submitted
    when the clock reaches its arrival time, so admission policies see
    the queue as it actually is at that instant — submitting the whole
    future up front would count unarrived requests as backlog and shed
    the lot."""
    i, n = 0, len(specs)
    while i < n or not srv.drained:
        while i < n and (arrivals[i] <= srv.now or srv.drained):
            # drained + future arrival: submit it and let the server
            # fast-forward its clock to the arrival
            srv.submit(specs[i], arrival_time=float(arrivals[i]))
            i += 1
        srv.step()
    return srv.results


def _replay_reference(params, admitted_specs, arrivals_by_uid, chunk_time):
    """The undisturbed reference: the same admitted set on a plain
    server — no admission policy, no fault plan — at the same pool
    shape.  Pool rows never mix, so every completed request must match
    this run bit for bit."""
    from repro.serve import (AsyncReservoirServer, ReservoirEngine,
                             ServeStats, SubmitSpec)
    import dataclasses
    eng = ReservoirEngine(params, backend="xla", stats=ServeStats())
    srv = AsyncReservoirServer(eng, n_slots=N_SLOTS,
                               chunk_steps=CHUNK_STEPS,
                               chunk_time=chunk_time, stats=ServeStats())
    for spec in admitted_specs:
        # deadlines off: the reference answers "what are the right bits",
        # not "would it have been dropped"
        srv.submit(dataclasses.replace(spec, deadline=None),
                   arrival_time=arrivals_by_uid[spec.uid])
    return srv.run()


def _bitexact(results, reference):
    """Every completed request matches the reference bit for bit."""
    checked = 0
    for uid, res in results.items():
        if getattr(res, "status", "ok") != "ok":
            continue
        ref = reference[uid]
        if not np.array_equal(np.asarray(res.output),
                              np.asarray(ref.output)):
            return False, checked
        checked += 1
    return True, checked


def _row(scenario, srv, n_req, total_steps, chunk_time, **extra):
    st = srv.stats
    submitted = st.enqueued + st.rejected + st.shed
    lost = st.enqueued - st.completed - st.timed_out
    lat = _percentiles("request_latency_seconds")
    wait = _percentiles("queue_wait_seconds")
    return {
        "family": "serve_sustained", "scenario": scenario,
        "mode": "fp32", "backend": "xla",
        "n_slots": N_SLOTS, "chunk_steps": CHUNK_STEPS,
        "chunk_time_s": chunk_time,
        "requests": n_req, "total_steps": total_steps,
        "submitted": submitted, "admitted": st.enqueued,
        "completed": st.completed, "timed_out": st.timed_out,
        "rejected": st.rejected, "shed": st.shed, "retries": st.retries,
        "lost_admitted": lost,
        "shed_rate": (st.rejected + st.shed) / submitted if submitted
        else 0.0,
        "makespan_s": srv.now,
        "latency_p50_s": lat["p50"], "latency_p99_s": lat["p99"],
        "latency_p999_s": lat["p999"],
        "queue_wait_p99_s": wait["p99"],
        **extra,
    }


def measure_local(fast: bool) -> list:
    """The single-device scenarios: poisson, bursty(+faults), overload
    with backpressure on vs off."""
    from repro import obs
    from repro.runtime.faults import FaultPlan
    from repro.serve import (AsyncReservoirServer, BoundedQueuePolicy,
                             ReservoirEngine, ServeStats, default_policy)

    dim = 256 if fast else 512
    n_req = 48 if fast else 160
    params = _params(dim, seed=5)
    t_chunk = _measure_chunk_time(params, dim)
    # service rate of the pool in steps/s; mean request is ~36 steps
    service = N_SLOTS * CHUNK_STEPS / t_chunk
    mean_len = 36.0

    def server(admission=None, fault_plan=None):
        eng = ReservoirEngine(params, backend="xla", stats=ServeStats())
        return AsyncReservoirServer(
            eng, n_slots=N_SLOTS, chunk_steps=CHUNK_STEPS,
            chunk_time=t_chunk, stats=ServeStats(),
            admission=admission, fault_plan=fault_plan)

    rows = []

    # -- poisson @ ~80% utilisation: the steady-state SLO baseline ------
    obs.configure(tracing=False)
    try:
        specs, at, steps = _trace(n_req, mean_len / (0.8 * service), seed=21,
                                  deadline_frac=0.25,
                                  deadline_budget=50 * t_chunk)
        srv = server(admission=default_policy(max_depth=4 * N_SLOTS))
        res = _drive(srv, specs, at)
        admitted = [s for s in specs
                    if getattr(res.get(s.uid), "status", "ok") != "rejected"]
        ref = _replay_reference(params, admitted,
                                dict(zip([s.uid for s in specs], at)),
                                t_chunk)
        exact, checked = _bitexact(res, ref)
        rows.append(_row("poisson", srv, n_req, steps, t_chunk,
                         utilization=0.8, bitexact=exact,
                         bitexact_checked=checked))
    finally:
        obs.disable()

    # -- bursty arrivals + seeded transient/straggler faults ------------
    obs.configure(tracing=False)
    try:
        specs, at, steps = _trace(n_req, mean_len / (0.8 * service), seed=22,
                                  bursty=True)
        horizon = float(at[-1]) + 20 * t_chunk
        plan = FaultPlan.seeded(7, horizon=horizon,
                                transient_rate=2.0 / horizon * 5,
                                slow_rate=1.0 / horizon * 3,
                                slow_factor=3.0,
                                slow_duration=5 * t_chunk,
                                backoff_base_s=t_chunk / 64)
        srv = server(admission=default_policy(max_depth=4 * N_SLOTS),
                     fault_plan=plan)
        res = _drive(srv, specs, at)
        admitted = [s for s in specs
                    if getattr(res.get(s.uid), "status", "ok") != "rejected"]
        ref = _replay_reference(params, admitted,
                                dict(zip([s.uid for s in specs], at)),
                                t_chunk)
        exact, checked = _bitexact(res, ref)
        rows.append(_row("bursty_faults", srv, n_req, steps, t_chunk,
                         utilization=0.8, bitexact=exact,
                         bitexact_checked=checked,
                         faults_injected=dict(plan.injected)))
    finally:
        obs.disable()

    # -- overload @ ~3x: backpressure on vs off -------------------------
    # longer trace than the steady scenarios: the unbounded queue's p99
    # grows with the trace, the bounded one must not — the gap IS the gate
    over_n = 2 * n_req
    for label, admission in (("overload_backpressure",
                              BoundedQueuePolicy(max_depth=MAX_DEPTH)),
                             ("overload_unbounded", None)):
        obs.configure(tracing=False)
        try:
            specs, at, steps = _trace(over_n, mean_len / (3.0 * service),
                                      seed=23)
            srv = server(admission=admission)
            _drive(srv, specs, at)
            rows.append(_row(label, srv, over_n, steps, t_chunk,
                             utilization=3.0,
                             max_depth=MAX_DEPTH if admission else None))
        finally:
            obs.disable()
    return rows


def measure_chaos(fast: bool) -> list:
    """The chaos trace: 8 virtual devices, sharded server, one injected
    shard death mid-trace, seeded transients, autoscale grow-back.
    Reports recovery time (loss -> pool width restored) and the
    bit-exactness verdict vs the undisturbed 4-shard run."""
    import jax
    from repro import obs
    from repro.dist import DistributedReservoirServer, ShardedReservoirEngine
    from repro.runtime.elastic import AutoscalePolicy
    from repro.runtime.faults import FaultPlan
    from repro.serve import ServeStats

    assert len(jax.devices()) >= 8, "chaos trace needs 8 devices"
    dim = 256
    n_req = 48 if fast else 120
    sps = 2                     # slots_per_shard >= 2: bit-identity regime
    n_shards = 4
    params = _params(dim, seed=6)
    t_chunk = 1.0               # device-parallel virtual clock
    specs, at, steps = _trace(n_req, 36.0 / (0.8 * n_shards * sps
                                             * CHUNK_STEPS / t_chunk),
                              seed=31)
    loss_at = float(at[-1]) * 0.3
    horizon = float(at[-1]) + 40 * t_chunk

    def serve(disturb):
        plan = None
        autoscale = None
        if disturb:
            plan = FaultPlan.seeded(11, horizon=horizon,
                                    transient_rate=3.0 / horizon,
                                    shard_loss_times=[loss_at],
                                    backoff_base_s=t_chunk / 64)
            autoscale = AutoscalePolicy(min_shards=1, max_shards=n_shards,
                                        cooldown_steps=2)
        eng = ShardedReservoirEngine(params, n_shards=n_shards,
                                     stats=ServeStats())
        srv = DistributedReservoirServer(
            eng, slots_per_shard=sps, chunk_steps=CHUNK_STEPS,
            chunk_time=t_chunk, stats=ServeStats(), fault_plan=plan,
            autoscale=autoscale)
        for spec, t in zip(specs, at):
            srv.submit(spec, arrival_time=float(t))
        widths = []
        while srv.step():
            widths.append((srv.now, srv.n_shards))
        return srv.results, srv, plan, widths

    obs.configure(tracing=False)
    try:
        res, srv, plan, widths = serve(disturb=True)
        ref, ref_srv, _, _ = serve(disturb=False)
        exact, checked = _bitexact(res, ref)
        loss_t = plan.fault_times.get("shard_loss", [loss_at])[0]
        restored = [t for t, w in widths if t > loss_t and w >= n_shards]
        recovery = (restored[0] - loss_t) if restored else None
        return [_row("chaos", srv, n_req, steps, t_chunk,
                     n_shards=n_shards, slots_per_shard=sps,
                     reshards=srv.reshards, grows=srv.grows,
                     readmitted=srv.readmitted,
                     shard_loss_at_s=loss_t,
                     recovery_time_s=recovery,
                     bitexact=exact, bitexact_checked=checked,
                     faults_injected=dict(plan.injected))]
    finally:
        obs.disable()


def gates(rows: list) -> dict:
    """The CI gate summary: every value here is asserted by the
    workflow's serve_sustained step."""
    by = {r["scenario"]: r for r in rows}
    bp = by.get("overload_backpressure")
    ub = by.get("overload_unbounded")
    out = {
        "zero_lost_admitted": all(r["lost_admitted"] == 0 for r in rows),
        "bitexact_all": all(r.get("bitexact", True) for r in rows),
    }
    if bp and ub:
        # the bounded queue caps wait at ~max_depth + in-pool chunks;
        # the unbounded queue's p99 grows with the whole trace
        bound = (MAX_DEPTH + 3 * N_SLOTS) * bp["chunk_time_s"]
        out["overload_p99_bounded"] = bp["latency_p99_s"] <= bound
        out["overload_p99_bound_s"] = bound
        out["overload_p99_with_s"] = bp["latency_p99_s"]
        out["overload_p99_without_s"] = ub["latency_p99_s"]
        out["overload_backpressure_wins"] = (
            bp["latency_p99_s"] < ub["latency_p99_s"])
        out["overload_sheds"] = bp["rejected"] + bp["shed"] > 0
    chaos = by.get("chaos")
    if chaos:
        out["chaos_recovered"] = (chaos.get("recovery_time_s") is not None
                                  and chaos["grows"] >= 1)
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json-out", default=SUSTAINED_OUT)
    ap.add_argument("--chaos-child", action="store_true",
                    help=argparse.SUPPRESS)  # respawned under 8 devices
    args = ap.parse_args(argv)
    if args.chaos_child:
        rows = measure_chaos(args.fast)
        print("SUSTAINED_JSON")
        print(json.dumps(rows))
        return
    rows = measure_local(args.fast)
    import jax
    if len(jax.devices()) >= 8:
        rows.extend(measure_chaos(args.fast))
    payload = {"benchmark": "serve_sustained", "fast_mode": args.fast,
               "rows": rows, "gates": gates(rows)}
    with open(args.json_out, "w") as fh:
        json.dump(payload, fh, indent=2)
    print(f"# wrote {args.json_out} ({len(rows)} rows)", file=sys.stderr)
    print(json.dumps(payload["gates"], indent=2))


if __name__ == "__main__":
    main()
